PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify verify-fast test test-fast sweep-quick bench-quick \
	bench-solver bench-solver-smoke bench-serve bench-serve-smoke \
	lint docs-check clean

## verify: repro-lint gate + tier-1 tests + one quick end-to-end sweep + the
## batched-solver and serving-gateway throughput smoke gates (the CI gate)
verify: lint test sweep-quick bench-solver-smoke bench-serve-smoke

## verify-fast: the core dev loop (<45s) — deselects the multi-minute
## jax-stack tests (pytest -m slow: shard_map subprocess runs, kernel
## sweeps, dry-runs) and runs quick serving sweeps: one static admission
## round, one event-driven churn suite (exercises the ServeSim loop), one
## failure-injection suite (exercises migration + trace replay), and one
## mixed training/inference suite (exercises the round-trip TR-pipe model
## and mode-split contention reporting, docs/training.md)
verify-fast: test-fast
	$(PYTHON) -m repro.sweep --suite nsfnet_multirequest nsfnet_churn \
		nsfnet_failures nsfnet_mixed_training --quick --out sweep_out

## test: tier-1 test suite (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

## test-fast: tier-1 suite without the slow-marked jax-stack tests
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

## sweep-quick: quick NSFNET paper-grid sweep through the scenario engine
sweep-quick:
	$(PYTHON) -m repro.sweep --suite nsfnet_paper --quick --out sweep_out

## bench-quick: all paper-figure benchmarks at the reduced CI tier
bench-quick:
	$(PYTHON) -m benchmarks.run --quick

## bench-solver: full solver-core throughput grid -> BENCH_solver.json
## (NumPy loop vs batched JAX vs Pallas, batch sizes 1..1024; exits non-zero
## unless warm batched JAX is >= 10x the NumPy loop at batch >= 256)
bench-solver:
	$(PYTHON) -m benchmarks.solver_throughput

## bench-solver-smoke: batch=8 gate only — warm batched JAX must beat the
## scalar NumPy loop
bench-solver-smoke:
	$(PYTHON) -m benchmarks.solver_throughput --smoke

## bench-serve: full gateway throughput grid (batch-window sweep, cold vs
## warm admissions/s, tick percentiles) -> BENCH_serve.json
bench-serve:
	$(PYTHON) -m benchmarks.serve_throughput

## bench-serve-smoke: one small streaming cell — warm sustained gateway
## throughput must clear the admissions/s floor (docs/gateway.md)
bench-serve-smoke:
	$(PYTHON) -m benchmarks.serve_throughput --smoke

## lint: repro-lint in --strict mode (docs/analysis.md) + ruff's pyflakes
## tier as the generic complement where installed (CI installs it; local
## trees without ruff still get the full repro-lint gate)
lint:
	$(PYTHON) -m repro.analysis --strict src/repro
	@if command -v ruff > /dev/null 2>&1; then \
		ruff check src/repro; \
	else \
		echo "ruff not installed; skipped the generic pyflakes tier"; \
	fi

## docs-check: CLIs import/--help cleanly and docs/*.md links are unbroken
docs-check:
	$(PYTHON) -m repro.sweep --help > /dev/null
	$(PYTHON) -m repro.serve --help > /dev/null
	$(PYTHON) -m repro.serve --gateway --n-requests 4 --arrival poisson \
		--batch-window-s 0.5 > /dev/null
	$(PYTHON) scripts/check_docs_sync.py

clean:
	rm -rf sweep_out .pytest_cache
	find . -name __pycache__ -type d -prune -exec rm -rf {} +
