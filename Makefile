PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify test sweep-quick bench-quick clean

## verify: tier-1 tests + one quick end-to-end sweep (the CI gate)
verify: test sweep-quick

## test: tier-1 test suite (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

## sweep-quick: quick NSFNET paper-grid sweep through the scenario engine
sweep-quick:
	$(PYTHON) -m repro.sweep --suite nsfnet_paper --quick --out sweep_out

## bench-quick: all paper-figure benchmarks at the reduced CI tier
bench-quick:
	$(PYTHON) -m benchmarks.run --quick

clean:
	rm -rf sweep_out .pytest_cache
	find . -name __pycache__ -type d -prune -exec rm -rf {} +
