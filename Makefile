PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify verify-fast test test-fast sweep-quick bench-quick docs-check clean

## verify: tier-1 tests + one quick end-to-end sweep (the CI gate)
verify: test sweep-quick

## verify-fast: the core dev loop (<40s) — deselects the multi-minute
## jax-stack tests (pytest -m slow: shard_map subprocess runs, kernel
## sweeps, dry-runs) and runs quick serving sweeps: one static admission
## round and one event-driven churn suite (exercises the ServeSim loop)
verify-fast: test-fast
	$(PYTHON) -m repro.sweep --suite nsfnet_multirequest nsfnet_churn \
		--quick --out sweep_out

## test: tier-1 test suite (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

## test-fast: tier-1 suite without the slow-marked jax-stack tests
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

## sweep-quick: quick NSFNET paper-grid sweep through the scenario engine
sweep-quick:
	$(PYTHON) -m repro.sweep --suite nsfnet_paper --quick --out sweep_out

## bench-quick: all paper-figure benchmarks at the reduced CI tier
bench-quick:
	$(PYTHON) -m benchmarks.run --quick

## docs-check: CLIs import/--help cleanly and docs/*.md links are unbroken
docs-check:
	$(PYTHON) -m repro.sweep --help > /dev/null
	$(PYTHON) -m repro.serve --help > /dev/null
	$(PYTHON) scripts/check_docs_sync.py

clean:
	rm -rf sweep_out .pytest_cache
	find . -name __pycache__ -type d -prune -exec rm -rf {} +
