"""Figs. 8 & 9: impact of K on latency with computation / transmission /
propagation breakdown, for MSI (b=2) and MSL (b=128)."""
from __future__ import annotations

from repro.core import IF, TR, ServiceChainRequest

from .common import DEST, SOURCE, Row, candidate_sets, paper_instance, solve

SCHEMES = ["exact", "bcd", "comp-ms", "comm-ms"]


def run(quick: bool = False) -> list[Row]:
    net, prof = paper_instance()
    rows: list[Row] = []
    cases = [(IF, 2, "fig8"), (TR, 128, "fig9")]
    ks = [2, 3, 5] if quick else range(2, 8)
    n_seeds = 3 if quick else 10
    for mode, b, fig in cases:
        req = ServiceChainRequest("resnet101", SOURCE, DEST, b, mode)
        for K in ks:
            for scheme in SCHEMES:
                agg = [0.0, 0.0, 0.0]
                n = 0
                for seed in range(n_seeds):
                    res = solve(scheme, net, prof, req, K, candidate_sets(K, seed))
                    if res.feasible:
                        n += 1
                        agg[0] += res.latency.computation_s
                        agg[1] += res.latency.transmission_s
                        agg[2] += res.latency.propagation_s
                if n == 0:
                    rows.append(Row(f"{fig}_K{K}_{scheme}", float("nan"), "infeasible"))
                    continue
                comp, trans, prop = (v / n for v in agg)
                rows.append(Row(
                    f"{fig}_K{K}_{scheme}",
                    (comp + trans + prop) * 1e6,
                    f"comp_ms={comp*1e3:.2f};trans_ms={trans*1e3:.2f};"
                    f"prop_ms={prop*1e3:.2f}",
                ))
    return rows
