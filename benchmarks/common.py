"""Shared setup for the paper-reproduction benchmarks.

The scenario grids themselves live in ``repro.sweep.suites``; this module keeps
the CSV row type plus thin compatibility wrappers for scripts that still build
one-off instances by hand.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import (
    ProblemInstance,
    nsfnet,
    resnet101_profile,
)
from repro.core import solve as engine_solve
from repro.core import solver_names
from repro.sweep.spec import candidate_sets as _candidate_sets
from repro.sweep.suites import DEST, NSFNET_NODES, SOURCE

# `exact` is the provably-ILP-equivalent joint DP (tests/test_core_solvers.py
# proves equality with the HiGHS MILP); the latency grids use it so the full
# paper sweep stays fast on this 1-core container.  `ilp` (HiGHS) is run in the
# exec-time benchmarks, where its wall time is the measurement.  The scheme
# names come from the engine registry (repro.core.solver_names).
SOLVERS = tuple(solver_names())


def candidate_sets(K: int, seed: int, nodes: list[str] | None = None,
                   source: str = SOURCE, dest: str = DEST) -> list[list[str]]:
    """Paper Sec. VI-A2 candidate policy (delegates to the sweep engine)."""
    return _candidate_sets(K, seed, nodes or NSFNET_NODES, source, dest)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def group_in_order(results, keyfn):
    """Group sweep results by keyfn preserving first-seen (suite) order."""
    cells: dict = {}
    for r in results:
        cells.setdefault(keyfn(r), []).append(r)
    return cells


def solve(scheme: str, net, profile, request, K, cands, **kw):
    """Solve one hand-built instance through the engine registry."""
    problem = ProblemInstance(net, profile, request, K,
                              tuple(tuple(c) for c in cands))
    return engine_solve(problem, scheme, **kw)


def paper_instance(source: str = SOURCE):
    return nsfnet(source=source), resnet101_profile()
