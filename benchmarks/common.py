"""Shared setup for the paper-reproduction benchmarks."""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core import (
    IF,
    TR,
    ServiceChainRequest,
    bcd_solve,
    comm_ms_solve,
    comp_ms_solve,
    exact_solve,
    ilp_solve,
    nsfnet,
    resnet101_profile,
)

SOURCE, DEST = "v4", "v13"

# `exact` is the provably-ILP-equivalent joint DP (tests/test_core_solvers.py
# proves equality with the HiGHS MILP); the latency grids use it so the full
# paper sweep stays fast on this 1-core container.  `ilp` (HiGHS) is run in the
# exec-time benchmarks, where its wall time is the measurement.
SOLVERS = {
    "ilp": ilp_solve,
    "exact": exact_solve,
    "bcd": bcd_solve,
    "comp-ms": comp_ms_solve,
    "comm-ms": comm_ms_solve,
}


def candidate_sets(K: int, seed: int, nodes: list[str] | None = None,
                   source: str = SOURCE, dest: str = DEST) -> list[list[str]]:
    """Paper Sec. VI-A2: first/last pinned to s/d; each intermediate sub-model
    gets |V^k| = 2 randomly, distinctly selected candidate nodes."""
    rng = random.Random(seed * 1000 + K)
    nodes = nodes or [f"v{i}" for i in range(1, 15)]
    mids = [n for n in nodes if n not in (source, dest)]
    picked = rng.sample(mids, 2 * (K - 2)) if K > 2 else []
    cands = [[source]]
    for k in range(K - 2):
        cands.append(picked[2 * k : 2 * k + 2])
    cands.append([dest])
    return cands


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def solve(scheme: str, net, profile, request, K, cands, **kw):
    return SOLVERS[scheme](net, profile, request, K, cands, **kw)


def paper_instance(source: str = SOURCE):
    return nsfnet(source=source), resnet101_profile()
