"""Figs. 10 & 11: solver execution time vs service chain length K and network
size V.  Training scenario, b = 128 (paper Sec. VI-D).

The paper's limit is 1000 s on a 36-core Xeon; this container has 1 core, so the
default ILP time limit is scaled to 120 s (the qualitative result — ILP times out
for V >= 30 while BCD stays in the tens of milliseconds — is preserved; see
EXPERIMENTS.md).
"""
from __future__ import annotations

import time

from repro.core import TR, ServiceChainRequest, random_network

from .common import DEST, SOURCE, Row, candidate_sets, paper_instance, solve

SCHEMES = ["ilp", "bcd", "comp-ms", "comm-ms"]


def run_k_sweep(quick: bool = False, ilp_time_limit: float = 120.0) -> list[Row]:
    net, prof = paper_instance()
    rows: list[Row] = []
    ks = [2, 4] if quick else range(2, 8)
    for K in ks:
        n_seeds = 1 if (quick or K >= 6) else 3  # big-K MILPs are slow (1 core)
        for scheme in SCHEMES:
            times, n_feas = [], 0
            for seed in range(n_seeds):
                req = ServiceChainRequest("resnet101", SOURCE, DEST, 128, TR)
                kw = {"time_limit_s": ilp_time_limit} if scheme == "ilp" else {}
                res = solve(scheme, net, prof, req, K, candidate_sets(K, seed), **kw)
                times.append(res.wall_time_s)
                n_feas += int(res.feasible)
            avg = sum(times) / len(times)
            rows.append(Row(f"fig10_K{K}_{scheme}", avg * 1e6,
                            f"exec_time_ms={avg*1e3:.2f};feasible={n_feas}/{n_seeds}"))
    return rows


def run_v_sweep(quick: bool = False, ilp_time_limit: float = 120.0) -> list[Row]:
    rows: list[Row] = []
    vs = [10, 20] if quick else [10, 20, 30, 40, 50]
    prof = paper_instance()[1]
    K = 4
    for V in vs:
        net = random_network(V, p=0.2, seed=7, source="v1")
        nodes = sorted(net.nodes)
        dest = nodes[-1]
        req = ServiceChainRequest("resnet101", "v1", dest, 128, TR)
        for scheme in SCHEMES:
            if scheme == "ilp" and V >= 30 and quick:
                continue
            cands = candidate_sets(K, 0, nodes=nodes, source="v1", dest=dest)
            kw = {"time_limit_s": ilp_time_limit} if scheme == "ilp" else {}
            t0 = time.perf_counter()
            res = solve(scheme, net, prof, req, K, cands, **kw)
            wall = time.perf_counter() - t0
            status = "ok" if res.feasible else "timeout/infeasible"
            rows.append(Row(f"fig11_V{V}_{scheme}", wall * 1e6,
                            f"exec_time_ms={wall*1e3:.2f};{status}"))
    return rows


def run(quick: bool = False) -> list[Row]:
    return run_k_sweep(quick) + run_v_sweep(quick)
