"""Figs. 10 & 11: solver execution time vs service chain length K and network
size V.  Training scenario, b = 128 (paper Sec. VI-D).

The paper's limit is 1000 s on a 36-core Xeon; this container has 1 core, so the
default ILP time limit is scaled to 120 s (the qualitative result — ILP times out
for V >= 30 while BCD stays in the tens of milliseconds — is preserved; see
EXPERIMENTS.md).

Scenario grids come from the sweep engine (``exec_time_k`` / ``random_scaling``
suites).  Execution is strictly serial with the shared-cache context disabled
(``use_context_cache=False``): wall time is the measurement here, and warm
cross-scenario caches would flatter whichever scheme runs later.
"""
from __future__ import annotations

from repro.sweep import SweepRunner
from repro.sweep.suites import exec_time_k, random_scaling

from .common import Row, group_in_order


def _cold_runner() -> SweepRunner:
    return SweepRunner(workers=0, use_context_cache=False)


def run_k_sweep(quick: bool = False, ilp_time_limit: float = 120.0) -> list[Row]:
    specs = exec_time_k(quick=quick, ilp_time_limit_s=ilp_time_limit)
    results = _cold_runner().run(specs)
    cells = group_in_order(results, lambda r: (r.spec.K, r.spec.solver))
    rows: list[Row] = []
    for (K, scheme), rs in cells.items():
        avg = sum(r.wall_time_s for r in rs) / len(rs)
        n_feas = sum(r.feasible for r in rs)
        rows.append(Row(f"fig10_K{K}_{scheme}", avg * 1e6,
                        f"exec_time_ms={avg*1e3:.2f};feasible={n_feas}/{len(rs)}"))
    return rows


def run_v_sweep(quick: bool = False, ilp_time_limit: float = 120.0) -> list[Row]:
    specs = random_scaling(quick=quick, ilp_time_limit_s=ilp_time_limit)
    results = _cold_runner().run(specs)
    rows: list[Row] = []
    for r in results:
        V = r.spec.topology_kwargs["n_nodes"]
        status = "ok" if r.feasible else "timeout/infeasible"
        rows.append(Row(f"fig11_V{V}_{r.spec.solver}", r.wall_time_s * 1e6,
                        f"exec_time_ms={r.wall_time_s*1e3:.2f};{status}"))
    return rows


def run(quick: bool = False) -> list[Row]:
    return run_k_sweep(quick) + run_v_sweep(quick)
