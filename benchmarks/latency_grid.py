"""Figs. 4 & 5: inference / training latency per batch vs (K, b) for every scheme.

Averaged over seeds (paper: 10 trials).  The optimal scheme is the ILP-equivalent
exact DP; `bcd`, `comp-ms`, `comm-ms` as in the paper.  The grid is the
``nsfnet_paper`` suite of the sweep engine, executed through ``SweepRunner`` so
compute/fit tables and Dijkstra frontiers are shared across the whole grid.
"""
from __future__ import annotations

from repro.core import IF
from repro.sweep import SweepRunner
from repro.sweep.suites import nsfnet_paper

from .common import Row, group_in_order


def run(mode: str = IF, seeds: int = 10, quick: bool = False,
        workers: int = 0) -> list[Row]:
    specs = nsfnet_paper(quick=quick, modes=(mode,), seeds=seeds)
    results = SweepRunner(workers=workers).run(specs)

    # aggregate seeds per (figure, K, b, scheme) cell, in suite order
    cells = group_in_order(
        results, lambda r: (r.spec.tags["figure"], r.spec.K,
                            r.spec.batch_size, r.spec.solver))

    rows: list[Row] = []
    for (fig, K, b, scheme), rs in cells.items():
        feas = [r for r in rs if r.feasible]
        name = f"{fig}_{mode}_K{K}_b{b}_{scheme}"
        if not feas:
            rows.append(Row(name, float("nan"), "infeasible"))
            continue
        n = len(feas)
        tot = sum(r.latency_s for r in feas) / n
        comp = sum(r.computation_s for r in feas) / n
        trans = sum(r.transmission_s for r in feas) / n
        prop = sum(r.propagation_s for r in feas) / n
        rows.append(Row(
            name, tot * 1e6,
            f"latency_ms={tot * 1e3:.2f};comp_ms={comp * 1e3:.2f};"
            f"trans_ms={trans * 1e3:.2f};prop_ms={prop * 1e3:.2f};"
            f"feasible={n}/{len(rs)}",
        ))
    return rows
