"""Figs. 4 & 5: inference / training latency per batch vs (K, b) for every scheme.

Averaged over seeds (paper: 10 trials).  The optimal scheme is the ILP-equivalent
exact DP; `bcd`, `comp-ms`, `comm-ms` as in the paper.
"""
from __future__ import annotations

from repro.core import IF, TR, ServiceChainRequest

from .common import DEST, SOURCE, Row, candidate_sets, paper_instance, solve

K_RANGE = range(2, 8)
B_RANGE = [2**i for i in range(0, 9)]  # 1..256
SCHEMES = ["exact", "bcd", "comp-ms", "comm-ms"]


def run(mode: str = IF, seeds: int = 10, quick: bool = False) -> list[Row]:
    net, prof = paper_instance()
    ks = [2, 3, 5] if quick else list(K_RANGE)
    bs = [2, 128] if quick else B_RANGE
    n_seeds = 3 if quick else seeds
    rows: list[Row] = []
    fig = "fig4" if mode == IF else "fig5"
    for K in ks:
        for b in bs:
            req = ServiceChainRequest("resnet101", SOURCE, DEST, b, mode)
            for scheme in SCHEMES:
                tot, n_feas, comp, trans, prop = 0.0, 0, 0.0, 0.0, 0.0
                for seed in range(n_seeds):
                    cands = candidate_sets(K, seed)
                    res = solve(scheme, net, prof, req, K, cands)
                    if res.feasible:
                        n_feas += 1
                        tot += res.latency_s
                        comp += res.latency.computation_s
                        trans += res.latency.transmission_s
                        prop += res.latency.propagation_s
                if n_feas == 0:
                    rows.append(Row(f"{fig}_{mode}_K{K}_b{b}_{scheme}", float("nan"),
                                    "infeasible"))
                    continue
                rows.append(Row(
                    f"{fig}_{mode}_K{K}_b{b}_{scheme}",
                    tot / n_feas * 1e6,
                    f"latency_ms={tot / n_feas * 1e3:.2f};comp_ms={comp / n_feas * 1e3:.2f};"
                    f"trans_ms={trans / n_feas * 1e3:.2f};prop_ms={prop / n_feas * 1e3:.2f};"
                    f"feasible={n_feas}/{n_seeds}",
                ))
    return rows
