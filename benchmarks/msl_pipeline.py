"""MSL-PP benchmark (§Perf hillclimb #3): the paper's planner driving pipeline
parallelism on the production mesh, vs the dp-tp baseline.

For each featured arch it (1) runs the BCD planner on the pod-level topology to
pick K and the per-stage group segments, (2) lowers + compiles the pipelined
train step on a ('stage','data') mesh carved from the 512 fake devices, and
(3) reports the roofline terms next to the dp-tp dry-run cell.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import Row

ART = Path(__file__).resolve().parents[1] / "artifacts"
SRC = str(Path(__file__).resolve().parents[1] / "src")

FEATURED = ["qwen3-14b", "gemma2-27b"]


def _run_pp_cell(arch: str, timeout: float = 2400.0) -> dict:
    out = ART / "msl_pp" / f"{arch}__train_4k.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists():
        return json.loads(out.read_text())
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun_pp", arch, str(out)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        return {"status": "error", "stderr": proc.stderr[-2000:]}
    return json.loads(out.read_text())


def run(quick: bool = False) -> list[Row]:
    rows: list[Row] = []
    dp = {}
    for f in (ART / "dryrun").glob("*train_4k__multi.json"):
        j = json.loads(f.read_text())
        dp[j["arch"]] = j
    for arch in (FEATURED[:1] if quick else FEATURED):
        j = _run_pp_cell(arch)
        name = f"msl_pp_{arch}_train_4k"
        if j.get("status") != "ok":
            rows.append(Row(name, float("nan"),
                            f"error:{j.get('stderr', '')[:120]}"))
            continue
        r = j["roofline"]
        t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        derived = (
            f"plan_K={j['plan']['K']};segments={j['plan']['segments']};"
            f"predicted_ms={j['plan']['predicted_latency_s']*1e3:.1f};"
            f"tc={r['t_compute']:.3f}s;tm={r['t_memory']:.3f}s;"
            f"tx={r['t_collective']:.3f}s;mem={j['memory']['per_device_bytes']/2**30:.1f}GB"
        ).replace(",", ";")
        d = dp.get(arch)
        if d and d.get("status") == "ok":
            dt = max(d["roofline"]["t_compute"], d["roofline"]["t_memory"],
                     d["roofline"]["t_collective"])
            derived += f";dp_tp_tdom={dt:.3f}s;mem_dp={d['memory']['per_device_bytes']/2**30:.1f}GB"
        rows.append(Row(name, t_dom * 1e6, derived))
    return rows
