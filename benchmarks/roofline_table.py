"""Roofline table (EXPERIMENTS.md §Roofline): reads the dry-run artifacts and
emits one row per (arch x shape x mesh) cell with the three terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and before/after vs the baseline
snapshot when present."""
from __future__ import annotations

import json
from pathlib import Path

from .common import Row

ART = Path(__file__).resolve().parents[1] / "artifacts"


def _load(d: Path) -> dict:
    out = {}
    if not d.exists():
        return out
    for f in d.glob("*.json"):
        j = json.loads(f.read_text())
        out[(j["arch"], j["shape"], j["mesh"])] = j
    return out


def run(quick: bool = False) -> list[Row]:
    cur = _load(ART / "dryrun")
    base = _load(ART / "dryrun_baseline")
    rows: list[Row] = []
    if not cur:
        return [Row("roofline_missing", float("nan"),
                    "run: PYTHONPATH=src python -m repro.launch.dryrun --all")]
    for key in sorted(cur):
        j = cur[key]
        name = f"roofline_{key[0]}_{key[1]}_{key[2]}"
        if j.get("status") == "skipped":
            rows.append(Row(name, 0.0, f"skipped:{j['reason'][:70]}"))
            continue
        if j.get("status") != "ok":
            rows.append(Row(name, float("nan"), "error"))
            continue
        r = j["roofline"]
        m = j["memory"]
        t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        derived = (
            f"tc={r['t_compute']:.3f}s;tm={r['t_memory']:.3f}s;"
            f"tx={r['t_collective']:.3f}s;dominant={r['bottleneck']};"
            f"frac={r['roofline_fraction']:.4f};useful={r['useful_flops_ratio']:.3f};"
            f"mem={m['per_device_bytes']/2**30:.1f}GB;fits={m['fits_16gb']}")
        b = base.get(key)
        if b and b.get("status") == "ok":
            bt = max(b["roofline"]["t_compute"], b["roofline"]["t_memory"],
                     b["roofline"]["t_collective"])
            derived += (f";baseline_tdom={bt:.3f}s;speedup={bt/max(t_dom,1e-12):.2f}x"
                        f";baseline_mem={b['memory']['per_device_bytes']/2**30:.1f}GB")
        rows.append(Row(name, t_dom * 1e6, derived))
    return rows
