"""Benchmark driver — one suite per paper table/figure, plus the roofline table.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--suite NAME ...]

Prints ``name,us_per_call,derived`` CSV rows (and echoes section headers on
stderr so the CSV stays machine-readable).
"""
from __future__ import annotations

import argparse
import sys
import time


def _suites():
    from . import breakdown, exec_time, latency_grid, worked_examples

    def fig4(quick):
        from repro.core import IF

        return latency_grid.run(IF, quick=quick)

    def fig5(quick):
        from repro.core import TR

        return latency_grid.run(TR, quick=quick)

    suites = {
        "fig4_inference_latency": fig4,
        "fig5_training_latency": fig5,
        "fig6_fig7_worked_examples": worked_examples.run,
        "fig8_fig9_breakdown": breakdown.run,
        "fig10_fig11_exec_time": exec_time.run,
    }
    try:
        from . import roofline_table

        suites["roofline"] = roofline_table.run
    except ImportError:
        pass
    try:
        from . import msl_pipeline

        suites["msl_pipeline"] = msl_pipeline.run
    except ImportError:
        pass
    return suites


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids (CI-friendly)")
    ap.add_argument("--suite", nargs="*", default=None)
    args = ap.parse_args()
    suites = _suites()
    names = args.suite or list(suites)
    print("name,us_per_call,derived")
    for name in names:
        if name not in suites:
            print(f"unknown suite {name}; have {list(suites)}", file=sys.stderr)
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", file=sys.stderr)
        for row in suites[name](quick=args.quick):
            print(row.csv())
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
