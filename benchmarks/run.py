"""Benchmark driver — one suite per paper table/figure, plus the roofline table.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--suite NAME ...]
                                                [--workers N]

The paper-figure suites are backed by the scenario-sweep engine
(``repro.sweep``): ``--quick`` selects each suite's reduced CI grid, and
``--workers`` fans the latency grids out over processes.  Prints
``name,us_per_call,derived`` CSV rows (and echoes section headers on stderr so
the CSV stays machine-readable).
"""
from __future__ import annotations

import argparse
import sys
import time


def _sweep_rows(suite_name: str, quick: bool) -> list:
    """Run a repro.sweep suite and flatten its results into benchmark rows."""
    from repro.sweep import SweepRunner
    from repro.sweep.suites import SUITES

    from .common import Row

    results = SweepRunner(workers=0).run(SUITES[suite_name](quick=quick))
    rows = []
    for r in results:
        s = r.spec
        cell = s.tags.get("cell", s.scenario_id())
        derived = ("infeasible" if not r.feasible else
                   f"latency_ms={r.latency_s*1e3:.2f};"
                   f"exec_time_ms={r.wall_time_s*1e3:.2f}")
        if r.acceptance_ratio is not None:
            derived += (f";accept={r.acceptance_ratio:.2f}"
                        f";p95_ms={(r.latency_p95_s or 0.0)*1e3:.2f}")
        rows.append(Row(f"{suite_name}_{cell}_{s.solver}",
                        (r.latency_s or float("nan")) * 1e6, derived))
    return rows


def _suites():
    from . import breakdown, exec_time, latency_grid, worked_examples

    def fig4(quick, workers=0):
        from repro.core import IF

        return latency_grid.run(IF, quick=quick, workers=workers)

    def fig5(quick, workers=0):
        from repro.core import TR

        return latency_grid.run(TR, quick=quick, workers=workers)

    suites = {
        "fig4_inference_latency": fig4,
        "fig5_training_latency": fig5,
        "fig6_fig7_worked_examples": worked_examples.run,
        "fig8_fig9_breakdown": breakdown.run,
        "fig10_fig11_exec_time": exec_time.run,
        "sweep_tpu_pod": lambda quick: _sweep_rows("tpu_pod", quick),
        "sweep_faults": lambda quick: _sweep_rows("nsfnet_faults", quick),
        "serve_multirequest": lambda quick: _sweep_rows("nsfnet_multirequest",
                                                        quick),
        "serve_load_scaling": lambda quick: _sweep_rows("random_load_scaling",
                                                        quick),
    }
    try:
        from . import roofline_table

        suites["roofline"] = roofline_table.run
    except ImportError:
        pass
    try:
        from . import msl_pipeline

        suites["msl_pipeline"] = msl_pipeline.run
    except ImportError:
        pass
    return suites


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids (CI-friendly)")
    ap.add_argument("--suite", nargs="*", default=None)
    ap.add_argument("--workers", type=int, default=0,
                    help="process fan-out for the latency-grid suites")
    args = ap.parse_args()
    suites = _suites()
    names = args.suite or list(suites)
    print("name,us_per_call,derived")
    for name in names:
        if name not in suites:
            print(f"unknown suite {name}; have {list(suites)}", file=sys.stderr)
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", file=sys.stderr)
        kw = {}
        if args.workers and name in ("fig4_inference_latency",
                                     "fig5_training_latency"):
            kw["workers"] = args.workers
        for row in suites[name](quick=args.quick, **kw):
            print(row.csv())
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
