"""Serving-gateway throughput: sustained admissions/second, cold vs warm.

Streams a synthetic Poisson fleet of recurring request shapes through
:class:`ServeGateway` and measures sustained admission throughput — admitted
chains per second of tick wall time — separating *cold* (fresh
:class:`PlanCache` / :class:`EvalCache`, every distinct shape hits the
solver) from *warm* (caches carried over from earlier runs, the steady-state
regime of a long-running gateway where recurring shapes skip the solver and
per-admission work is residual accounting + latency evaluation).

The stream is built so the measurement isolates the control plane:

* capacities scaled x1e6 ("big fabric") — admission never capacity-blocks,
  so throughput measures the admission pipeline, not solver replans;
* few distinct shapes cycled over many requests — the plan-cache regime the
  gateway's Layer 2 exists for (hit rate ~= 1 - n_shapes/n_requests);
* finite holds — departures keep the release/accounting path honest.

A batch-window sweep shows how arrival grouping amortizes per-tick overhead
(window 0 ticks once per distinct arrival; larger windows presolve and admit
in bigger batches).  Tick-latency percentiles come from
:class:`GatewayStats`.

Usage:  PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke]
                                                             [--out PATH]

``--smoke`` runs one small cell (512 requests, 0.5s window) and asserts warm
sustained throughput >= SMOKE_FLOOR_ADM_PER_S admissions/s (exit 1
otherwise) — wired into ``make verify`` via ``bench-serve-smoke``.  The full
grid writes ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time

from repro.core import (
    IF,
    EvalCache,
    LinkSpec,
    NodeSpec,
    PhysicalNetwork,
    nsfnet,
    resnet101_profile,
)
from repro.serve import GatewayConfig, PlanCache, ServeGateway, ServeRequest
from repro.sweep.spec import candidate_sets

from .common import DEST, NSFNET_NODES, SOURCE

# Warm admissions/s floor for the --smoke gate: measured ~1.5e4/s for the
# smoke cell on the reference 1-core container, gated at 1e4/s.
SMOKE_FLOOR_ADM_PER_S = 1e4

_N_SHAPES = 8
_HOLD_S = 2.0
_RATE_RPS = 0.1
_CAP_SCALE = 1e6
_WARM_REPS = 5

FULL_N = 2048
FULL_SPAN_S = 64.0
FULL_WINDOWS = [0.0, 0.25, 0.5, 1.0, 2.0]
SMOKE_N = 512
SMOKE_SPAN_S = 16.0
SMOKE_WINDOWS = [0.5]


def big_fabric() -> PhysicalNetwork:
    """NSFNET with every capacity scaled so admission never blocks."""
    base = nsfnet(source=SOURCE)
    net = PhysicalNetwork()
    for name, spec in base.nodes.items():
        net.add_node(NodeSpec(name, spec.compute,
                              spec.mem_capacity * _CAP_SCALE,
                              spec.disk_capacity * _CAP_SCALE))
    for (u, v), spec in base.links.items():
        net.add_link(u, v, LinkSpec(spec.bw_fw * _CAP_SCALE,
                                    spec.bw_bw * _CAP_SCALE,
                                    spec.delay_fw, spec.delay_bw))
    return net


def build_stream(n: int, span_s: float, seed: int = 0) -> list[ServeRequest]:
    """Poisson arrivals over `span_s`, cycling `_N_SHAPES` pinned candidate
    pools (the recurring-shape regime), finite exponential-free fixed holds."""
    shapes = [tuple(tuple(c) for c in
                    candidate_sets(3, s, NSFNET_NODES, SOURCE, DEST))
              for s in range(_N_SHAPES)]
    rng = random.Random(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.expovariate(n / span_s)
        reqs.append(ServeRequest(
            request_id=i, source=SOURCE, destination=DEST, batch_size=1,
            mode=IF, K=3, candidates=shapes[i % _N_SHAPES], arrival_s=t,
            rate_rps=_RATE_RPS, model_id="resnet101", duration_s=_HOLD_S))
    return reqs


def _run_once(net: PhysicalNetwork, profile, reqs: list[ServeRequest],
              window_s: float, plan_cache: PlanCache,
              eval_cache: EvalCache) -> dict:
    """One full stream through a fresh gateway (shared warm caches)."""
    gw = ServeGateway(net, profile,
                      config=GatewayConfig(batch_window_s=window_s),
                      cache=eval_cache, plan_cache=plan_cache)
    t0 = time.perf_counter()
    out = gw.run_stream(reqs)
    wall = time.perf_counter() - t0
    gs = out.gateway_stats
    if out.n_accepted != len(reqs):
        raise AssertionError(
            f"big fabric must admit everything: {out.n_accepted}/{len(reqs)}")
    return {
        "wall_s": wall,
        "adm_per_s": gs["admissions_per_s"],
        "n_ticks": gs["n_ticks"],
        "tick_wall_pct": gs["tick_wall_pct"],
        "plan_cache_hit_rate": gs["plan_cache"]["hit_rate"],
    }


def run_grid(n: int, span_s: float, windows: list[float]) -> dict:
    net = big_fabric()
    profile = resnet101_profile()
    reqs = build_stream(n, span_s)
    rows = []
    for w in windows:
        # fresh caches: the first run is the cold measurement for this cell
        pc, ec = PlanCache(), EvalCache()
        cold = _run_once(net, profile, reqs, w, pc, ec)
        _run_once(net, profile, reqs, w, pc, ec)  # settle before timed reps
        warm_runs = [_run_once(net, profile, reqs, w, pc, ec)
                     for _ in range(_WARM_REPS)]
        best = max(warm_runs, key=lambda r: r["adm_per_s"])
        row = {
            "batch_window_s": w,
            "n_ticks": best["n_ticks"],
            "cold_adm_per_s": cold["adm_per_s"],
            "warm_adm_per_s": best["adm_per_s"],
            "warm_speedup_vs_cold": best["adm_per_s"] / cold["adm_per_s"],
            "warm_tick_wall_pct": best["tick_wall_pct"],
            "plan_cache_hit_rate": best["plan_cache_hit_rate"],
        }
        rows.append(row)
        p50 = (best["tick_wall_pct"]["p50"] or 0.0) * 1e3
        print(f"serve_throughput,window={w},ticks={best['n_ticks']},"
              f"cold_adm_per_s={cold['adm_per_s']:.0f},"
              f"warm_adm_per_s={best['adm_per_s']:.0f},"
              f"tick_p50_ms={p50:.2f},"
              f"pc_hit_rate={best['plan_cache_hit_rate']:.3f}")
        sys.stdout.flush()
    return {
        "benchmark": "serve_throughput",
        "n_requests": n,
        "span_s": span_s,
        "n_shapes": _N_SHAPES,
        "hold_s": _HOLD_S,
        "warm_reps": _WARM_REPS,
        "note": ("admissions/s = admitted chains per second of tick wall "
                 "time on the x1e6-capacity NSFNET (control-plane cost "
                 "only — no capacity blocking).  warm = PlanCache/EvalCache "
                 "carried across runs, the long-running gateway regime; "
                 "cold includes every distinct shape's solve."),
        "results": rows,
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one small cell + warm-throughput gate "
                         "(no JSON artifact)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        report = run_grid(SMOKE_N, SMOKE_SPAN_S, SMOKE_WINDOWS)
        warm = report["results"][0]["warm_adm_per_s"]
        print(f"smoke: warm sustained throughput {warm:.0f} admissions/s "
              f"(floor {SMOKE_FLOOR_ADM_PER_S:.0f})")
        if warm < SMOKE_FLOOR_ADM_PER_S:
            print("FAIL: warm gateway throughput below the floor",
                  file=sys.stderr)
            return 1
        return 0

    report = run_grid(FULL_N, FULL_SPAN_S, FULL_WINDOWS)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")

    best = max(r["warm_adm_per_s"] for r in report["results"])
    print(f"gate: best warm throughput {best:.0f} admissions/s "
          f"(target >= {SMOKE_FLOOR_ADM_PER_S:.0f})")
    return 0 if best >= SMOKE_FLOOR_ADM_PER_S else 1


if __name__ == "__main__":
    sys.exit(main())
