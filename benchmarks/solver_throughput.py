"""Solver-core throughput: scalar NumPy loop vs batched JAX vs Pallas kernel.

Measures instances/second for the one-shot DFTS solver across batch sizes,
separating *cold* (first call, includes trace/compile and cache build) from
*warm* (steady state — the serve-planner regime, where admission waves re-solve
recurring instance populations every tick and the jitted scans plus derived
caches are already hot).  Both engines warm up the same way: the NumPy loop
keeps its persistent ``EvalCache`` across calls, the JAX path keeps its jit
traces and encode/decode memos.  The DP scan itself always re-runs on every
warm call for every instance — only derived artifacts (encodings, path costs,
decode/eval keyed by the scan *output*) are memoized, so warm numbers measure
real solve work, not result lookup.

Engines:

* ``numpy``  — per-instance ``solve(p, "dfts_np", cache=...)`` loop (the
  scalar oracle twin).
* ``jax``    — one ``solve_batch(batch, "dfts_jax", dedup=False)`` call per
  batch (vmap'd lax.scan DP; ``dedup=False`` so every instance is solved).
* ``pallas`` — same, ``use_pallas=True``.  On CPU the kernel runs in
  interpret mode, which is a correctness path, not a performance path; its
  numbers are reported for completeness but never gated on.

Usage:  PYTHONPATH=src python -m benchmarks.solver_throughput [--smoke]
                                                              [--out PATH]

``--smoke`` runs a single batch=8 cell and asserts warm batched-JAX beats the
NumPy loop (exit 1 otherwise) — wired into ``make verify`` via
``bench-solver-smoke``.  The full grid writes ``BENCH_solver.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (
    IF,
    PIPE,
    TR,
    EvalCache,
    ProblemInstance,
    ServiceChainRequest,
    nsfnet,
    resnet101_profile,
    solve,
    solve_batch,
)
from repro.sweep.spec import candidate_sets

from .common import DEST, NSFNET_NODES, SOURCE

# Instance population: both heavy candidate configurations from the paper
# sweep, inference and training, two batch sizes, distinct candidate seeds.
# 64 distinct fused instances plus 32 round-trip TR-pipe instances (appended
# last, so small-batch cells — including the smoke gate — keep the original
# fused-only mix), cycled to fill larger batches (recurring instances are
# exactly the serve-planner admission regime).
_CONFIGS = [(3, 6), (5, 4)]
_MODES = [IF, TR]
_BATCHES = [8, 128]
_SEEDS = range(1, 9)
_TR_PIPE_M = 4  # pipeline depth of the round-trip training instances

FULL_BATCH_SIZES = [1, 8, 64, 256, 1024]
SMOKE_BATCH_SIZES = [8]
_WARM_REPS = 7


def build_instances() -> list[ProblemInstance]:
    net = nsfnet(source=SOURCE)
    profile = resnet101_profile()
    instances = []
    for K, per_stage in _CONFIGS:
        for mode in _MODES:
            for b in _BATCHES:
                for seed in _SEEDS:
                    cands = candidate_sets(K, seed, NSFNET_NODES, SOURCE,
                                           DEST, per_stage=per_stage)
                    req = ServiceChainRequest(model_id=profile.model_id,
                                              source=SOURCE,
                                              destination=DEST,
                                              batch_size=b, mode=mode)
                    instances.append(ProblemInstance(
                        net, profile, req, K,
                        tuple(tuple(c) for c in cands)))
    # Round-trip training pipelines (docs/training.md): TR + pipe instances
    # exercising the two-bottleneck (tau_fw, tau_bw) pair scan.  Appended
    # after the fused population so cells with batch <= 64 are unchanged.
    for K, per_stage in _CONFIGS:
        for b in _BATCHES:
            for seed in _SEEDS:
                cands = candidate_sets(K, seed, NSFNET_NODES, SOURCE,
                                       DEST, per_stage=per_stage)
                req = ServiceChainRequest(model_id=profile.model_id,
                                          source=SOURCE, destination=DEST,
                                          batch_size=b, mode=TR,
                                          schedule=PIPE,
                                          n_microbatches=_TR_PIPE_M)
                instances.append(ProblemInstance(
                    net, profile, req, K,
                    tuple(tuple(c) for c in cands)))
    return instances


def _cycle(instances: list[ProblemInstance], n: int) -> list[ProblemInstance]:
    return [instances[i % len(instances)] for i in range(n)]


def _numpy_loop(batch: list[ProblemInstance], cache: EvalCache) -> None:
    for p in batch:
        solve(p, "dfts_np", cache=cache)


def _time_engine(engine: str, batch: list[ProblemInstance],
                 cache: EvalCache) -> tuple[float, float]:
    """Return (cold_s, warm_s) wall time for one full pass over `batch`."""
    if engine == "numpy":
        def run():
            _numpy_loop(batch, cache)
    else:
        kw = {"use_pallas": True} if engine == "pallas" else {}

        def run():
            # min_batch=1 pins the batched kernel even at batch=1 — this
            # benchmark *measures* the dispatch crossover the engine's
            # default threshold (SOLVE_BATCH_MIN_BATCH) is derived from,
            # so it must never be rerouted by it.
            solve_batch(batch, "dfts_jax", cache=cache, dedup=False,
                        min_batch=1, **kw)

    t0 = time.perf_counter()
    run()
    cold = time.perf_counter() - t0
    run()  # settle into steady state before the timed reps

    # min over reps, timeit-style: the noise floor is the measurement; both
    # engines get the same estimator.
    warm_times = []
    for _ in range(_WARM_REPS):
        t0 = time.perf_counter()
        run()
        warm_times.append(time.perf_counter() - t0)
    return cold, min(warm_times)


def run_grid(batch_sizes: list[int], engines: list[str]) -> dict:
    instances = build_instances()
    rows = []
    for n in batch_sizes:
        batch = _cycle(instances, n)
        cell: dict = {"batch_size": n, "engines": {}}
        # interpret-mode Pallas is O(ms)/instance on CPU; cap its grid so the
        # full run stays in CI territory (its trend is flat in batch anyway).
        cell_engines = [e for e in engines if e != "pallas" or n <= 64]
        for engine in cell_engines:
            # Fresh per-engine cache: engines must not warm each other.
            cold, warm = _time_engine(engine, batch, EvalCache())
            cell["engines"][engine] = {
                "cold_s": cold,
                "warm_s": warm,
                "cold_inst_per_s": n / cold,
                "warm_inst_per_s": n / warm,
                "warm_us_per_inst": warm / n * 1e6,
            }
        np_warm = cell["engines"].get("numpy", {}).get("warm_s")
        for engine in cell_engines:
            e = cell["engines"][engine]
            e["warm_speedup_vs_numpy"] = (
                np_warm / e["warm_s"] if np_warm else None)
        rows.append(cell)
        for engine in cell_engines:
            e = cell["engines"][engine]
            sp = e["warm_speedup_vs_numpy"]
            print(f"solver_throughput,batch={n},engine={engine},"
                  f"warm_us_per_inst={e['warm_us_per_inst']:.1f},"
                  f"warm_inst_per_s={e['warm_inst_per_s']:.0f},"
                  f"speedup_vs_numpy={sp:.2f}" if sp else
                  f"solver_throughput,batch={n},engine={engine},"
                  f"warm_us_per_inst={e['warm_us_per_inst']:.1f}")
            sys.stdout.flush()
    return {
        "benchmark": "solver_throughput",
        "solver": "dfts",
        "n_distinct_instances": len(instances),
        "n_tr_pipe_instances": sum(
            1 for p in instances if p.request.mode == TR
            and p.request.schedule == PIPE),
        "warm_reps": _WARM_REPS,
        "note": ("warm = steady-state re-solve of a recurring instance "
                 "population (serve-admission regime); the DP scan runs on "
                 "every call — only derived encode/decode artifacts are "
                 "cached.  pallas on CPU is interpret-mode (correctness "
                 "path, expected slow).  TR-pipe instances price the "
                 "round-trip two-bottleneck model (docs/training.md)."),
        "results": rows,
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="batch=8 numpy-vs-jax gate only (no JSON artifact)")
    ap.add_argument("--out", default="BENCH_solver.json")
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the interpret-mode Pallas engine (slow on CPU)")
    args = ap.parse_args(argv)

    if args.smoke:
        report = run_grid(SMOKE_BATCH_SIZES, ["numpy", "jax"])
        cell = report["results"][0]["engines"]
        speedup = cell["jax"]["warm_speedup_vs_numpy"]
        print(f"smoke: warm jax speedup vs numpy at batch=8: {speedup:.2f}x")
        if speedup < 1.0:
            print("FAIL: warm batched JAX slower than the scalar NumPy loop",
                  file=sys.stderr)
            return 1
        return 0

    engines = ["numpy", "jax"] + ([] if args.no_pallas else ["pallas"])
    report = run_grid(FULL_BATCH_SIZES, engines)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")

    gate = [c for c in report["results"] if c["batch_size"] >= 256]
    best = max(c["engines"]["jax"]["warm_speedup_vs_numpy"] for c in gate)
    print(f"gate: best warm jax speedup at batch>=256: {best:.2f}x "
          f"(target >= 10x)")
    return 0 if best >= 10.0 else 1


if __name__ == "__main__":
    sys.exit(main())
