"""Figs. 6 & 7: optimal service path + model splitting worked examples.

MSI (K=3, b=2) and MSL (K=3, b=128) with V^2 = {v7, v11} (the paper draws v7 as
the chosen intermediate; its random second candidate is not printed, we pin
{v7, v11}).  Prints the full plan of each scheme for side-by-side comparison with
the paper's figures.
"""
from __future__ import annotations

from repro.core import IF, TR, PlanEvaluator, ServiceChainRequest

from .common import DEST, SOURCE, Row, paper_instance, solve

SCHEMES = ["ilp", "bcd", "comp-ms", "comm-ms"]


def _describe(res, ev) -> str:
    if not res.feasible:
        return "infeasible"
    p = res.plan
    segs = ";".join(f"F{k+1}=[{lo}-{hi}]@{n}"
                    for k, ((lo, hi), n) in enumerate(zip(p.segments, p.placement)))
    paths = ";".join("->".join(path) for path in p.paths)
    lb = res.latency
    return (f"{segs};paths={paths};comp_ms={lb.computation_s*1e3:.2f};"
            f"trans_ms={lb.transmission_s*1e3:.2f};prop_ms={lb.propagation_s*1e3:.2f}")


def run(quick: bool = False) -> list[Row]:
    net, prof = paper_instance()
    cands = [[SOURCE], ["v7", "v11"], [DEST]]
    rows: list[Row] = []
    for mode, b, fig in [(IF, 2, "fig6"), (TR, 128, "fig7")]:
        req = ServiceChainRequest("resnet101", SOURCE, DEST, b, mode)
        ev = PlanEvaluator(net, prof, req)
        for scheme in SCHEMES:
            res = solve(scheme, net, prof, req, 3, cands)
            rows.append(Row(f"{fig}_{mode}_b{b}_{scheme}",
                            res.latency_s * 1e6 if res.feasible else float("nan"),
                            _describe(res, ev)))
    return rows
