"""Fault tolerance demo: train with a planner-chosen chain, kill a node
mid-run, re-plan with BCD (milliseconds), restore the checkpoint, continue —
plus straggler-driven re-calibration (the paper's OLS kappa fit, Sec. VI-A2).

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import ARCHS
from repro.core import TR, ServiceChainRequest, tpu_pod_topology
from repro.data import BatchSpec, SyntheticLM
from repro.ft import ElasticPlanController
from repro.models import transformer as T
from repro.msl import group_profile, make_pipeline_mesh, make_pipeline_train_step
from repro.msl.planner import PipelinePlan
from repro.optim import make_optimizer


def to_pipeline_plan(ctl: ElasticPlanController, n_groups: int) -> PipelinePlan:
    p = ctl.plan
    return PipelinePlan(K=p.K, segments=p.segments, placement=p.placement,
                        n_groups=n_groups, predicted_latency_s=ctl.result.latency_s,
                        breakdown={})


def main() -> None:
    arch = "qwen3-14b"
    cfg = ARCHS[arch].reduced()
    R = cfg.n_layers // len(cfg.pattern)

    # planner state over the pod-level topology (full-config profile)
    net = tpu_pod_topology(n_groups=6, chips_per_group=32)
    nodes = sorted(net.nodes)
    prof = group_profile(ARCHS[arch], seq_len=4096, mode="train")
    req = ServiceChainRequest(arch, nodes[0], nodes[-1], 8, TR)
    cands = [[nodes[0]], nodes[1:3], [nodes[-1]]]
    ctl = ElasticPlanController(net, prof, req, K=3, candidates=cands)
    print(f"[plan] K=3 placement={ctl.plan.placement} "
          f"segments={ctl.plan.segments} "
          f"predicted={ctl.result.latency_s*1e3:.1f} ms")

    # the reduced model trains on the 2-stage CPU mesh with an equal split
    mesh = make_pipeline_mesh(2, 2)
    plan = PipelinePlan(K=2, segments=[(1, R // 2), (R // 2 + 1, R)],
                        placement=ctl.plan.placement[:2], n_groups=R,
                        predicted_latency_s=0.0, breakdown={})
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg.optimizer, lr=1e-3, warmup=2, total=24)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_pipeline_train_step(cfg, mesh, plan, 2, opt))
    stream = SyntheticLM(BatchSpec(8, 32, cfg.vocab_size), seed=0)
    ckpt = CheckpointManager("/tmp/repro_ft_ckpt", keep=2)

    step = 0
    TOTAL = 12
    while step < TOTAL:
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 3 == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
            print(f"step {step:3d} loss={float(m['loss']):.4f} (ckpt)")
        step += 1
        if step == 7:
            victim = ctl.plan.placement[1]
            print(f"\n!!! node {victim} fails at step {step}")
            new_plan = ctl.fail_node(victim, step=step)
            print(f"[replan] placement={new_plan.placement} "
                  f"segments={new_plan.segments}")
            restored_step, state = ckpt.restore()
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            opt_state["step"] = jnp.asarray(opt_state["step"]).reshape(())
            step = restored_step + 1
            print(f"[restore] resumed from step {restored_step}\n")

    print("\nevent log:")
    for e in ctl.events:
        print(f"  step {e.step:3d} {e.kind:10s} {e.detail}")
    print("FT DEMO OK")


if __name__ == "__main__":
    main()
