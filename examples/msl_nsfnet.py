"""The paper's own scenario end-to-end: ResNet101 over NSFNET.

Solves model splitting + placement + chaining with all four schemes (exact
ILP-equivalent DP, BCD, COMP-MS, COMM-MS) plus the ``portfolio`` meta-solver
(best-of-heuristics on one shared cache) for MSI (K=3, b=2) and MSL (K=3,
b=128) and prints Fig. 6/7-style service paths.  Scenarios are declared as
``repro.sweep`` specs and executed through the engine — the same path the
benchmark grids and the ``python -m repro.sweep`` CLI use.

  PYTHONPATH=src python examples/msl_nsfnet.py
"""
from repro.core import IF, TR, PlanEvaluator
from repro.sweep import ScenarioSpec, SweepRunner

SCHEMES = ["exact", "bcd", "comp-ms", "comm-ms", "portfolio"]
CANDIDATES = [["v4"], ["v7", "v11"], ["v13"]]


def show(result, ev) -> None:
    if not result.feasible:
        print("   infeasible")
        return
    p = result.plan()
    for k, ((lo, hi), node) in enumerate(zip(p.segments, p.placement)):
        print(f"   F{k+1} = layers {lo}-{hi} @ {node} "
              f"(comp {ev.segment_comp_s(node, lo, hi)*1e3:.1f} ms)")
    for k, path in enumerate(p.paths):
        trans, prop = ev.cut_transfer_s(path, p.segments[k][1])
        print(f"   S{k+2}: {'->'.join(path)} (trans {trans*1e3:.1f} ms, "
              f"prop {prop*1e3:.1f} ms)")
    winner = (result.solver_stats or {}).get("winner")
    print(f"   total {result.latency_s*1e3:.1f} ms [{result.status}]"
          f"{f' (winner: {winner})' if winner else ''}  "
          f"(comp {result.computation_s*1e3:.1f} "
          f"/ trans {result.transmission_s*1e3:.1f} "
          f"/ prop {result.propagation_s*1e3:.1f})"
          f"  solved in {result.wall_time_s*1e3:.1f} ms")


def main() -> None:
    runner = SweepRunner(workers=0)
    for mode, b, title in [(IF, 2, "MSI (inference), K=3, b=2"),
                           (TR, 128, "MSL (training), K=3, b=128")]:
        print(f"\n=== {title} ===")
        specs = [
            ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                         profile="resnet101", source="v4", destination="v13",
                         batch_size=b, mode=mode, K=3, solver=scheme,
                         candidates=CANDIDATES,
                         tags={"suite": "msl_nsfnet_example"})
            for scheme in SCHEMES
        ]
        results = runner.run(specs)
        spec0 = specs[0]
        ev = PlanEvaluator(spec0.build_network(), spec0.build_profile(),
                           spec0.request())
        for scheme, result in zip(SCHEMES, results):
            name = "optimal" if scheme == "exact" else scheme
            print(f" {name}:")
            show(result, ev)


if __name__ == "__main__":
    main()
