"""The paper's own scenario end-to-end: ResNet101 over NSFNET.

Solves model splitting + placement + chaining with all four schemes (exact
ILP-equivalent DP, BCD, COMP-MS, COMM-MS) for MSI (K=3, b=2) and MSL (K=3,
b=128) and prints Fig. 6/7-style service paths.

  PYTHONPATH=src python examples/msl_nsfnet.py
"""
from repro.core import (
    IF,
    TR,
    PlanEvaluator,
    ServiceChainRequest,
    bcd_solve,
    comm_ms_solve,
    comp_ms_solve,
    exact_solve,
    nsfnet,
    resnet101_profile,
)

SCHEMES = [("optimal", exact_solve), ("bcd", bcd_solve),
           ("comp-ms", comp_ms_solve), ("comm-ms", comm_ms_solve)]


def show(res, ev) -> None:
    if not res.feasible:
        print("   infeasible")
        return
    p = res.plan
    for k, ((lo, hi), node) in enumerate(zip(p.segments, p.placement)):
        print(f"   F{k+1} = layers {lo}-{hi} @ {node} "
              f"(comp {ev.segment_comp_s(node, lo, hi)*1e3:.1f} ms)")
    for k, path in enumerate(p.paths):
        trans, prop = ev.cut_transfer_s(path, p.segments[k][1])
        print(f"   S{k+2}: {'->'.join(path)} (trans {trans*1e3:.1f} ms, "
              f"prop {prop*1e3:.1f} ms)")
    lb = res.latency
    print(f"   total {lb.total_s*1e3:.1f} ms  (comp {lb.computation_s*1e3:.1f} "
          f"/ trans {lb.transmission_s*1e3:.1f} / prop {lb.propagation_s*1e3:.1f})"
          f"  solved in {res.wall_time_s*1e3:.1f} ms")


def main() -> None:
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    for mode, b, title in [(IF, 2, "MSI (inference), K=3, b=2"),
                           (TR, 128, "MSL (training), K=3, b=128")]:
        print(f"\n=== {title} ===")
        req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
        ev = PlanEvaluator(net, prof, req)
        for name, solver in SCHEMES:
            print(f" {name}:")
            show(solver(net, prof, req, 3, cands), ev)


if __name__ == "__main__":
    main()
