"""Quickstart: plan a service chain with the paper's BCD optimizer, then train
a small LM through the MSL pipeline runtime it planned — with checkpointing.

Runs on CPU with 4 emulated devices (mesh ('stage','data') = (2,2)).

  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-14b] [--steps 30]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import ARCHS
from repro.data import BatchSpec, SyntheticLM
from repro.models import transformer as T
from repro.msl import make_pipeline_mesh, make_pipeline_train_step, plan_pipeline
from repro.msl.planner import PipelinePlan
from repro.optim import make_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    R = cfg.n_layers // len(cfg.pattern)

    # 1) the paper's planner chooses K and the layer-group segments for the
    #    FULL config on the pod-level topology...
    plan_full = plan_pipeline(ARCHS[args.arch], seq_len=4096, microbatch=8,
                              candidate_K=(2, 4, 8))
    print(f"[plan] {args.arch}: K={plan_full.K} segments={plan_full.segments} "
          f"predicted={plan_full.predicted_latency_s*1e3:.1f} ms/step "
          f"breakdown={plan_full.breakdown}")

    # 2) ...and we train the reduced config with the same machinery (K=2 on
    #    the 2-stage CPU mesh), microbatched, grads through ppermute.
    plan = PipelinePlan(K=2, segments=[(1, R // 2), (R // 2 + 1, R)],
                        placement=["p0g0", "p0g1"], n_groups=R,
                        predicted_latency_s=0.0, breakdown={})
    mesh = make_pipeline_mesh(2, 2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg.optimizer, lr=1e-3, warmup=5, total=args.steps)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_pipeline_train_step(cfg, mesh, plan, n_micro=2, opt=opt))

    spec = BatchSpec(global_batch=8, seq_len=32, vocab=cfg.vocab_size)
    stream = SyntheticLM(spec, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(step+1)*1e3:.0f} ms/step)")
        if step and step % 10 == 0:
            ckpt.save(step, {"params": params, "opt": opt_state},
                      blocking=False)
    ckpt.wait()
    print(f"done; checkpoints at {args.ckpt_dir}, latest step "
          f"{ckpt.latest_step()}")


if __name__ == "__main__":
    main()
