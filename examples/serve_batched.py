"""Batched serving demo: prefill + decode with KV/SSM caches on a reduced
model, host-side request batching via ServingEngine.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen2-1.5b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    if cfg.memory_len:
        raise SystemExit("this demo targets text-only archs; "
                         "use one of the [dense]/[moe]/[ssm] configs")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, batch_size=4, cache_len=96)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=rng.integers(4, 24))
               .astype(np.int32) for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt_len={len(prompts[i])} -> {len(o)} tokens: "
              f"{o[:10]}{'...' if len(o) > 10 else ''}")
    print(f"\n{args.requests} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on 1 CPU core, reduced config)")


if __name__ == "__main__":
    main()
