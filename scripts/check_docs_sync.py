#!/usr/bin/env python
"""Docs-sync smoke check (CI) — thin alias over the ``docs-sync`` rule in
``repro.analysis.rules_docs`` (same REQUIRED_DOCUMENTED semantics, same
failure messages).  Kept so existing workflows (`make docs-check`, the CI
docs job) don't break; the full linter is ``python -m repro.analysis``."""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT / "src") not in sys.path:  # standalone runs without PYTHONPATH=src
    sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.rules_docs import docs_sync_errors  # noqa: E402


def main() -> int:
    errors, n_reachable = docs_sync_errors(ROOT)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"docs-sync ok: {n_reachable} docs reachable from README.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
