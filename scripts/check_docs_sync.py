#!/usr/bin/env python
"""Docs-sync smoke check (CI): every docs/*.md file referenced from README.md
and from other docs exists, and every docs/*.md on disk is reachable from
README.md (no orphaned documentation).  Exits non-zero with a report on
drift."""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\(((?:docs/)?[\w.-]+\.md)(?:#[\w-]+)?\)")


def doc_links(path: Path) -> set[Path]:
    """docs/*.md paths referenced by markdown links in `path` (repo-relative)."""
    out = set()
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith("docs/"):
            out.add(ROOT / target)
        elif path.parent == ROOT / "docs":
            out.add(ROOT / "docs" / target)
    return out


def main() -> int:
    errors: list[str] = []
    readme = ROOT / "README.md"
    reachable = doc_links(readme)
    for doc in sorted((ROOT / "docs").glob("*.md")):
        reachable |= doc_links(doc)

    for ref in sorted(reachable):
        if not ref.exists():
            errors.append(f"broken doc link: {ref.relative_to(ROOT)}")

    readme_reachable = doc_links(readme)
    frontier = list(readme_reachable)
    while frontier:  # transitive closure from README
        doc = frontier.pop()
        if not doc.exists():
            continue
        for ref in doc_links(doc):
            if ref not in readme_reachable:
                readme_reachable.add(ref)
                frontier.append(ref)
    for doc in sorted((ROOT / "docs").glob("*.md")):
        if doc not in readme_reachable:
            errors.append(f"orphaned doc (not reachable from README.md): "
                          f"{doc.relative_to(ROOT)}")

    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"docs-sync ok: {len(readme_reachable)} docs reachable from README.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
