#!/usr/bin/env python
"""Docs-sync smoke check (CI): every docs/*.md file referenced from README.md
and from other docs exists, and every docs/*.md on disk is reachable from
README.md (no orphaned documentation).  Exits non-zero with a report on
drift."""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\(((?:docs/)?[\w.-]+\.md)(?:#[\w-]+)?\)")
SRC_RE = re.compile(r"`(src/repro/[\w/.]+\.py)`")

# Modules the docs must both mention and that must exist on disk — the
# subsystem map in docs/architecture.md and the solver guide go stale
# silently otherwise.
REQUIRED_DOCUMENTED = (
    "src/repro/core/jax_solvers.py",
    "src/repro/kernels/minplus.py",
    "src/repro/serve/gateway.py",
    "src/repro/serve/failures.py",
    "src/repro/core/trainpipe.py",
)


def doc_links(path: Path) -> set[Path]:
    """docs/*.md paths referenced by markdown links in `path` (repo-relative)."""
    out = set()
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith("docs/"):
            out.add(ROOT / target)
        elif path.parent == ROOT / "docs":
            out.add(ROOT / "docs" / target)
    return out


def main() -> int:
    errors: list[str] = []
    readme = ROOT / "README.md"
    reachable = doc_links(readme)
    for doc in sorted((ROOT / "docs").glob("*.md")):
        reachable |= doc_links(doc)

    for ref in sorted(reachable):
        if not ref.exists():
            errors.append(f"broken doc link: {ref.relative_to(ROOT)}")

    readme_reachable = doc_links(readme)
    frontier = list(readme_reachable)
    while frontier:  # transitive closure from README
        doc = frontier.pop()
        if not doc.exists():
            continue
        for ref in doc_links(doc):
            if ref not in readme_reachable:
                readme_reachable.add(ref)
                frontier.append(ref)
    for doc in sorted((ROOT / "docs").glob("*.md")):
        if doc not in readme_reachable:
            errors.append(f"orphaned doc (not reachable from README.md): "
                          f"{doc.relative_to(ROOT)}")

    # source modules referenced by full path in docs must exist on disk ...
    all_docs = [readme] + sorted((ROOT / "docs").glob("*.md"))
    docs_text = "\n".join(d.read_text() for d in all_docs)
    for mod in sorted(set(SRC_RE.findall(docs_text))):
        if not (ROOT / mod).exists():
            errors.append(f"doc references missing source module: {mod}")
    # ... and the mapped subsystems must stay documented (by basename)
    for mod in REQUIRED_DOCUMENTED:
        path = ROOT / mod
        if not path.exists():
            errors.append(f"required module missing from tree: {mod}")
        if path.name not in docs_text:
            errors.append(f"module {mod} is not mentioned anywhere in "
                          f"README.md or docs/ (update docs/architecture.md "
                          f"and docs/solvers.md)")

    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"docs-sync ok: {len(readme_reachable)} docs reachable from README.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
