"""repro-lint — AST-based static enforcement of this repo's correctness
conventions (docs/analysis.md).

The headline guarantees elsewhere in the tree — NumPy/JAX bit-parity,
conservation-exact residual accounting, content-hash plan caching — all rest
on conventions (arity-disjoint cache-key families, seeded RNG on solver
paths, registry capability declarations matching solver bodies, every
``ScenarioSpec`` knob hash-relevant).  This package turns those house rules
into machine-checked invariants with rule-named diagnostics:

* :mod:`repro.analysis.base` — ``Rule`` protocol, ``Finding``,
  ``ProjectContext``, the driver (``run_analysis``);
* :mod:`repro.analysis.baseline` — accepted-finding suppression file;
* :mod:`repro.analysis.cli` — ``python -m repro.analysis`` (exit-code
  contract: 0 clean / 1 findings / 2 usage error);
* ``rules_cache`` / ``rules_determinism`` / ``rules_registry`` /
  ``rules_spec`` / ``rules_hygiene`` / ``rules_docs`` — the rule catalog.

Pure stdlib by design: the linter runs in environments without the
scientific stack (the CI docs job, pre-commit hooks).
"""
from .base import (Finding, ModuleInfo, ProjectContext, Rule, get_rules,
                   register_rule, rule_names, run_analysis)
from .baseline import Baseline, load_baseline, save_baseline

__all__ = [
    "Finding", "ModuleInfo", "ProjectContext", "Rule",
    "get_rules", "register_rule", "rule_names", "run_analysis",
    "Baseline", "load_baseline", "save_baseline",
]
