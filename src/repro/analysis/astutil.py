"""Small shared AST helpers for the rule catalog (stdlib ``ast`` only)."""
from __future__ import annotations

import ast
from typing import Iterator


def dotted_name(node: ast.AST) -> str | None:
    """Flatten ``a.b.c`` Attribute/Name chains to ``"a.b.c"`` (None if the
    chain contains anything else — calls, subscripts)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    """Every function/method definition in the module, any nesting depth."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def enclosing_function_map(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    """node -> nearest enclosing function def (module-level nodes absent)."""
    out: dict[ast.AST, ast.AST] = {}

    def visit(node: ast.AST, fn: ast.AST | None) -> None:
        for child in ast.iter_child_nodes(node):
            here = fn
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                here = child
            elif fn is not None:
                out[child] = fn
            visit(child, here)

    visit(tree, None)
    return out


def local_assignment(fn: ast.AST, name: str,
                     before: ast.AST | None = None) -> ast.expr | None:
    """The value last assigned to ``name`` inside function ``fn`` (textually
    before ``before`` when given) — a one-step, same-scope resolution that is
    enough for the ``key = (...)`` / ``use(key)`` idiom the rules check."""
    limit = getattr(before, "lineno", None)
    best: tuple[int, ast.expr] | None = None
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        if limit is not None and node.lineno >= limit:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and tgt.id == name:
                if best is None or node.lineno > best[0]:
                    best = (node.lineno, node.value)
    return best[1] if best else None


def const_str_tuple(node: ast.AST) -> list[str] | None:
    """Elements of a tuple/list display of string constants, else None."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    out = []
    for el in node.elts:
        if isinstance(el, ast.Constant) and isinstance(el.value, str):
            out.append(el.value)
        else:
            return None
    return out


def call_name(node: ast.Call) -> str | None:
    """The called function's terminal name: ``f(...)`` -> "f",
    ``a.b.f(...)`` -> "f"."""
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None
