"""repro-lint core: the Rule protocol, Finding records, and project context.

The framework is deliberately tiny and stdlib-only (``ast`` + ``pathlib``) so
``python -m repro.analysis`` runs anywhere the repo checks out — CI's
docs-sync job installs no scientific stack, and the linter must not drag one
in.

Two rule granularities cover everything in the catalog:

* **per-module** rules implement :meth:`Rule.check_module` and get one parsed
  :class:`ModuleInfo` at a time (most AST rules);
* **project** rules implement :meth:`Rule.check_project` and get the whole
  :class:`ProjectContext` — for cross-file invariants (cache-key families
  must stay arity-disjoint *across* modules, docs-sync reads markdown, the
  solver-registry rule follows calls between solver modules).

A rule may implement both; the driver calls whichever are overridden.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator


@dataclass(frozen=True)
class Finding:
    """One diagnostic: which rule fired, where, what, and how to fix it.

    ``message`` is the finding's stable identity half (with ``rule`` and
    ``path``) for baseline matching — keep line numbers and other drift-prone
    detail out of it so a baseline entry survives unrelated edits to the
    file.  ``suggestion`` is the actionable remediation shown under the
    finding; it never participates in matching.
    """

    rule: str
    path: str  # repo-relative, '/'-separated
    line: int
    message: str
    suggestion: str = ""
    col: int = 0

    def fingerprint(self) -> str:
        """Line-independent identity used by the baseline file."""
        return f"{self.rule}\t{self.path}\t{self.message}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.suggestion:
            out += f"\n    fix: {self.suggestion}"
        return out


@dataclass
class ModuleInfo:
    """One parsed source file: path, text, and its ``ast`` tree."""

    path: Path  # absolute
    relpath: str  # repo-relative, '/'-separated
    source: str
    tree: ast.Module

    @classmethod
    def parse(cls, path: Path, root: Path) -> "ModuleInfo":
        source = path.read_text()
        rel = path.resolve().relative_to(root).as_posix()
        return cls(path, rel, source, ast.parse(source, filename=str(path)))

    def line(self, node: ast.AST) -> int:
        return getattr(node, "lineno", 1)

    def noqa_lines(self) -> set[int]:
        """Line numbers carrying a ``# noqa`` marker (any code)."""
        out = set()
        for i, text in enumerate(self.source.splitlines(), start=1):
            if "# noqa" in text:
                out.add(i)
        return out


@dataclass
class ProjectContext:
    """Everything a cross-file rule can see: the repo root, every parsed
    module under the analyzed paths, and parse failures (reported as findings
    by the driver, so a syntax error can't silently hide a whole file)."""

    root: Path
    modules: list[ModuleInfo] = field(default_factory=list)
    parse_errors: list[Finding] = field(default_factory=list)

    def module(self, relpath: str) -> ModuleInfo | None:
        for m in self.modules:
            if m.relpath == relpath:
                return m
        return None

    def modules_under(self, prefix: str) -> list[ModuleInfo]:
        return [m for m in self.modules if m.relpath.startswith(prefix)]


class Rule:
    """Base class for lint rules.

    Subclasses set ``name`` (the rule id used in reports, ``--select`` and
    the baseline file) and ``description`` (one line for ``--list-rules``),
    then override :meth:`check_module` and/or :meth:`check_project`.
    """

    name: str = ""
    description: str = ""

    def check_module(self, module: ModuleInfo,
                     ctx: ProjectContext) -> Iterator[Finding]:
        return iter(())

    def check_project(self, ctx: ProjectContext) -> Iterator[Finding]:
        return iter(())


# -------------------------------------------------------------- rule registry
_RULES: dict[str, Rule] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator adding a rule to the catalog (mirrors the solver
    registry idiom: registration *is* discovery — the CLI and docs list
    whatever is registered, nothing else to update)."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"rule class {cls.__name__} must set a name")
    if inst.name in _RULES:
        raise ValueError(f"rule {inst.name!r} is already registered")
    _RULES[inst.name] = inst
    return cls


def rule_names() -> tuple[str, ...]:
    _ensure_rules_loaded()
    return tuple(sorted(_RULES))


def get_rules(select: Iterable[str] | None = None) -> list[Rule]:
    """The selected rule instances (all registered rules by default).
    Unknown names raise with the known catalog, mirroring ``get_solver``."""
    _ensure_rules_loaded()
    if select is None:
        return [_RULES[n] for n in sorted(_RULES)]
    out = []
    for name in select:
        if name not in _RULES:
            raise ValueError(f"unknown rule {name!r}; registered rules: "
                             f"{sorted(_RULES)}")
        out.append(_RULES[name])
    return out


_RULES_LOADED = False


def _ensure_rules_loaded() -> None:
    # Importing the rule modules runs their @register_rule decorators; lazy
    # for the same reason the solver registry is (standalone import, no
    # cycles, cheap repeated lookups).
    global _RULES_LOADED
    if _RULES_LOADED:
        return
    from . import (rules_cache, rules_determinism, rules_docs,  # noqa: F401
                   rules_hygiene, rules_registry, rules_spec)
    _RULES_LOADED = True


# ------------------------------------------------------------------ the driver
def collect_modules(paths: list[Path], root: Path) -> ProjectContext:
    """Parse every ``*.py`` under ``paths`` into a :class:`ProjectContext`.
    Unparseable files become findings under the pseudo-rule ``parse-error``
    instead of crashing the run."""
    ctx = ProjectContext(root=root)
    seen: set[Path] = set()
    files: list[Path] = []
    for p in paths:
        p = p.resolve()
        cands = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in cands:
            if f not in seen:
                seen.add(f)
                files.append(f)
    for f in files:
        try:
            ctx.modules.append(ModuleInfo.parse(f, root))
        except SyntaxError as e:
            rel = f.resolve().relative_to(root).as_posix()
            ctx.parse_errors.append(Finding(
                "parse-error", rel, e.lineno or 1,
                f"file does not parse: {e.msg}"))
    return ctx


def run_rules(ctx: ProjectContext,
              rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run the rule catalog over a collected context; findings come back in
    (path, line, rule) order plus any parse errors first."""
    findings: list[Finding] = list(ctx.parse_errors)
    for rule in (get_rules() if rules is None else rules):
        for m in ctx.modules:
            findings.extend(rule.check_module(m, ctx))
        findings.extend(rule.check_project(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule, f.message))
    return findings


def run_analysis(paths: list[Path], root: Path,
                 select: Iterable[str] | None = None) -> list[Finding]:
    """One-call API (the tests' entry point): parse + run selected rules."""
    return run_rules(collect_modules(paths, root), get_rules(select))
