"""Baseline suppression file for repro-lint.

A baseline records *accepted* findings — violations that are intentional
(each entry carries a one-line justification) — so ``--strict`` CI runs stay
green on the shipped tree while any **new** finding still fails.  Entries
match on the finding's line-independent :meth:`~repro.analysis.base.Finding
.fingerprint` (``rule / path / message``), so unrelated edits to a file never
invalidate its baseline entries.

File format (one entry per record, ``#`` comments and blank lines ignored)::

    # justification for the entry below
    rule<TAB>path<TAB>message

``load_baseline`` / ``save_baseline`` round-trip this format; ``apply``
splits findings into (kept, suppressed) and reports entries that matched
nothing (stale — the violation was fixed, delete the entry).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

from .base import Finding

DEFAULT_BASELINE = "lint_baseline.txt"  # repo-root default, auto-loaded


@dataclass
class Baseline:
    """Parsed baseline: fingerprint -> justification comment lines."""

    entries: dict[str, list[str]] = field(default_factory=dict)

    def __contains__(self, fp: str) -> bool:
        return fp in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def apply(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[str]]:
        """(kept, suppressed, stale-fingerprints)."""
        kept, suppressed, matched = [], [], set()
        for f in findings:
            fp = f.fingerprint()
            if fp in self.entries:
                suppressed.append(f)
                matched.add(fp)
            else:
                kept.append(f)
        stale = [fp for fp in self.entries if fp not in matched]
        return kept, suppressed, stale


def load_baseline(path: Path) -> Baseline:
    base = Baseline()
    if not path.exists():
        return base
    pending: list[str] = []
    for raw in path.read_text().splitlines():
        line = raw.rstrip("\n")
        if not line.strip():
            pending = []
            continue
        if line.lstrip().startswith("#"):
            pending.append(line)
            continue
        if line.count("\t") < 2:
            raise ValueError(
                f"{path}: malformed baseline entry {line!r} "
                f"(expected 'rule<TAB>path<TAB>message')")
        base.entries[line] = pending
        pending = []
    return base


def save_baseline(path: Path, findings: list[Finding],
                  old: Baseline | None = None) -> None:
    """Write the current findings as the new baseline, preserving the
    justification comments of entries that survive from ``old`` and stamping
    ``# TODO: justify`` on new ones (a human replaces it in review)."""
    old = old if old is not None else Baseline()
    lines = [
        "# repro-lint baseline (docs/analysis.md): accepted findings, one",
        "# 'rule<TAB>path<TAB>message' entry per record, preceded by its",
        "# one-line justification.  Regenerate with",
        "#   python -m repro.analysis --update-baseline [paths...]",
        "",
    ]
    seen: set[str] = set()
    for f in findings:
        fp = f.fingerprint()
        if fp in seen:
            continue
        seen.add(fp)
        comments = old.entries.get(fp) or ["# TODO: justify this suppression"]
        lines.extend(comments)
        lines.append(fp)
        lines.append("")
    path.write_text("\n".join(lines).rstrip("\n") + "\n")
