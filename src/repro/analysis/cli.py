"""``python -m repro.analysis`` — the repro-lint command line.

Usage::

    python -m repro.analysis [options] [paths...]

Paths default to ``src/repro`` under the detected repo root.  Exit-code
contract (scripts and CI depend on it):

* **0** — no findings after baseline suppression (and, under ``--strict``,
  no stale baseline entries either);
* **1** — at least one unsuppressed finding, or ``--strict`` with stale
  baseline entries;
* **2** — usage error (unknown rule, malformed baseline, bad path).

The baseline at ``<root>/lint_baseline.txt`` is loaded automatically when
present (``--no-baseline`` ignores it; ``--baseline FILE`` points elsewhere);
``--update-baseline`` rewrites it from the current findings, preserving
existing justification comments.  See docs/analysis.md for the rule catalog
and the baseline workflow.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .base import collect_modules, get_rules, run_rules
from .baseline import DEFAULT_BASELINE, load_baseline, save_baseline

_ROOT_MARKERS = (".git", "pytest.ini", "Makefile")


def detect_root(start: Path) -> Path:
    """Nearest ancestor of ``start`` carrying a repo marker, else ``start``."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if any((cand / m).exists() for m in _ROOT_MARKERS):
            return cand
    return cur


def _common_root(paths: list[Path], explicit: Path | None) -> Path:
    if explicit is not None:
        return explicit.resolve()
    root = detect_root(paths[0])
    # every analyzed file must be expressible repo-relative; fall back to the
    # deepest common ancestor for out-of-tree paths (test fixtures, /tmp)
    for p in paths:
        rp = p.resolve()
        while not rp.is_relative_to(root):
            root = root.parent
    return root


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST-based checker for this repo's "
                    "correctness conventions (docs/analysis.md)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/directories to analyze (default: src/repro)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root for relative paths + baseline lookup "
                         "(default: auto-detect)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE} "
                         f"when it exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(keeps existing justification comments)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries (the CI mode)")
    ap.add_argument("--select", default=None, metavar="RULE[,RULE...]",
                    help="run only these rules")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in get_rules():
            print(f"{rule.name:<16} {rule.description}")
        return 0

    try:
        select = (None if args.select is None
                  else [s for s in args.select.split(",") if s])
        rules = get_rules(select)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    paths = args.paths
    if not paths:
        root = detect_root(Path.cwd()) if args.root is None else args.root
        default = Path(root) / "src" / "repro"
        if not default.exists():
            print(f"error: no paths given and {default} does not exist",
                  file=sys.stderr)
            return 2
        paths = [default]
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    root = _common_root(paths, args.root)
    ctx = collect_modules(paths, root)
    findings = run_rules(ctx, rules)

    baseline_path = (args.baseline if args.baseline is not None
                     else root / DEFAULT_BASELINE)
    try:
        baseline = (load_baseline(baseline_path) if not args.no_baseline
                    else load_baseline(Path("/nonexistent")))
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.update_baseline:
        save_baseline(baseline_path, findings, old=baseline)
        print(f"baseline: wrote {len({f.fingerprint() for f in findings})} "
              f"entr{'y' if len(findings) == 1 else 'ies'} to "
              f"{baseline_path}")
        return 0

    kept, suppressed, stale = baseline.apply(findings)

    for f in kept:
        print(f.render())
    n_files = len(ctx.modules)
    summary = (f"repro-lint: {len(kept)} finding(s) in {n_files} file(s)"
               + (f", {len(suppressed)} baseline-suppressed" if suppressed
                  else ""))
    status = 0
    if kept:
        status = 1
    if stale:
        for fp in stale:
            print(f"stale baseline entry (fix landed? delete it): "
                  f"{fp.replace(chr(9), ' | ')}",
                  file=sys.stderr)
        if args.strict:
            status = status or 1
    print(summary, file=sys.stderr if status else sys.stdout)
    return status


if __name__ == "__main__":
    sys.exit(main())
