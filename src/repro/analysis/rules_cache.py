"""Rule ``cache-key``: cache-key discipline for EvalCache and PlanCache.

The invariants it machine-checks (docs/analysis.md):

* every key stored into / looked up from an ``EvalCache`` table
  (``cache.comp`` / ``cache.fits``) is a tuple built by the recognized key
  constructor — a literal prefix followed by the evaluator's ``*…._ck``
  request-context tail — so entries can never silently drop the
  batch/mode/schedule context that keeps heterogeneous fleets safe on one
  shared cache;
* key *families* (distinct literal prefixes) stay **arity-disjoint**: the
  fused 7-tuple ``(node, lo, hi, *_ck)`` and the per-direction 8-tuple
  ``(node, lo, hi, direction, *_ck)`` from the round-trip training model can
  share one dict only because their lengths differ.  A new family whose
  total arity collides with an existing one would alias entries across
  semantics — this rule turns that tribal knowledge into a named finding;
* ``PlanCache`` keys are ProblemInstance **content hashes** (strings from
  ``solve_key``/``content_hash``), never ad-hoc tuples — tuple keys would
  bypass the engine-wide instance identity that makes cached outcomes
  bit-identical to fresh solves.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .astutil import enclosing_function_map, local_assignment
from .base import Finding, ModuleInfo, ProjectContext, Rule, register_rule

EVAL_TABLES = ("comp", "fits")
CK_SUFFIX = "_ck"  # the recognized request-context tail attribute
HASH_PRODUCERS = ("content_hash", "solve_key", "_solve_key", "spec_hash")


def _is_eval_table(node: ast.AST) -> bool:
    """``<something cache-like>.comp`` / ``.fits`` attribute access."""
    if not (isinstance(node, ast.Attribute) and node.attr in EVAL_TABLES):
        return False
    base = ast.unparse(node.value)
    return "cache" in base.lower()


def _key_tuple(module: ModuleInfo, expr: ast.AST, site: ast.AST,
               fn_map: dict) -> ast.Tuple | None:
    """Resolve the key expression at a cache site to its tuple display —
    either written inline or assigned to a local name in the same function."""
    if isinstance(expr, ast.Tuple):
        return expr
    if isinstance(expr, ast.Name):
        fn = fn_map.get(site)
        if fn is not None:
            val = local_assignment(fn, expr.id, before=None)
            if isinstance(val, ast.Tuple):
                return val
    return None


@register_rule
class CacheKeyRule(Rule):
    name = "cache-key"
    description = ("EvalCache keys use the *…_ck constructor and families "
                   "stay arity-disjoint; PlanCache keys are content hashes")

    # ------------------------------------------------------------- per module
    def check_module(self, module: ModuleInfo,
                     ctx: ProjectContext) -> Iterator[Finding]:
        fn_map = enclosing_function_map(module.tree)
        noqa = module.noqa_lines()
        for site, key_expr, table in _eval_sites(module.tree):
            if site.lineno in noqa:
                continue
            tup = _key_tuple(module, key_expr, site, fn_map)
            if tup is None:
                if isinstance(key_expr, ast.Name):
                    continue  # untraceable local — give names the benefit
                yield Finding(
                    self.name, module.relpath, site.lineno,
                    f"EvalCache .{table} key is not a recognized key-"
                    f"constructor tuple",
                    "build the key as a literal tuple ending in the "
                    "evaluator's *…._ck request-context tail, e.g. "
                    "(node, lo, hi, *self._ck)")
                continue
            last = tup.elts[-1] if tup.elts else None
            tail_ok = (isinstance(last, ast.Starred)
                       and isinstance(last.value, ast.Attribute)
                       and last.value.attr.endswith(CK_SUFFIX))
            if not tail_ok:
                yield Finding(
                    self.name, module.relpath, site.lineno,
                    f"EvalCache .{table} key tuple lacks the *…._ck "
                    f"request-context tail",
                    "append *<evaluator>._ck so batch/mode/schedule/"
                    "microbatch context stays part of the memo key")

        for call, key_arg in _plancache_sites(module.tree):
            if call.lineno in noqa:
                continue
            bad = _non_hash_key(module, key_arg, call, fn_map)
            if bad:
                yield Finding(
                    self.name, module.relpath, call.lineno,
                    f"PlanCache key is {bad}, not a ProblemInstance "
                    f"content hash",
                    "key PlanCache entries by the engine-wide content hash "
                    "(ServeRequest.solve_key / ProblemInstance."
                    "content_hash), never ad-hoc tuples")

    # ---------------------------------------------------- cross-file families
    def check_project(self, ctx: ProjectContext) -> Iterator[Finding]:
        # family = normalized literal prefix of a *…_ck key; arity = prefix
        # length (the _ck tail has one fixed length project-wide, so distinct
        # prefix lengths <=> distinct total arities)
        families: dict[tuple[str, ...], tuple[str, int]] = {}
        for module in ctx.modules:
            fn_map = enclosing_function_map(module.tree)
            for site, key_expr, _table in _eval_sites(module.tree):
                tup = _key_tuple(module, key_expr, site, fn_map)
                if tup is None or not tup.elts:
                    continue
                last = tup.elts[-1]
                if not (isinstance(last, ast.Starred)
                        and isinstance(last.value, ast.Attribute)
                        and last.value.attr.endswith(CK_SUFFIX)):
                    continue
                prefix = tuple(ast.unparse(e) for e in tup.elts[:-1])
                where = (module.relpath, site.lineno)
                for seen, (seen_where, seen_line) in families.items():
                    if seen != prefix and len(seen) == len(prefix):
                        yield Finding(
                            self.name, module.relpath, site.lineno,
                            f"EvalCache key family ({', '.join(prefix)}, "
                            f"*_ck) collides in arity with family "
                            f"({', '.join(seen)}, *_ck)",
                            f"key families must stay arity-disjoint so "
                            f"entries never alias in a shared table; the "
                            f"colliding family is at {seen_where}:"
                            f"{seen_line} — add/remove a discriminating "
                            f"prefix element or reuse the existing "
                            f"constructor verbatim")
                        break
                else:
                    families.setdefault(prefix, where)


def _eval_sites(tree: ast.Module):
    """(site-node, key-expr, table) for every EvalCache table access:
    ``cache.comp[key]`` loads/stores and ``cache.comp.get(key)`` lookups."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and _is_eval_table(node.value):
            yield node, node.slice, node.value.attr
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "get"
              and _is_eval_table(node.func.value)
              and node.args):
            yield node, node.args[0], node.func.value.attr


def _plancache_sites(tree: ast.Module):
    """(call, key-arg) for ``<plan cache>.get/put`` calls on objects whose
    spelling marks them as a PlanCache."""
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("get", "put") and node.args):
            continue
        base = ast.unparse(node.func.value)
        if "plan_cache" in base or "PlanCache" in base:
            yield node, node.args[0]


def _non_hash_key(module: ModuleInfo, arg: ast.AST, site: ast.AST,
                  fn_map: dict) -> str | None:
    """A human description of the key if it is visibly *not* a content hash
    (tuple display, non-string constant — directly or through one local
    assignment); None when it is a hash or untraceable (assumed fine)."""
    if isinstance(arg, ast.Name):
        fn = fn_map.get(site)
        val = (local_assignment(fn, arg.id, before=None)
               if fn is not None else None)
        if val is not None:
            arg = val
    if isinstance(arg, ast.Tuple):
        return "a tuple"
    if isinstance(arg, ast.Constant) and not isinstance(arg.value, str):
        return f"a {type(arg.value).__name__} constant"
    return None
