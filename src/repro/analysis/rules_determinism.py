"""Rule ``determinism``: no wall clock, no unseeded RNG on solver/sim paths.

Bit-parity harnesses (NumPy vs JAX solvers), conservation replay
(``replay_verify_sim``) and content-hash caching all assume solver, serve,
MSL and sweep code is a *deterministic function of its inputs*.  This rule
flags, inside the checked subtrees (``core/``, ``serve/``, ``msl/``,
``sweep/`` — ``launch/`` and ``benchmarks/`` are allowlisted because
launching and benchmarking legitimately read the clock):

* wall-clock reads: ``time.time()``, ``time.time_ns()``,
  ``datetime.now()`` / ``utcnow()`` / ``today()`` — replayable code takes
  timestamps as parameters; interval timing uses ``time.perf_counter()``
  (monotonic, never used as data), which is allowed;
* module-level global-state RNG: ``np.random.<sampler>`` and stdlib
  ``random.<sampler>`` calls — these draw from hidden global streams that
  any import can perturb;
* unseeded generator construction: ``np.random.default_rng()`` /
  ``random.Random()`` with no seed argument.

Seeded construction (``random.Random(seed)``, ``default_rng(seed)``,
``np.random.Philox(key=...)``) and the functional ``jax.random`` API are the
approved idioms and pass untouched.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .astutil import dotted_name
from .base import Finding, ModuleInfo, ProjectContext, Rule, register_rule

CHECKED_DIRS = frozenset({"core", "serve", "msl", "sweep"})
ALLOWED_DIRS = frozenset({"launch", "benchmarks"})  # timing is their job

WALL_CLOCK = {
    "time.time": "time.time()",
    "time.time_ns": "time.time_ns()",
    "datetime.now": "datetime.now()",
    "datetime.utcnow": "datetime.utcnow()",
    "datetime.today": "datetime.today()",
    "date.today": "date.today()",
}

NP_SAMPLERS = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "uniform", "normal", "standard_normal",
    "poisson", "exponential", "binomial", "beta", "gamma", "seed",
})
PY_SAMPLERS = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "triangular", "seed", "getrandbits",
})

_RNG_FIX = ("thread a seeded generator from the caller "
            "(random.Random(seed) / np.random.default_rng(seed)) instead of "
            "the global stream")
_CLOCK_FIX = ("wall-clock reads break replay determinism; take timestamps "
              "as parameters, or use time.perf_counter() for wall-time "
              "stats that are never inputs")


def _in_scope(relpath: str) -> bool:
    parts = relpath.split("/")[:-1]
    if any(p in ALLOWED_DIRS for p in parts):
        return False
    return any(p in CHECKED_DIRS for p in parts)


@register_rule
class DeterminismRule(Rule):
    name = "determinism"
    description = ("no wall-clock or unseeded/global RNG in core/, serve/, "
                   "msl/, sweep/ (launch/ and benchmarks are allowlisted)")

    def check_module(self, module: ModuleInfo,
                     ctx: ProjectContext) -> Iterator[Finding]:
        if not _in_scope(module.relpath):
            return
        noqa = module.noqa_lines()
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call) or node.lineno in noqa:
                continue
            dn = dotted_name(node.func)
            if dn is None:
                continue
            # wall clock --------------------------------------------------
            for suffix, label in WALL_CLOCK.items():
                if dn == suffix or dn.endswith("." + suffix):
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        f"wall-clock call {label} in deterministic path",
                        _CLOCK_FIX)
                    break
            else:
                parts = dn.split(".")
                # numpy global-state RNG ----------------------------------
                if (len(parts) >= 3 and parts[-2] == "random"
                        and parts[0] in ("np", "numpy")
                        and parts[-1] in NP_SAMPLERS):
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        f"global-stream RNG call {dn}()", _RNG_FIX)
                elif dn.endswith("random.default_rng") and not (
                        node.args or node.keywords):
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        "unseeded np.random.default_rng()",
                        "pass an explicit seed: np.random.default_rng(seed)")
                # stdlib global-state RNG ---------------------------------
                elif (len(parts) == 2 and parts[0] == "random"
                        and parts[1] in PY_SAMPLERS):
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        f"global-stream RNG call {dn}()", _RNG_FIX)
                elif dn == "random.Random" and not (
                        node.args or node.keywords):
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        "unseeded random.Random()",
                        "pass an explicit seed: random.Random(seed)")
