"""Rule ``docs-sync``: README/docs cross-links and module coverage.

The framework port of ``scripts/check_docs_sync.py`` (the script is now a
thin alias over this rule, same REQUIRED_DOCUMENTED semantics and the same
failure messages):

* every docs/*.md referenced from README.md or another doc exists;
* every docs/*.md on disk is reachable from README.md (no orphans);
* every ``src/repro/...py`` path mentioned in docs exists on disk;
* the mapped subsystems in :data:`REQUIRED_DOCUMENTED` exist *and* are
  mentioned somewhere in README.md or docs/ — the architecture map must not
  go stale silently.

Runs only when the analyzed tree's root actually carries a README.md and a
docs/ directory (fixture projects without docs produce no findings).
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Iterator

from .base import Finding, ProjectContext, Rule, register_rule

LINK_RE = re.compile(r"\(((?:docs/)?[\w.-]+\.md)(?:#[\w-]+)?\)")
SRC_RE = re.compile(r"`(src/repro/[\w/.]+\.py)`")

# Modules the docs must both mention and that must exist on disk — the
# subsystem map in docs/architecture.md and the solver guide go stale
# silently otherwise.
REQUIRED_DOCUMENTED = (
    "src/repro/core/jax_solvers.py",
    "src/repro/kernels/minplus.py",
    "src/repro/serve/gateway.py",
    "src/repro/serve/failures.py",
    "src/repro/core/trainpipe.py",
    "src/repro/analysis/base.py",
    "src/repro/analysis/baseline.py",
    "src/repro/analysis/cli.py",
)


def doc_links(path: Path, root: Path) -> set[Path]:
    """docs/*.md paths referenced by markdown links in `path` (repo-relative)."""
    out = set()
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith("docs/"):
            out.add(root / target)
        elif path.parent == root / "docs":
            out.add(root / "docs" / target)
    return out


def docs_sync_errors(root: Path) -> tuple[list[str], int]:
    """(error messages, number of docs reachable from README) — the exact
    checks and messages of the original scripts/check_docs_sync.py."""
    errors: list[str] = []
    readme = root / "README.md"
    reachable = doc_links(readme, root)
    for doc in sorted((root / "docs").glob("*.md")):
        reachable |= doc_links(doc, root)

    for ref in sorted(reachable):
        if not ref.exists():
            errors.append(f"broken doc link: {ref.relative_to(root)}")

    readme_reachable = doc_links(readme, root)
    frontier = list(readme_reachable)
    while frontier:  # transitive closure from README
        doc = frontier.pop()
        if not doc.exists():
            continue
        for ref in doc_links(doc, root):
            if ref not in readme_reachable:
                readme_reachable.add(ref)
                frontier.append(ref)
    for doc in sorted((root / "docs").glob("*.md")):
        if doc not in readme_reachable:
            errors.append(f"orphaned doc (not reachable from README.md): "
                          f"{doc.relative_to(root)}")

    # source modules referenced by full path in docs must exist on disk ...
    all_docs = [readme] + sorted((root / "docs").glob("*.md"))
    docs_text = "\n".join(d.read_text() for d in all_docs)
    for mod in sorted(set(SRC_RE.findall(docs_text))):
        if not (root / mod).exists():
            errors.append(f"doc references missing source module: {mod}")
    # ... and the mapped subsystems must stay documented (by basename)
    for mod in REQUIRED_DOCUMENTED:
        path = root / mod
        if not path.exists():
            errors.append(f"required module missing from tree: {mod}")
        if path.name not in docs_text:
            errors.append(f"module {mod} is not mentioned anywhere in "
                          f"README.md or docs/ (update docs/architecture.md "
                          f"and docs/solvers.md)")
    return errors, len(readme_reachable)


@register_rule
class DocsSyncRule(Rule):
    name = "docs-sync"
    description = ("README/docs links resolve, no orphaned docs, "
                   "REQUIRED_DOCUMENTED modules exist and stay documented")

    def check_project(self, ctx: ProjectContext) -> Iterator[Finding]:
        root = ctx.root
        if not ((root / "README.md").exists() and (root / "docs").is_dir()):
            return
        errors, _ = docs_sync_errors(root)
        for msg in errors:
            yield Finding(self.name, "README.md", 1, msg,
                          "see docs/analysis.md (docs-sync) for the doc "
                          "graph conventions")
