"""Rules ``no-shim-import`` and ``unused-import``: import hygiene.

* ``no-shim-import`` — the warn-once ``*_solve`` deprecation shims exist for
  *external* callers only; internal modules importing them would re-entrench
  the legacy API (and their first call burns the one-per-process warning an
  actual user should see).  The shim name list is derived from the
  ``deprecated_solver_alias(...)`` assignments themselves, so a new shim is
  covered the moment it is created.

* ``unused-import`` — the pyflakes-F401 tier as a native rule (the generic
  complement ruff provides where installed; this keeps the gate hermetic).
  Conventions honored: ``from __future__`` imports, ``# noqa`` lines and
  re-export ``__init__.py`` files without ``__all__`` are skipped; any
  simple-identifier string constant in the module (``__all__`` entries,
  registry name tables) counts as a use.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .astutil import call_name
from .base import Finding, ModuleInfo, ProjectContext, Rule, register_rule


@register_rule
class ShimImportRule(Rule):
    name = "no-shim-import"
    description = ("internal modules never import the deprecated warn-once "
                   "*_solve shims (deprecated_solver_alias)")

    def check_project(self, ctx: ProjectContext) -> Iterator[Finding]:
        shims: dict[str, str] = {}  # alias name -> defining module
        for module in ctx.modules:
            for node in ast.walk(module.tree):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)
                        and call_name(node.value)
                        == "deprecated_solver_alias"):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            shims[tgt.id] = module.relpath
        if not shims:
            return
        for module in ctx.modules:
            noqa = module.noqa_lines()
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.ImportFrom):
                    continue
                if node.lineno in noqa:
                    continue
                for alias in node.names:
                    src = shims.get(alias.name)
                    if src is None or src == module.relpath:
                        continue
                    yield Finding(
                        self.name, module.relpath, node.lineno,
                        f"imports deprecated shim {alias.name!r}",
                        f"call the registered solver through the engine "
                        f"instead: solve(ProblemInstance(...), "
                        f"solver=<name>) — the shim in {src} exists only "
                        f"for external callers")


@register_rule
class UnusedImportRule(Rule):
    name = "unused-import"
    description = ("imported names must be used (F401 tier; __init__.py "
                   "re-exports and # noqa lines are exempt)")

    def check_module(self, module: ModuleInfo,
                     ctx: ProjectContext) -> Iterator[Finding]:
        is_init = module.relpath.endswith("__init__.py")
        has_all = any(
            isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in n.targets)
            for n in module.tree.body)
        if is_init and not has_all:
            return  # re-export module: imports ARE the interface

        noqa = module.noqa_lines()
        imported: list[tuple[str, int]] = []  # (bound name, line)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    imported.append((bound, node.lineno))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        return  # star imports defeat static use tracking
                    imported.append((alias.asname or alias.name,
                                     node.lineno))

        used: set[str] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Name):
                used.add(node.id)
            elif (isinstance(node, ast.Constant)
                    and isinstance(node.value, str)
                    and node.value.isidentifier()):
                used.add(node.value)  # __all__ entries, name tables

        for name, line in imported:
            if name in used or line in noqa:
                continue
            yield Finding(
                self.name, module.relpath, line,
                f"imported name {name!r} is never used",
                "delete the import (or mark an intentional re-export with "
                "# noqa)")
