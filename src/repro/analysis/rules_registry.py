"""Rule ``solver-registry``: capability declarations match solver bodies.

``@register_solver(name, schedules=...)`` is the single source of capability
truth — the sweep spec validator, the serve planner and ``solve()`` all trust
it.  A declaration that drifts from the body fails in two directions, both
flagged here:

* **declared but unreachable** — the solver declares ``PIPE`` but no code
  reachable from its body ever branches on the pipelined schedule (no
  ``request.schedule == PIPE`` test, no call into a pipe-handling helper):
  pipelined requests would silently get sequential plans;
* **handled but undeclared** — the body (transitively) contains a pipelined
  code path but the registration omits ``PIPE``: the capability gate would
  reject requests the solver actually models, or worse, a later widening of
  the declaration would "work" untested.

Reachability is a conservative intra-project call-graph walk: bare-name
calls resolved through local defs and ``from`` imports, stopping at the
engine/evaluator layer (``ensure_solver_supported``, ``PlanEvaluator`` and
friends are the *gate* and the *pricer* — every solver touches them, so
traversing them would make the check vacuous).  A ``schedule == PIPE`` test
whose branch only raises counts as a *guard*, not as handling — rejecting
pipe without declaring it is exactly right.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Iterator

from .astutil import call_name
from .base import Finding, ModuleInfo, ProjectContext, Rule, register_rule

# engine / evaluation machinery: never traversed (see module docstring)
BOUNDARY_CALLEES = frozenset({
    "ensure_solver_supported", "solver_supports", "get_solver", "solve",
    "solve_batch", "register_solver", "PlanEvaluator", "EvalCache",
})
BOUNDARY_MODULES = frozenset({
    "engine", "plan", "costmodel", "problem", "network", "topology",
})

SCHEDULE_NAMES = {"SEQ": "seq", "PIPE": "pipe"}


@register_rule
class SolverRegistryRule(Rule):
    name = "solver-registry"
    description = ("@register_solver schedules= declarations match what the "
                   "solver body (transitively) actually handles")

    def check_project(self, ctx: ProjectContext) -> Iterator[Finding]:
        index = _FunctionIndex(ctx)
        for module, fn, reg_line, declared in _registrations(ctx):
            if declared is None:
                continue  # meta solver or schedules we cannot evaluate
            handles, guards = _pipe_evidence(index, module, fn)
            if "pipe" in declared and not handles:
                yield Finding(
                    self.name, module.relpath, reg_line,
                    f"solver {fn.name!r} declares schedule 'pipe' but no "
                    f"reachable code branches on the pipelined schedule",
                    "either drop PIPE from the registration's schedules= or "
                    "add the pipelined code path (a request.schedule == "
                    "PIPE branch / a *pipe helper call)")
            if "pipe" not in declared and handles:
                yield Finding(
                    self.name, module.relpath, reg_line,
                    f"solver {fn.name!r} handles pipelined requests without "
                    f"declaring schedule 'pipe'",
                    "add PIPE to the registration's schedules= so the "
                    "capability gate (solver_supports) stops rejecting "
                    "requests the body actually models")


# ---------------------------------------------------------------- extraction
def _registrations(ctx: ProjectContext):
    """(module, function-def, registration-line, declared-schedules|None)
    for every ``register_solver`` application — decorator form and the
    ``register_solver(...)(fn)`` call form."""
    for module in ctx.modules:
        local_fns = {n.name: n for n in module.tree.body
                     if isinstance(n, ast.FunctionDef)}
        for node in module.tree.body:
            if isinstance(node, ast.FunctionDef):
                for deco in node.decorator_list:
                    if (isinstance(deco, ast.Call)
                            and call_name(deco) == "register_solver"):
                        yield (module, node, deco.lineno,
                               _declared_schedules(deco))
            else:
                for call in ast.walk(node):
                    # register_solver(name, ...)(fn)
                    if (isinstance(call, ast.Call)
                            and isinstance(call.func, ast.Call)
                            and call_name(call.func) == "register_solver"
                            and len(call.args) == 1
                            and isinstance(call.args[0], ast.Name)):
                        fn = local_fns.get(call.args[0].id)
                        if fn is not None:
                            yield (module, fn, call.lineno,
                                   _declared_schedules(call.func))


def _declared_schedules(reg_call: ast.Call) -> frozenset[str] | None:
    """The statically evaluable declared-schedule set; None when the solver
    is meta or the declaration cannot be resolved (no finding either way)."""
    schedules: frozenset[str] | None = frozenset({"seq", "pipe"})  # default
    for kw in reg_call.keywords:
        if kw.arg == "meta" and isinstance(kw.value, ast.Constant) \
                and kw.value.value:
            return None
        if kw.arg != "schedules":
            continue
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            out = set()
            for el in kw.value.elts:
                if isinstance(el, ast.Name) and el.id in SCHEDULE_NAMES:
                    out.add(SCHEDULE_NAMES[el.id])
                elif (isinstance(el, ast.Constant)
                        and el.value in ("seq", "pipe")):
                    out.add(el.value)
                else:
                    return None
            schedules = frozenset(out)
        elif isinstance(kw.value, ast.Name) and kw.value.id == "SCHEDULES":
            schedules = frozenset({"seq", "pipe"})
        else:
            return None
    return schedules


# -------------------------------------------------------------- reachability
class _FunctionIndex:
    """Project-wide bare-name call resolution: local module defs first, then
    ``from``-imports of other analyzed modules (relative or ``repro.``-
    absolute)."""

    def __init__(self, ctx: ProjectContext):
        self.ctx = ctx
        self.defs: dict[str, dict[str, ast.FunctionDef]] = {}
        self.imports: dict[str, dict[str, tuple[str, str]]] = {}
        by_path = {m.relpath: m for m in ctx.modules}
        for m in ctx.modules:
            self.defs[m.relpath] = {
                n.name: n for n in m.tree.body
                if isinstance(n, ast.FunctionDef)}
            imp: dict[str, tuple[str, str]] = {}
            for node in ast.walk(m.tree):
                if not isinstance(node, ast.ImportFrom) or node.module is None:
                    continue
                target = _resolve_module(m.relpath, node, by_path)
                if target is None:
                    continue
                for alias in node.names:
                    imp[alias.asname or alias.name] = (target, alias.name)
            self.imports[m.relpath] = imp

    def resolve(self, relpath: str,
                name: str) -> tuple[str, ast.FunctionDef] | None:
        fn = self.defs.get(relpath, {}).get(name)
        if fn is not None:
            return relpath, fn
        imp = self.imports.get(relpath, {}).get(name)
        if imp is not None:
            target, orig = imp
            if PurePosixPath(target).stem in BOUNDARY_MODULES:
                return None
            fn = self.defs.get(target, {}).get(orig)
            if fn is not None:
                return target, fn
        return None


def _resolve_module(relpath: str, node: ast.ImportFrom,
                    by_path: dict) -> str | None:
    """Map an ImportFrom to an analyzed module's relpath (or None)."""
    parts = node.module.split(".")
    if node.level:  # relative: walk up from the importing module's package
        base = PurePosixPath(relpath).parent
        for _ in range(node.level - 1):
            base = base.parent
        cand = (base.joinpath(*parts)).as_posix() + ".py"
    else:  # absolute: match by dotted-path suffix against analyzed modules
        suffix = "/".join(parts) + ".py"
        cands = [p for p in by_path if p.endswith(suffix)]
        cand = cands[0] if len(cands) == 1 else None
    return cand if cand in by_path else None


def _pipe_evidence(index: _FunctionIndex, module: ModuleInfo,
                   fn: ast.FunctionDef) -> tuple[bool, bool]:
    """(handles, guards): walk the conservative call graph from ``fn`` and
    look for pipelined-schedule evidence (see module docstring)."""
    handles = guards = False
    visited: set[tuple[str, str]] = set()
    stack: list[tuple[str, ast.FunctionDef]] = [(module.relpath, fn)]
    while stack:
        relpath, cur = stack.pop()
        if (relpath, cur.name) in visited:
            continue
        visited.add((relpath, cur.name))
        h, g = _scan_body(cur)
        handles |= h
        guards |= g
        for node in ast.walk(cur):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                callee = node.func.id
                if callee in BOUNDARY_CALLEES:
                    continue
                if "pipe" in callee.lower():
                    handles = True  # calling a pipe helper IS handling
                target = index.resolve(relpath, callee)
                if target is not None:
                    stack.append(target)
    return handles, guards


def _scan_body(fn: ast.FunctionDef) -> tuple[bool, bool]:
    """Pipe evidence inside one function body: ``== PIPE`` comparisons are
    *handling* unless the enclosing if-branch consists solely of raises
    (then they are a guard)."""
    handles = guards = False
    guard_compares: set[ast.AST] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.If) and _mentions_pipe(node.test) and all(
                isinstance(s, ast.Raise) for s in node.body):
            guards = True
            for sub in ast.walk(node.test):
                guard_compares.add(sub)
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare) and node not in guard_compares \
                and _mentions_pipe(node):
            handles = True
    return handles, guards


def _mentions_pipe(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name) and sub.id == "PIPE":
            return True
        if isinstance(sub, ast.Constant) and sub.value == "pipe":
            return True
    return False
