"""Rule ``spec-hash``: every ScenarioSpec knob is hash-relevant.

On-disk sweep caching, serve presolve dedup and twin-pairing all key on the
spec content hash (``ScenarioSpec.key()`` -> ``spec_hash()``): a knob that
changes results but silently falls out of the hash makes two *different*
scenarios collide in the cache — the nastiest possible staleness bug, and
one a downstream parity test only catches by luck.

``key()`` hashes ``to_dict()`` minus an explicit exclusion set, so every
*new* dataclass field is hash-relevant by construction; what this rule pins
down statically is the exclusion set itself:

* every field ``key()`` pops out of the hash must be declared in the
  module-level ``HASH_IRRELEVANT`` allowlist (one place, with a
  justification comment per entry);
* every ``HASH_IRRELEVANT`` entry must still be a real dataclass field
  (stale allowlist entries are findings too);
* every allowlisted field must actually be popped — an allowlisted field
  that ``key()`` still hashes means allowlist and implementation drifted;
* pops that cannot be resolved statically (computed field sets) are flagged:
  the whole point is that the exclusion set is reviewable at a glance.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .astutil import const_str_tuple
from .base import Finding, ModuleInfo, ProjectContext, Rule, register_rule

SPEC_CLASS = "ScenarioSpec"
ALLOWLIST_NAME = "HASH_IRRELEVANT"
KEY_METHOD = "key"


@register_rule
class SpecHashRule(Rule):
    name = "spec-hash"
    description = ("every ScenarioSpec field is content-hashed by key() "
                   "unless declared in the HASH_IRRELEVANT allowlist")

    def check_module(self, module: ModuleInfo,
                     ctx: ProjectContext) -> Iterator[Finding]:
        spec = next((n for n in module.tree.body
                     if isinstance(n, ast.ClassDef) and n.name == SPEC_CLASS),
                    None)
        if spec is None:
            return
        key_fn = next((n for n in spec.body
                       if isinstance(n, ast.FunctionDef)
                       and n.name == KEY_METHOD), None)
        if key_fn is None:
            return

        fields = {n.target.id for n in spec.body
                  if isinstance(n, ast.AnnAssign)
                  and isinstance(n.target, ast.Name)}
        allowlist = _module_allowlist(module.tree)
        popped, via_loop, unresolved = _popped_fields(key_fn, allowlist)

        for line, desc in unresolved:
            yield Finding(
                self.name, module.relpath, line,
                f"{SPEC_CLASS}.key() excludes a field set that cannot be "
                f"resolved statically ({desc})",
                f"pop hash-excluded fields via the module-level "
                f"{ALLOWLIST_NAME} tuple (or literal field names) so the "
                f"exclusion set stays reviewable")
        allowed = set(allowlist or ())
        for name, line in sorted(popped.items()):
            if name not in allowed:
                yield Finding(
                    self.name, module.relpath, line,
                    f"field {name!r} is excluded from the spec content hash "
                    f"but not declared in {ALLOWLIST_NAME}",
                    f"add {name!r} to {ALLOWLIST_NAME} with a justification "
                    f"comment — or stop popping it so it hashes")
            elif name not in fields and name not in via_loop:
                # a stale name reached only through the HASH_IRRELEVANT loop
                # is the *allowlist entry's* fault — reported once below
                yield Finding(
                    self.name, module.relpath, line,
                    f"key() pops {name!r}, which is not a {SPEC_CLASS} "
                    f"field",
                    "remove the stale pop (the field was renamed or "
                    "deleted)")
        if allowlist is not None:
            for name in allowlist:
                if name not in fields:
                    yield Finding(
                        self.name, module.relpath, spec.lineno,
                        f"stale {ALLOWLIST_NAME} entry {name!r}: not a "
                        f"{SPEC_CLASS} field",
                        "remove the entry (the field was renamed or "
                        "deleted)")
                elif name not in popped:
                    yield Finding(
                        self.name, module.relpath, key_fn.lineno,
                        f"field {name!r} is declared hash-irrelevant but "
                        f"key() still hashes it",
                        f"pop it in key() (the canonical form iterates "
                        f"{ALLOWLIST_NAME}) or remove it from the "
                        f"allowlist")


def _module_allowlist(tree: ast.Module) -> list[str] | None:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == ALLOWLIST_NAME:
                    return const_str_tuple(node.value)
    return None


def _popped_fields(
    key_fn: ast.FunctionDef, allowlist: list[str] | None
) -> tuple[dict[str, int], set[str], list[tuple[int, str]]]:
    """Fields ``key()`` pops from the hashed dict: literal ``d.pop("x")``
    strings, plus loops ``for f in HASH_IRRELEVANT: d.pop(f)`` (and loops
    over literal tuples), expanded.  Returns (name -> line, names popped
    only via the HASH_IRRELEVANT loop, unresolved)."""
    popped: dict[str, int] = {}
    via_loop: set[str] = set()
    unresolved: list[tuple[int, str]] = []
    loop_vars: dict[str, list[str]] = {}
    for node in ast.walk(key_fn):
        if isinstance(node, ast.For) and isinstance(node.target, ast.Name):
            if (isinstance(node.iter, ast.Name)
                    and node.iter.id == ALLOWLIST_NAME):
                loop_vars[node.target.id] = [f"@{ALLOWLIST_NAME}"]
            else:
                lit = const_str_tuple(node.iter)
                if lit is not None:
                    loop_vars[node.target.id] = lit
    for node in ast.walk(key_fn):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "pop" and node.args):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            popped[arg.value] = node.lineno
        elif isinstance(arg, ast.Name) and arg.id in loop_vars:
            values = loop_vars[arg.id]
            if values == [f"@{ALLOWLIST_NAME}"]:
                for name in (allowlist or ()):
                    popped[name] = node.lineno
                    via_loop.add(name)
                if allowlist is None:
                    unresolved.append(
                        (node.lineno,
                         f"loops over {ALLOWLIST_NAME}, which is not a "
                         f"module-level tuple of string literals"))
            else:
                for name in values:
                    popped[name] = node.lineno
        else:
            unresolved.append(
                (node.lineno, f"pop argument {ast.unparse(arg)!r}"))
    return popped, via_loop, unresolved
