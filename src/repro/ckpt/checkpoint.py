"""Sharded numpy checkpointing: atomic, async, retention-managed.

Each leaf is one .npy under the step directory (streams per-leaf, never
materializes the full tree twice); the manifest records keypaths, shapes,
dtypes, and the training step.  Writes go to a temp dir renamed into place
(crash-atomic); a background thread makes saves non-blocking; `keep` bounds
disk use.  Restore rebuilds the nested pytree from keypaths alone (dicts +
lists), so no "like" tree is needed.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _set_nested(root, parts: list[str], value):
    cur = root
    for i, p in enumerate(parts[:-1]):
        nxt_is_idx = parts[i + 1].isdigit()
        if p.isdigit():
            p = int(p)
            while len(cur) <= p:
                cur.append([] if nxt_is_idx else {})
            if cur[p] == [] and not nxt_is_idx:
                cur[p] = {}
            cur = cur[p]
        else:
            if p not in cur:
                cur[p] = [] if nxt_is_idx else {}
            cur = cur[p]
    last = parts[-1]
    if last.isdigit():
        last = int(last)
        while len(cur) <= last:
            cur.append(None)
        cur[last] = value
    else:
        cur[last] = value


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write,
                                            args=(step, host_tree), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        tmp = self.dir / f".tmp_step_{step}_{time.time_ns()}"
        tmp.mkdir(parents=True)
        leaves = jax.tree_util.tree_flatten_with_path(host_tree)[0]
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append({
                "path": _path_str(path), "file": fname,
                "shape": list(np.shape(leaf)), "dtype": str(np.asarray(leaf).dtype),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:010d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ckpts = sorted(self.dir.glob("step_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: int | None = None):
        """Returns (step, tree) or (None, None) when no checkpoint exists."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        root: dict = {}
        for leaf in manifest["leaves"]:
            arr = np.load(d / leaf["file"])
            _set_nested(root, leaf["path"].split("/"), arr)
        return manifest["step"], root
