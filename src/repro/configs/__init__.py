from .base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)
from .registry import ARCHS, get_config, list_archs

__all__ = [
    "ModelConfig", "ShapeConfig", "ARCHS", "get_config", "list_archs",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "shape_applicable",
]
