"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN.  [hf:Snowflake/snowflake-arctic-base]

bf16 params + Adafactor: 480B fp32 Adam state would not fit 256 x 16 GB HBM
(30 GB/chip); bf16 weights + factored second moments fit (~8 GB/chip).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    head_dim=128,
    pattern=("moe_dense",),
    n_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    param_dtype="bfloat16",
    sharding_strategy="2d",  # EP: experts on 'model'
    optimizer="adafactor",
)
