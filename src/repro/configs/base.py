"""Config system: every architecture is a `ModelConfig` selectable via --arch.

A model is an embedding + a repeated *pattern* of block kinds + head.  Kinds:

  attn        global causal self-attention (GQA) + MLP
  local_attn  sliding-window causal self-attention + MLP
  xattn       cross-attention to a modality memory (no self-attn) + MLP
  dec_block   decoder block: self-attn + cross-attn + MLP (enc-dec decoders)
  moe         mixture-of-experts FFN block (attention + MoE)
  moe_dense   MoE + parallel dense residual FFN (arctic)
  rglru       RG-LRU recurrent block (Griffin/RecurrentGemma)
  ssd         Mamba-2 state-space-duality block (attention-free)

`n_layers` layers follow `pattern` cyclically; full pattern repetitions are
executed under one `lax.scan` with stacked params, the remainder is unrolled.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // n_heads
    pattern: tuple[str, ...] = ("attn",)

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None  # local-attention window
    rope_theta: float = 10_000.0
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    rnn_width: int | None = None
    conv_width: int = 4

    # encoder-decoder (audio): encoder is `enc_layers` of non-causal attn;
    # decoder is `n_layers` of `pattern` (dec_block).
    enc_layers: int = 0

    # modality frontend stub: inputs carry precomputed embeddings of this length
    memory_len: int = 0  # cross-attention memory length (vision patches / audio frames)

    # numerics / training
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: str = "float32"  # optimizer master dtype
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor
    remat: bool = True
    q_chunk: int = 512  # blocked-attention query chunk
    loss_chunk: int = 4096  # chunked cross-entropy block (tokens)
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale

    # planner applicability notes (DESIGN.md Sec. 3)
    sub_quadratic: bool = False  # eligible for long_500k decode

    # distribution strategy (launch/shardings.py):
    #   fsdp — batch over ALL mesh axes (4k tokens/chip at train_4k), weights
    #          ZeRO-3 sharded and gathered per layer (v5e-native for dense)
    #   2d   — batch over DP axes only + TP/EP on 'model' (MoE needs EP)
    sharding_strategy: str = "fsdp"

    def __post_init__(self):
        assert self.n_layers >= 1 and self.d_model % 2 == 0
        if self.n_heads:
            assert self.n_heads % max(1, self.n_kv_heads) == 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // max(1, self.n_heads)

    def layer_kinds(self) -> list[str]:
        return [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (one fwd/train step)."""
        small = dict(
            n_layers=min(self.n_layers, 2 * len(self.pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            moe_d_ff=64 if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16,
            ssm_chunk=16,
            rnn_width=64 if self.rnn_width else None,
            window=min(self.window, 32) if self.window else None,
            enc_layers=min(self.enc_layers, 2),
            memory_len=min(self.memory_len, 8) if self.memory_len else 0,
            q_chunk=16,
            loss_chunk=128,
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)


# shape suite (assignment): every LM arch is exercised on these
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules (recorded in DESIGN.md Sec. 3)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for full-attention archs"
    return True, ""
