"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000;
local+global alternating attention, attention & final logit softcaps, GeGLU.
[arXiv:2408.00118]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36_864,
    vocab_size=256_000,
    head_dim=128,
    pattern=("local_attn", "attn"),
    attn_softcap=50.0,
    final_softcap=30.0,
    window=4096,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    optimizer="adamw",
)
