"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; cross-attn image layers every 5th layer (20 of 100).
[hf:meta-llama/Llama-3.2-11B-Vision scaled per assignment]

The vision frontend is a STUB per the assignment: `input_specs()` provides
precomputed patch embeddings (memory_len x d_model); `xattn` layers attend to
them.  Adafactor keeps 90B optimizer state within 16 GB/chip.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    rope_theta=500_000.0,
    memory_len=4096,  # precomputed vision patch embeddings (stub frontend)
    param_dtype="bfloat16",
    optimizer="adafactor",
)
