"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, d_ff=0, vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]

Sub-quadratic: O(1)-in-context recurrent state — runs the long_500k decode shape.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    pattern=("ssd",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    sub_quadratic=True,
    optimizer="adamw",
)
