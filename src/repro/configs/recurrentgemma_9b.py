"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention, 2:1 pattern (Griffin).
[arXiv:2402.19427]

Sub-quadratic: RG-LRU state is O(1) in context; the local-attention cache is a
2048-token ring buffer — eligible for the long_500k decode shape.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    rnn_width=4096,
    conv_width=4,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    sub_quadratic=True,
    optimizer="adamw",
)
