"""--arch registry: the 10 assigned architectures (+ the paper's own ResNet101
profile for the planner examples)."""
from __future__ import annotations

from . import (
    arctic_480b,
    gemma2_27b,
    llama_3_2_vision_90b,
    mamba2_370m,
    qwen2_1_5b,
    qwen3_14b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    starcoder2_7b,
    whisper_small,
)
from .base import ModelConfig

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_moe_30b_a3b,
        arctic_480b,
        llama_3_2_vision_90b,
        qwen2_1_5b,
        starcoder2_7b,
        gemma2_27b,
        qwen3_14b,
        recurrentgemma_9b,
        whisper_small,
        mamba2_370m,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)
