"""whisper-small [audio]: enc-dec 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865; conv audio frontend is a STUB (input_specs() provides precomputed
frame embeddings of length memory_len).  [arXiv:2212.04356]

Decoder: 12 `dec_block` layers (self-attn + cross-attn to the encoder output).
Encoder: 12 non-causal attn layers over the frame embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    pattern=("dec_block",),
    enc_layers=12,
    memory_len=1500,  # precomputed conv-frontend frame embeddings (stub)
    qkv_bias=True,
    mlp_variant="gelu",
    optimizer="adamw",
)
