"""Core — the paper's contribution: joint optimization of model splitting,
placement, and chaining for SFC-based multi-hop split learning/inference.

The solving API is the engine triple (see docs/solvers.md):

  * `ProblemInstance` — frozen, content-hashable problem description
    (network + profile + request + K + candidate sets).
  * `solve(problem, solver=...)` — capability-checked dispatch through the
    solver registry; returns a `SolveOutcome` (plan, objective, status in
    {optimal, feasible, infeasible}, wall time, solver stats).
  * `@register_solver(name, schedules=..., optimal=...)` — one decorator adds
    a solver (learned, randomized, external) to every layer: sweep grids,
    the serving planner, benchmarks, and the CLIs.

Registered solvers:
  * `ilp`      — faithful MILP of Eqs. (1)-(15), HiGHS branch-and-bound (exact,
                 sequential schedule only).
  * `exact`    — provably equivalent joint DP (fast optimal oracle).
  * `bcd`      — the paper's BCD heuristic (Alg. 1: K-seq segmentation + DFTS).
  * `comp-ms` / `comm-ms` — the paper's comparison schemes.
  * `portfolio` — meta-solver: best feasible outcome over a member set run on
                 one shared EvalCache, with per-member stats.

The flat `*_solve` functions are kept as deprecated shims (one
DeprecationWarning per process; bit-for-bit identical plans).
"""
from . import baselines as _baselines  # noqa: F401 (registers comp-ms / comm-ms)
from . import bcd as _bcd  # noqa: F401 (registers bcd)
from . import exact as _exact  # noqa: F401 (registers exact)
from . import ilp as _ilp  # noqa: F401 (registers ilp)
from .costmodel import (
    BW,
    FW,
    IF,
    PIPE,
    SCHEDULES,
    SEQ,
    TR,
    CPU_XEON_6226R,
    GPU_RTX_A6000,
    ComputeModel,
    LayerProfile,
    ModelProfile,
    cuts_from_segments,
    effective_microbatches,
    even_split,
    segments_from_sizes,
    tpu_group_compute_model,
    validate_segments,
)
from .dfts import dfts
from .engine import (
    PORTFOLIO_DEFAULT_MEMBERS,
    SolverInfo,
    deprecated_solver_alias,
    ensure_solver_supported,
    get_solver,
    portfolio_solve,
    register_solver,
    solve,
    solve_batch,
    solver_capabilities,
    solver_names,
    solver_supports,
    unregister_solver,
)
from .network import LinkSpec, NodeSpec, PhysicalNetwork, transmission_time_s
from .plan import (EvalCache, LatencyBreakdown, Plan, PlanEvaluator,
                   ServiceChainRequest)
from .problem import (FEASIBLE, INFEASIBLE, OPTIMAL, STATUSES, ProblemInstance,
                      SolveOutcome, SolveResult)
from .resnet101_profile import resnet101_profile
from .segmentation import k_sequence_segmentation
from .topology import candidate_sets, nsfnet, random_network, tpu_pod_topology
from .trainpipe import (evaluate_round_trip, round_trip_bottleneck_s,
                        round_trip_stage_times, round_trip_taus,
                        segment_comp_dir_s)

# Legacy flat entry points: thin deprecated shims over the registry.  They
# keep the historical `(net, profile, request, K, candidates, **kwargs)`
# signature and return bit-for-bit the same plans as `solve(...)`; importing
# the solver *modules* (repro.core.bcd, ...) keeps the undeprecated
# implementations for code that needs them.
bcd_solve = deprecated_solver_alias("bcd", "bcd_solve")
exact_solve = deprecated_solver_alias("exact", "exact_solve")
ilp_solve = deprecated_solver_alias("ilp", "ilp_solve")
comp_ms_solve = deprecated_solver_alias("comp-ms", "comp_ms_solve")
comm_ms_solve = deprecated_solver_alias("comm-ms", "comm_ms_solve")

# Legacy registry view: name -> registered solve function.  Derived from the
# engine registry in this one place; new code should use `solve(...)` /
# `get_solver(...)`, which also see solvers registered after import.
SOLVERS = {name: get_solver(name).fn for name in solver_names()}

__all__ = [
    "BW", "FW", "IF", "TR", "SEQ", "PIPE", "SCHEDULES", "effective_microbatches",
    "CPU_XEON_6226R", "GPU_RTX_A6000", "ComputeModel",
    "EvalCache", "LayerProfile", "ModelProfile", "LatencyBreakdown",
    "Plan", "PlanEvaluator", "ServiceChainRequest",
    "OPTIMAL", "FEASIBLE", "INFEASIBLE", "STATUSES",
    "ProblemInstance", "SolveOutcome", "SolveResult", "SolverInfo",
    "register_solver", "unregister_solver", "solve", "solve_batch",
    "solver_names",
    "solver_supports", "ensure_solver_supported", "get_solver",
    "solver_capabilities", "portfolio_solve", "PORTFOLIO_DEFAULT_MEMBERS",
    "LinkSpec", "NodeSpec", "PhysicalNetwork", "SOLVERS",
    "bcd_solve", "exact_solve", "ilp_solve", "comp_ms_solve", "comm_ms_solve",
    "dfts", "k_sequence_segmentation",
    "candidate_sets", "nsfnet", "random_network", "tpu_pod_topology",
    "resnet101_profile",
    "even_split", "segments_from_sizes", "cuts_from_segments", "validate_segments",
    "transmission_time_s", "tpu_group_compute_model",
    "evaluate_round_trip", "round_trip_bottleneck_s", "round_trip_stage_times",
    "round_trip_taus", "segment_comp_dir_s",
]
