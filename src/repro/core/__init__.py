"""Core — the paper's contribution: joint optimization of model splitting,
placement, and chaining for SFC-based multi-hop split learning/inference.

Solvers:
  * `ilp_solve`   — faithful MILP of Eqs. (1)-(15), HiGHS branch-and-bound (exact).
  * `exact_solve` — provably equivalent joint DP (fast optimal oracle).
  * `bcd_solve`   — the paper's BCD heuristic (Alg. 1: K-seq segmentation + DFTS).
  * `comp_ms_solve` / `comm_ms_solve` — the paper's comparison schemes.
"""
from .baselines import comm_ms_solve, comp_ms_solve
from .bcd import SolveResult, bcd_solve
from .costmodel import (
    BW,
    FW,
    IF,
    PIPE,
    SCHEDULES,
    SEQ,
    TR,
    CPU_XEON_6226R,
    GPU_RTX_A6000,
    ComputeModel,
    LayerProfile,
    ModelProfile,
    cuts_from_segments,
    effective_microbatches,
    even_split,
    segments_from_sizes,
    tpu_group_compute_model,
    validate_segments,
)
from .dfts import dfts
from .exact import exact_solve
from .ilp import ilp_solve
from .network import LinkSpec, NodeSpec, PhysicalNetwork, transmission_time_s
from .plan import (EvalCache, LatencyBreakdown, Plan, PlanEvaluator,
                   ServiceChainRequest)
from .resnet101_profile import resnet101_profile
from .segmentation import k_sequence_segmentation
from .topology import candidate_sets, nsfnet, random_network, tpu_pod_topology

# The one solver registry: name -> solve function with the uniform signature
# (net, profile, request, K, candidates, cache=..., **kwargs).  The sweep and
# serve layers both resolve solver names here.
SOLVERS = {
    "ilp": ilp_solve,
    "exact": exact_solve,
    "bcd": bcd_solve,
    "comp-ms": comp_ms_solve,
    "comm-ms": comm_ms_solve,
}

__all__ = [
    "BW", "FW", "IF", "TR", "SEQ", "PIPE", "SCHEDULES", "effective_microbatches",
    "CPU_XEON_6226R", "GPU_RTX_A6000", "ComputeModel",
    "EvalCache", "LayerProfile", "ModelProfile", "LatencyBreakdown",
    "Plan", "PlanEvaluator", "ServiceChainRequest", "SolveResult",
    "LinkSpec", "NodeSpec", "PhysicalNetwork", "SOLVERS",
    "bcd_solve", "exact_solve", "ilp_solve", "comp_ms_solve", "comm_ms_solve",
    "dfts", "k_sequence_segmentation",
    "candidate_sets", "nsfnet", "random_network", "tpu_pod_topology",
    "resnet101_profile",
    "even_split", "segments_from_sizes", "cuts_from_segments", "validate_segments",
    "transmission_time_s", "tpu_group_compute_model",
]
