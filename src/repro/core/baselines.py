"""Comparison schemes COMP-MS and COMM-MS (paper Sec. VI-A3).

Both are two-step: (1) choose the splitting y* minimizing only computation
(COMP-MS) or only communication (COMM-MS) overhead, ignoring placement and
chaining; (2) solve placement + chaining for the fixed y*.  Step 2 in the paper
is an ILP; given y the DFTS stage-DP is provably optimal (no link capacities), so
we use it — equivalent results, faster.
"""
from __future__ import annotations

import time

from .costmodel import PIPE, SEQ, ModelProfile, dirs_for_mode
from .dfts import dfts
from .engine import register_solver
from .network import PhysicalNetwork
from .plan import EvalCache, PlanEvaluator, ServiceChainRequest
from .problem import SolveResult

INF = float("inf")


def _dp_split(L: int, K: int, segcost) -> list[tuple[int, int]] | None:
    """Generic min-sum contiguous K-segmentation: segcost(k, lo, hi) -> float."""
    dp = [[INF] * (L + 1) for _ in range(K + 1)]
    choice = [[-1] * (L + 1) for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        dp[1][e] = segcost(0, 1, e)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                if dp[k - 1][e2] == INF:
                    continue
                c = dp[k - 1][e2] + segcost(k - 1, e2 + 1, e)
                if c < dp[k][e]:
                    dp[k][e] = c
                    choice[k][e] = e2
    if dp[K][L] == INF:
        return None
    cuts, e = [], L
    for k in range(K, 1, -1):
        e = choice[k][e]
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments


def _fits_some_candidate(ev: PlanEvaluator, cand: list[str], lo: int, hi: int) -> bool:
    return any(ev.segment_fits(i, lo, hi) for i in cand)


def _balance_tiebreak(profile: ModelProfile, lo: int, hi: int) -> float:
    """Tiny secondary objective: both step-1 ILPs in the paper have massive tie
    sets (homogeneous GPUs + linear kappa / equal-size cut groups); Gurobi breaks
    them arbitrarily, we break them toward memory-balanced segments so step 2
    stays feasible (the paper's step 2 is feasible for every K it plots)."""
    frac = profile.seg_mem_bytes(lo, hi) / max(1.0, profile.seg_mem_bytes(1, profile.L))
    return 1e-9 * frac * frac


def comp_ms_split(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
) -> list[tuple[int, int]] | None:
    """Computation-oriented splitting: minimize total compute delay assuming each
    stage runs on its *fastest* candidate (the endpoints are pinned, so the
    source-CPU penalty is respected, reproducing the paper's 'only layer 1 on the
    CPU' behaviour).  Segments that fit no candidate of V^k are infeasible
    (constraints (14)-(15) are part of the paper's step-1 ILP)."""
    b = request.batch_size
    ev = PlanEvaluator(net, profile, request)

    def stage_comp(k: int, lo: int, hi: int) -> float:
        if not _fits_some_candidate(ev, candidates[k], lo, hi):
            return INF
        best = INF
        for i in candidates[k]:
            cm = net.nodes[i].compute
            c = sum(
                cm.comp_time_s(b, profile.seg_flops(lo, hi, d))
                for d in dirs_for_mode(request.mode)
            )
            best = min(best, c)
        return best + _balance_tiebreak(profile, lo, hi)

    return _dp_split(profile.L, K, stage_comp)


def comm_ms_split(
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    net: PhysicalNetwork | None = None,
    candidates: list[list[str]] | None = None,
) -> list[tuple[int, int]] | None:
    """Communication-oriented splitting: minimize the cumulative smashed-data size
    over the K-1 cuts (FW, plus BW when training)."""
    ev = PlanEvaluator(net, profile, request) if net is not None else None

    def seg_comm(k: int, lo: int, hi: int) -> float:
        if ev is not None and candidates is not None:
            if not _fits_some_candidate(ev, candidates[k], lo, hi):
                return INF
        comm = 0.0
        if hi < profile.L:  # last segment ships nothing (psi_K = 0)
            comm = sum(profile.cut_bytes(hi, d) for d in dirs_for_mode(request.mode))
        return comm + _balance_tiebreak(profile, lo, hi)

    return _dp_split(profile.L, K, seg_comm)


def comp_balance_split(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """Compute-balanced splitting: each stage costed at its *fastest* feasible
    candidate, with a quadratic penalty so the DP balances stage times instead
    of summing them — a minimax surrogate expressible in the min-sum DP.  Used
    as the pipelined BCD's second initialization: the pipeline bottleneck
    rewards balanced stages, which the even/min-sum splits don't target."""
    ev = PlanEvaluator(net, profile, request, cache=cache)

    def stage_cost(k: int, lo: int, hi: int) -> float:
        best = INF
        for i in candidates[k]:
            if ev.segment_fits(i, lo, hi):
                best = min(best, ev.segment_comp_s(i, lo, hi))
        if best == INF:
            return INF
        return best * best

    return _dp_split(profile.L, K, stage_cost)


def min_memory_split(
    profile: ModelProfile, request: ServiceChainRequest, K: int
) -> list[tuple[int, int]] | None:
    """Capacity-aware fallback initial split: minimize sum of per-segment memory
    loads (params + b * peak smashed), which spreads heavy segments."""

    def seg_mem(k: int, lo: int, hi: int) -> float:
        m = profile.seg_mem_bytes(lo, hi)
        m += request.batch_size * profile.seg_peak_smashed(lo, hi, request.mode)
        return m * m  # quadratic penalty balances instead of piling up

    return _dp_split(profile.L, K, seg_mem)


def _two_step(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    segments: list[tuple[int, int]] | None,
    name: str,
    cache: EvalCache | None = None,
) -> SolveResult:
    t0 = time.perf_counter()
    if segments is None:
        return SolveResult(None, None, time.perf_counter() - t0, solver=name)
    plan = dfts(net, profile, request, segments, candidates, cache=cache)
    if plan is None:
        return SolveResult(None, None, time.perf_counter() - t0, solver=name)
    ev = PlanEvaluator(net, profile, request, cache=cache)
    return SolveResult(plan, ev.evaluate(plan), time.perf_counter() - t0, 1,
                       solver=name)


@register_solver("comp-ms", schedules=(SEQ, PIPE),
                 description="paper comparison scheme: computation-oriented "
                             "split, then schedule-aware DFTS")
def comp_ms_solve(net, profile, request, K, candidates,
                  cache: EvalCache | None = None) -> SolveResult:
    segs = comp_ms_split(net, profile, request, K, candidates)
    return _two_step(net, profile, request, K, candidates, segs, "comp-ms", cache)


@register_solver("comm-ms", schedules=(SEQ, PIPE),
                 description="paper comparison scheme: communication-oriented "
                             "split, then schedule-aware DFTS")
def comm_ms_solve(net, profile, request, K, candidates,
                  cache: EvalCache | None = None) -> SolveResult:
    segs = comm_ms_split(profile, request, K, net, candidates)
    return _two_step(net, profile, request, K, candidates, segs, "comm-ms", cache)
