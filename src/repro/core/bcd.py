"""Block Coordinate Descent heuristic (paper Alg. 1).

Alternates (1) model splitting via K-sequence segmentation DP and (2) model
placement + chaining via DFTS until the objective change is <= eps.  BCD is not
guaranteed to reach the global optimum (Sec. V-D) but converges monotonically:
each half-step is an exact minimization of its block with the other fixed.

Schedule-aware: for pipelined requests both blocks minimize the pipelined
objective (their dispatchers route to the capped-bottleneck variants), and the
result is *anchored* against the sequential-objective BCD solution — the
pipelined schedule can always execute the seq-optimized plan, so we return
whichever plan has the lower pipelined latency.  This guarantees
BCD-pipe latency <= pipe-eval(BCD-seq plan) <= BCD-seq latency for every
instance (the suite-level "pipe <= seq" invariant), even if the two heuristic
trajectories reach different coordinate-wise optima.
"""
from __future__ import annotations

import time
from dataclasses import replace

from .costmodel import PIPE, SEQ, ModelProfile, even_split
from .dfts import dfts
from .engine import register_solver
from .network import PhysicalNetwork
from .plan import (EvalCache, Plan, PlanEvaluator, ServiceChainRequest)
from .problem import SolveResult  # re-exported: legacy import site
from .segmentation import k_sequence_segmentation


def _alternate(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    candidates: list[list[str]],
    ev: PlanEvaluator,
    cache: EvalCache,
    segments: list[tuple[int, int]],
    eps: float,
    max_iters: int,
) -> tuple[Plan | None, float, list[float], int]:
    """One BCD trajectory (Alg. 1 lines 5-11) from the initial split
    ``segments``: DFTS for x_0, then alternate the two exact block
    minimizations.  Returns (plan, latency, history, iterations)."""
    plan = dfts(net, profile, request, segments, candidates, cache=cache)
    if plan is None:
        return None, float("inf"), [], 0
    prev = ev.latency_s(plan)
    history = [prev]
    iters = 0
    for iters in range(1, max_iters + 1):
        new_segments = k_sequence_segmentation(net, profile, request, plan,
                                               cache=cache)
        if new_segments is None:
            break
        new_plan = dfts(net, profile, request, new_segments, candidates,
                        cache=cache)
        if new_plan is None:
            break
        cur = ev.latency_s(new_plan)
        plan = new_plan
        history.append(cur)
        if abs(cur - prev) <= eps:
            prev = cur
            break
        prev = cur
    return plan, prev, history, iters


@register_solver("bcd", schedules=(SEQ, PIPE),
                 description="paper Alg. 1 heuristic: alternate K-seq "
                             "segmentation and DFTS; monotone, seq-anchored "
                             "under pipe")
def bcd_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    eps: float = 0.0,
    max_iters: int = 50,
    cache: EvalCache | None = None,
) -> SolveResult:
    t0 = time.perf_counter()
    cache = cache if cache is not None else EvalCache()
    ev = PlanEvaluator(net, profile, request, cache=cache)
    pipelined = request.schedule == PIPE and request.microbatches() > 1

    # initialization (Alg. 1 lines 1-4): even split y_0, then DFTS for x_0.
    segments = even_split(profile.L, K)
    plan, prev, history, iters = _alternate(net, profile, request, candidates,
                                            ev, cache, segments, eps, max_iters)
    if plan is None:
        # The even split y_0 may itself violate (14)-(15) everywhere.  Fall back
        # to a capacity-aware initial split: minimize the per-segment peak memory
        # (min over placements) via the same DP machinery with a greedy balance.
        from .baselines import min_memory_split  # local import avoids a cycle

        segments = min_memory_split(profile, request, K)
        if segments is not None:
            plan, prev, history, iters = _alternate(
                net, profile, request, candidates, ev, cache, segments, eps,
                max_iters)
    if plan is None:
        return SolveResult(None, None, time.perf_counter() - t0, 0)

    if pipelined:
        # Second start from a compute-balanced split: the pipeline bottleneck
        # rewards balanced stages, a shape the even split's trajectory often
        # cannot reach by coordinate descent alone.
        from .baselines import comp_balance_split  # local import avoids a cycle

        bal = comp_balance_split(net, profile, request, K, candidates,
                                 cache=cache)
        if bal is not None and bal != segments:
            plan2, prev2, history2, iters2 = _alternate(
                net, profile, request, candidates, ev, cache, bal, eps,
                max_iters)
            if plan2 is not None and prev2 < prev:
                plan, prev, history, iters = plan2, prev2, history2, iters2

        # Seq-anchor: the pipelined schedule can always run the plan the
        # sequential-objective BCD found; keep whichever is better under the
        # pipelined objective (see module docstring).
        seq_req = replace(request, schedule=SEQ, n_microbatches=1)
        seq_res = bcd_solve(net, profile, seq_req, K, candidates, eps=eps,
                            max_iters=max_iters, cache=cache)
        if seq_res.plan is not None:
            anchor = ev.latency_s(seq_res.plan)
            if anchor < prev:
                plan, prev = seq_res.plan, anchor
                history.append(anchor)

    return SolveResult(plan, ev.evaluate(plan), time.perf_counter() - t0, iters,
                       history, solver="bcd")
