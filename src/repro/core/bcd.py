"""Block Coordinate Descent heuristic (paper Alg. 1).

Alternates (1) model splitting via K-sequence segmentation DP and (2) model
placement + chaining via DFTS until the objective change is <= eps.  BCD is not
guaranteed to reach the global optimum (Sec. V-D) but converges monotonically:
each half-step is an exact minimization of its block with the other fixed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .costmodel import ModelProfile, even_split
from .dfts import dfts
from .network import PhysicalNetwork
from .plan import (EvalCache, LatencyBreakdown, Plan, PlanEvaluator,
                   ServiceChainRequest)
from .segmentation import k_sequence_segmentation


@dataclass
class SolveResult:
    plan: Plan | None
    latency: LatencyBreakdown | None
    wall_time_s: float
    iterations: int = 0
    history: list[float] = field(default_factory=list)
    solver: str = "bcd"

    @property
    def feasible(self) -> bool:
        return self.plan is not None

    @property
    def latency_s(self) -> float:
        return self.latency.total_s if self.latency else float("inf")


def bcd_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    eps: float = 0.0,
    max_iters: int = 50,
    cache: EvalCache | None = None,
) -> SolveResult:
    t0 = time.perf_counter()
    cache = cache if cache is not None else EvalCache()
    ev = PlanEvaluator(net, profile, request, cache=cache)

    # initialization (Alg. 1 lines 1-4): even split y_0, then DFTS for x_0.
    segments = even_split(profile.L, K)
    plan = dfts(net, profile, request, segments, candidates, cache=cache)
    if plan is None:
        # The even split y_0 may itself violate (14)-(15) everywhere.  Fall back
        # to a capacity-aware initial split: minimize the per-segment peak memory
        # (min over placements) via the same DP machinery with a greedy balance.
        from .baselines import min_memory_split  # local import avoids a cycle

        segments = min_memory_split(profile, request, K)
        if segments is not None:
            plan = dfts(net, profile, request, segments, candidates, cache=cache)
    if plan is None:
        return SolveResult(None, None, time.perf_counter() - t0, 0)

    prev = ev.latency_s(plan)
    history = [prev]
    iters = 0
    for iters in range(1, max_iters + 1):
        new_segments = k_sequence_segmentation(net, profile, request, plan,
                                               cache=cache)
        if new_segments is None:
            break
        new_plan = dfts(net, profile, request, new_segments, candidates,
                        cache=cache)
        if new_plan is None:
            break
        cur = ev.latency_s(new_plan)
        plan = new_plan
        history.append(cur)
        if abs(cur - prev) <= eps:
            prev = cur
            break
        prev = cur
    return SolveResult(plan, ev.evaluate(plan), time.perf_counter() - t0, iters,
                       history, solver="bcd")
