"""Layer-wise cost model: the paper's (rho, delta, r) vectors and device models.

Units (internal, everywhere in this package):
  - FLOPs: floating point operations per *sample* (rho^FW, rho^BW).
  - delta: smashed-data size in *bytes per sample* crossing the cut after layer l
    (delta^FW activations, delta^BW gradients).
  - r_mem / r_disk: bytes per layer.
  - time: seconds.

The paper's Table II constants (alpha_k, beta_k, alpha_tau, beta_tau) were fitted
with time in *milliseconds*:  kappa_ms(b, phi) = (alpha_k * b + beta_k) * phi,
tau_ms(b) = alpha_tau * b + beta_tau.  We verified this against the paper's worked
examples (Fig. 6a: kappa_CPU(2, 105.3e9) = 25.8 -> printed 25.7 ms; kappa_GPU(2,
131.56e9) = 3.3 -> printed 3.4 ms), so `ComputeModel` converts to seconds.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Sequence

FW = "FW"
BW = "BW"
IF = "IF"  # inference mode
TR = "TR"  # training mode

# Execution schedules.  "seq" is the paper's model: sub-model k+1 starts only
# after sub-model k finished and its smashed data fully arrived.  "pipe" splits
# the batch into n_microbatches that flow through the placed chain like a
# pipeline (Wei et al., arXiv:2505.04368): end-to-end latency becomes pipeline
# fill/drain plus (M-1) steady-state bottleneck-stage steps (docs/pipeline.md).
SEQ = "seq"
PIPE = "pipe"
SCHEDULES = (SEQ, PIPE)


def effective_microbatches(batch_size: int, n_microbatches: int) -> int:
    """Clamp the microbatch count to [1, b]: a microbatch carries >= 1 sample,
    so a b-sample batch pipelines at most b-deep.  M=1 is exactly the
    sequential schedule."""
    return max(1, min(int(n_microbatches), int(batch_size)))


def dirs_for_mode(mode: str) -> tuple[str, ...]:
    """D(mode) in the paper: {FW} for inference, {FW, BW} for training."""
    if mode == TR:
        return (FW, BW)
    if mode == IF:
        return (FW,)
    raise ValueError(f"unknown mode {mode!r}")


@dataclass(frozen=True)
class ComputeModel:
    """Piecewise-linear device compute model (paper Sec. VI-A2, Table II).

    ``pieces`` is a tuple of (b_max, alpha_k, beta_k) segments: the first segment
    with ``b <= b_max`` applies.  kappa/tau yield **seconds** (constants are the
    paper's ms-fitted values; we divide by 1e3).
    """

    name: str
    pieces: tuple[tuple[float, float, float], ...]
    alpha_tau: float = 0.0
    beta_tau: float = 0.0

    def _coeffs(self, b: float) -> tuple[float, float]:
        for b_max, a, beta in self.pieces:
            if b <= b_max:
                return a, beta
        raise AssertionError("pieces must end with b_max=inf")

    def kappa_s(self, b: float, flops: float) -> float:
        """Compute time (s) for `flops` per-sample FLOPs at batch size b."""
        a, beta = self._coeffs(b)
        return max(0.0, (a * b + beta) * flops) / 1e3

    def tau_s(self, b: float) -> float:
        """Device I/O overhead (s); zero for CPU nodes per the paper."""
        return max(0.0, (self.alpha_tau * b + self.beta_tau)) / 1e3

    def comp_time_s(self, b: float, flops: float) -> float:
        """T^comp = kappa_i(b, phi) + tau_i(b)   (Eq. 17)."""
        return self.kappa_s(b, flops) + self.tau_s(b)


# Paper Table II -----------------------------------------------------------------
CPU_XEON_6226R = ComputeModel(
    name="cpu-xeon-6226r",
    pieces=((8, 1.04e-10, 3.74e-11), (math.inf, 2.07e-10, -1.60e-9)),
    alpha_tau=0.0,
    beta_tau=0.0,
)
GPU_RTX_A6000 = ComputeModel(
    name="gpu-rtx-a6000",
    pieces=((math.inf, 3.94e-12, 1.72e-11),),
    alpha_tau=2.07e-13,
    beta_tau=1.69e-13,
)


def tpu_group_compute_model(
    chips: int,
    peak_flops: float = 197e12,
    mfu: float = 0.5,
    dispatch_overhead_s: float = 5e-6,
) -> ComputeModel:
    """TPU-native adaptation: a stage *group* of `chips` v5e chips as one planner node.

    kappa(b, phi) = b * phi / (chips * peak * mfu)  =>  alpha_k(ms/FLOP) = 1e3 /
    (chips*peak*mfu), beta_k = 0.  tau models per-step dispatch overhead.
    """
    alpha = 1e3 / (chips * peak_flops * mfu)
    return ComputeModel(
        name=f"tpu-v5e-x{chips}",
        pieces=((math.inf, alpha, 0.0),),
        alpha_tau=0.0,
        beta_tau=dispatch_overhead_s * 1e3,
    )


@dataclass(frozen=True)
class LayerProfile:
    """One global-model layer l: (rho_l^FW, rho_l^BW, delta_l^FW, delta_l^BW, r_l)."""

    name: str
    flops_fw: float  # rho^FW, per sample
    flops_bw: float  # rho^BW, per sample
    act_bytes: float  # delta^FW: smashed-data size emitted AFTER this layer, per sample
    grad_bytes: float  # delta^BW
    mem_bytes: float  # r^mem
    disk_bytes: float  # r^disk

    def flops(self, direction: str) -> float:
        return self.flops_fw if direction == FW else self.flops_bw

    def smashed_bytes(self, direction: str) -> float:
        return self.act_bytes if direction == FW else self.grad_bytes


@dataclass
class ModelProfile:
    """The planner's view of a global model F: an ordered list of L layers.

    Segment aggregates are served from lazily-built prefix-sum tables so the
    O(K L^2) solver DPs pay O(1) per segment query instead of O(L).  The layer
    list must not be mutated after the first query; call :meth:`invalidate_cache`
    if you do.
    """

    model_id: str
    layers: list[LayerProfile]
    _cum: dict | None = field(default=None, init=False, repr=False, compare=False)
    _peak_memo: dict = field(default_factory=dict, init=False, repr=False,
                             compare=False)
    _content_key: str | None = field(default=None, init=False, repr=False,
                                     compare=False)

    def __post_init__(self) -> None:
        if len(self.layers) < 2:
            raise ValueError("a splittable model needs at least 2 layers")

    @property
    def L(self) -> int:
        return len(self.layers)

    def invalidate_cache(self) -> None:
        """Drop the prefix-sum tables after mutating ``layers`` in place."""
        self._cum = None
        self._peak_memo.clear()
        self._content_key = None

    def content_key(self) -> str:
        """Canonical serialization of the profile's content (model_id + the
        full layer cost table) — the profile half of ProblemInstance identity.
        Cached; dropped by :meth:`invalidate_cache`."""
        if self._content_key is None:
            self._content_key = json.dumps(
                [self.model_id,
                 [[l.name, l.flops_fw, l.flops_bw, l.act_bytes, l.grad_bytes,
                   l.mem_bytes, l.disk_bytes] for l in self.layers]],
                separators=(",", ":"))
        return self._content_key

    def _cumsums(self) -> dict:
        if self._cum is None:
            def cum(vals: list[float]) -> list[float]:
                out = [0.0] * (len(vals) + 1)
                for i, v in enumerate(vals):
                    out[i + 1] = out[i] + v
                return out

            self._cum = {
                (FW, "flops"): cum([l.flops_fw for l in self.layers]),
                (BW, "flops"): cum([l.flops_bw for l in self.layers]),
                "mem": cum([l.mem_bytes for l in self.layers]),
                "disk": cum([l.disk_bytes for l in self.layers]),
            }
        return self._cum

    # --- segment aggregates (segments are 1-indexed inclusive [lo, hi]) ----------
    def seg_flops(self, lo: int, hi: int, direction: str) -> float:
        c = self._cumsums()[(direction, "flops")]
        return c[hi] - c[lo - 1]

    def seg_mem_bytes(self, lo: int, hi: int) -> float:
        c = self._cumsums()["mem"]
        return c[hi] - c[lo - 1]

    def seg_disk_bytes(self, lo: int, hi: int) -> float:
        c = self._cumsums()["disk"]
        return c[hi] - c[lo - 1]

    def seg_peak_smashed(self, lo: int, hi: int, mode: str) -> float:
        """max_{l in seg, dir in D(mode)} delta_l^dir  (constraint (15) 2nd term)."""
        key = (lo, hi, mode)
        peak = self._peak_memo.get(key)
        if peak is None:
            peak = 0.0
            for l in self.layers[lo - 1 : hi]:
                for d in dirs_for_mode(mode):
                    peak = max(peak, l.smashed_bytes(d))
            self._peak_memo[key] = peak
        return peak

    def cut_bytes(self, cut_after: int, direction: str) -> float:
        """delta at the cut after layer `cut_after` (1 <= cut_after <= L-1)."""
        assert 1 <= cut_after < self.L
        return self.layers[cut_after - 1].smashed_bytes(direction)

    def total_flops(self, direction: str) -> float:
        return self.seg_flops(1, self.L, direction)


def segments_from_sizes(sizes: Sequence[int]) -> list[tuple[int, int]]:
    """(L^1..L^K) -> 1-indexed inclusive [lo, hi] ranges."""
    segs, lo = [], 1
    for n in sizes:
        if n < 1:
            raise ValueError("each sub-model must hold >= 1 layer (constraint (10))")
        segs.append((lo, lo + n - 1))
        lo += n
    return segs


def even_split(L: int, K: int) -> list[tuple[int, int]]:
    """BCD initialization y_0: evenly divide L layers into K sub-models."""
    base, rem = divmod(L, K)
    sizes = [base + (1 if k < rem else 0) for k in range(K)]
    return segments_from_sizes(sizes)


def cuts_from_segments(segments: Sequence[tuple[int, int]]) -> list[int]:
    """Cut positions: layer index after which each of the first K-1 segments ends."""
    return [hi for (_, hi) in segments[:-1]]


def validate_segments(segments: Sequence[tuple[int, int]], L: int) -> None:
    """Constraints (6)-(13): contiguous, ordered, covering partition of 1..L."""
    if not segments:
        raise ValueError("empty segmentation")
    if segments[0][0] != 1:
        raise ValueError("constraint (7): first layer must be in sub-model 1")
    if segments[-1][1] != L:
        raise ValueError("constraint (8): last layer must be in sub-model K")
    prev_hi = 0
    for lo, hi in segments:
        if lo != prev_hi + 1:
            raise ValueError("constraints (12)-(13): segments must be contiguous & ordered")
        if hi < lo:
            raise ValueError("constraint (10): each sub-model holds >= 1 layer")
        prev_hi = hi
