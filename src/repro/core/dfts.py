"""DFTS — shortest path tour search for model placement + chaining given a fixed
splitting y (paper Sec. V-C, [22], [24]).

Implemented as the layered-graph / stage-wise search over the modified augmented
network: stage k expands every candidate i in V^k by charging the imaginary-link
cost c^k_{i, v_hat_ik} (compute, Eq. (17), FW + BW if training) and physical-link
costs c^k_{i,j} (Sec. V-C) that depend on the smashed-data size of the preceding
cut.  This attains the optimal placement + chaining for the given y because the
formulation has no link-capacity coupling between subpaths — each subpath is
independently a shortest path.

Stage relaxation is the min-composition of *cached* single-source frontiers
(`PhysicalNetwork.sssp`): dist_k(i) = min_{j in stage k-1} best[j] + sp_j(i),
which is exactly the multi-source Dijkstra result but lets every frontier be
reused across BCD iterations, schemes, seeds, and sweep grid points that share
the (network, smashed-data size) pair.  Complexity O((K+1) S E log V) cold with
S = |V^k| sources per stage (S <= 2 in the paper's scenarios), O((K+1) S V)
warm, matching the paper's Sec. V-D up to the candidate-set factor.
"""
from __future__ import annotations

import numpy as np

from .costmodel import BW, FW, TR, ModelProfile
from .network import PhysicalNetwork
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest

INF = float("inf")


def _backtrack(parent: dict[str, str | None], end: str, sources: set[str]) -> list[str]:
    path, cur = [end], end
    while cur not in sources:
        cur = parent[cur]
        assert cur is not None, "broken parent chain"
        path.append(cur)
    return path[::-1]


def _relax_stage_scalar(
    net: PhysicalNetwork,
    best: dict[str, float],
    fw_bytes: float,
    bw_bytes: float | None,
    targets: list[str],
) -> dict[str, tuple[float, str]]:
    """Reference scalar relaxation: per-target min over cached frontier dicts.
    Kept as the equivalence oracle for `_relax_stage` (tests assert bit-for-bit
    agreement); the hot path below vectorizes the same min-plus composition."""
    frontiers = {s: net.sssp(s, fw_bytes, bw_bytes) for s in best}
    out: dict[str, tuple[float, str]] = {}
    for t in targets:
        bd, bs = INF, None
        for s, d0 in best.items():
            d = d0 + frontiers[s][0][t]
            if d < bd:
                bd, bs = d, s
        if bs is not None:
            out[t] = (bd, bs)
    return out


def _relax_stage(
    net: PhysicalNetwork,
    best: dict[str, float],
    fw_bytes: float,
    bw_bytes: float | None,
    targets: list[str],
) -> dict[str, tuple[float, str]]:
    """target -> (dist, argmin source) as a vectorized min-plus composition.

    dist = (d0[:, None] + D)[.., targets].min(axis=0) over the network's dense
    [S, V] frontier matrix D (`PhysicalNetwork.frontier_matrix`), which is
    cached per (sources, smashed-data size) and therefore shared across every
    relaxation of an admission round / BCD iteration.  Bit-for-bit identical
    to `_relax_stage_scalar`: same additions in the same source order, and
    `argmin` picks the first minimal source exactly like the scalar scan.
    """
    if not targets:
        return {}
    srcs = tuple(best)
    D = net.frontier_matrix(srcs, fw_bytes, bw_bytes)
    idx = net.node_index()
    cols = [idx[t] for t in targets]
    comp = np.asarray([best[s] for s in srcs])[:, None] + D[:, cols]  # [S, T]
    amin = np.argmin(comp, axis=0)
    out: dict[str, tuple[float, str]] = {}
    for j, t in enumerate(targets):
        d = comp[amin[j], j]
        if d < INF:
            out[t] = (float(d), srcs[amin[j]])
    return out


def _stage_path(net: PhysicalNetwork, src: str, dst: str, fw_bytes: float,
                bw_bytes: float | None) -> list[str]:
    _, parent = net.sssp(src, fw_bytes, bw_bytes)
    return _backtrack(parent, dst, {src})


def dfts(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> Plan | None:
    """Optimal placement + chaining for fixed segments.  Returns None if every
    placement is capacity-infeasible (imaginary links pruned, Sec. V-C)."""
    K = len(segments)
    assert len(candidates) == K
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    training = request.mode == TR

    # stage 1: enter F^1 at each feasible candidate (subpath S_1 is uncharged in
    # Eq. (16); the paper pins V^1 = {s}).
    best: dict[str, float] = {}
    pred_node: list[dict[str, str]] = [dict() for _ in range(K)]
    cut_sizes: list[tuple[float, float | None]] = [(0.0, None)] * K
    lo, hi = segments[0]
    for i in candidates[0]:
        if ev.segment_fits(i, lo, hi):
            best[i] = ev.segment_comp_s(i, lo, hi)
    if not best:
        return None

    for k in range(1, K):
        cut = segments[k - 1][1]
        fw_bytes = b * profile.cut_bytes(cut, FW)
        bw_bytes = b * profile.cut_bytes(cut, BW) if training else None
        cut_sizes[k] = (fw_bytes, bw_bytes)
        lo, hi = segments[k]
        feas = [i for i in candidates[k] if ev.segment_fits(i, lo, hi)]
        reached = _relax_stage(net, best, fw_bytes, bw_bytes, feas)
        nxt: dict[str, float] = {}
        for i, (dist, src) in reached.items():
            if dist < INF:
                nxt[i] = dist + ev.segment_comp_s(i, segments[k][0], segments[k][1])
                pred_node[k][i] = src
        if not nxt:
            return None
        best = nxt

    # tail subpath S_{K+1}: psi_K = 0, propagation-only (FW + BW if training).
    tail_bw = 0.0 if training else None
    reached = _relax_stage(net, best, 0.0, tail_bw, [request.destination])
    if request.destination not in reached or reached[request.destination][0] == INF:
        return None
    tail_src = reached[request.destination][1]
    tail = _stage_path(net, tail_src, request.destination, 0.0, tail_bw)

    # backtrack placement and subpaths
    placement = [""] * K
    placement[K - 1] = tail_src
    for k in range(K - 1, 0, -1):
        placement[k - 1] = pred_node[k][placement[k]]
    paths = [
        _stage_path(net, placement[k - 1], placement[k], *cut_sizes[k])
        for k in range(1, K)
    ]
    tail_path = tail if len(tail) > 1 else []
    return Plan(segments=list(segments), placement=placement, paths=paths,
                tail_path=tail_path)
