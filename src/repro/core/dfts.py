"""DFTS — shortest path tour search for model placement + chaining given a fixed
splitting y (paper Sec. V-C, [22], [24]).

Implemented as the layered-graph / stage-wise multi-source Dijkstra over the
modified augmented network: stage k expands every candidate i in V^k by charging
the imaginary-link cost c^k_{i, v_hat_ik} (compute, Eq. (17), FW + BW if training)
and physical-link costs c^k_{i,j} (Sec. V-C) that depend on the smashed-data size
of the preceding cut.  This attains the optimal placement + chaining for the given
y because the formulation has no link-capacity coupling between subpaths — each
subpath is independently a shortest path.  Complexity O((K+1) E log V), matching
the paper's Sec. V-D.
"""
from __future__ import annotations

from .costmodel import BW, FW, TR, ModelProfile
from .network import PhysicalNetwork
from .plan import Plan, PlanEvaluator, ServiceChainRequest

INF = float("inf")


def _backtrack(parent: dict[str, str | None], end: str, sources: set[str]) -> list[str]:
    path, cur = [end], end
    while cur not in sources:
        cur = parent[cur]
        assert cur is not None, "broken parent chain"
        path.append(cur)
    return path[::-1]


def dfts(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    candidates: list[list[str]],
) -> Plan | None:
    """Optimal placement + chaining for fixed segments.  Returns None if every
    placement is capacity-infeasible (imaginary links pruned, Sec. V-C)."""
    K = len(segments)
    assert len(candidates) == K
    ev = PlanEvaluator(net, profile, request)
    b = request.batch_size
    training = request.mode == TR

    # stage 1: enter F^1 at each feasible candidate (subpath S_1 is uncharged in
    # Eq. (16); the paper pins V^1 = {s}).
    best: dict[str, float] = {}
    entry_path: list[dict[str, list[str]]] = [dict() for _ in range(K)]
    pred_node: list[dict[str, str]] = [dict() for _ in range(K)]
    lo, hi = segments[0]
    for i in candidates[0]:
        if ev.segment_fits(i, lo, hi):
            best[i] = ev.segment_comp_s(i, lo, hi)
            entry_path[0][i] = [i]
    if not best:
        return None

    for k in range(1, K):
        cut = segments[k - 1][1]
        fw_bytes = b * profile.cut_bytes(cut, FW)
        bw_bytes = b * profile.cut_bytes(cut, BW) if training else None
        dist, parent = net.dijkstra(dict(best), fw_bytes, bw_bytes)
        lo, hi = segments[k]
        nxt: dict[str, float] = {}
        for i in candidates[k]:
            if dist[i] < INF and ev.segment_fits(i, lo, hi):
                nxt[i] = dist[i] + ev.segment_comp_s(i, lo, hi)
                path = _backtrack(parent, i, set(best))
                entry_path[k][i] = path
                pred_node[k][i] = path[0]
        if not nxt:
            return None
        best = nxt

    # tail subpath S_{K+1}: psi_K = 0, propagation-only (FW + BW if training).
    tail_bw = 0.0 if training else None
    dist, parent = net.dijkstra(dict(best), 0.0, tail_bw)
    if dist[request.destination] == INF:
        return None
    tail = _backtrack(parent, request.destination, set(best))

    # backtrack placement and subpaths
    placement = [""] * K
    placement[K - 1] = tail[0]
    for k in range(K - 1, 0, -1):
        placement[k - 1] = pred_node[k][placement[k]]
    paths = [entry_path[k][placement[k]] for k in range(1, K)]
    tail_path = tail if len(tail) > 1 else []
    return Plan(segments=list(segments), placement=placement, paths=paths,
                tail_path=tail_path)
