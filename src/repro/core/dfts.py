"""DFTS — shortest path tour search for model placement + chaining given a fixed
splitting y (paper Sec. V-C, [22], [24]).

Implemented as the layered-graph / stage-wise search over the modified augmented
network: stage k expands every candidate i in V^k by charging the imaginary-link
cost c^k_{i, v_hat_ik} (compute, Eq. (17), FW + BW if training) and physical-link
costs c^k_{i,j} (Sec. V-C) that depend on the smashed-data size of the preceding
cut.  This attains the optimal placement + chaining for the given y because the
formulation has no link-capacity coupling between subpaths — each subpath is
independently a shortest path.

Stage relaxation is the min-composition of *cached* single-source frontiers
(`PhysicalNetwork.sssp`): dist_k(i) = min_{j in stage k-1} best[j] + sp_j(i),
which is exactly the multi-source Dijkstra result but lets every frontier be
reused across BCD iterations, schemes, seeds, and sweep grid points that share
the (network, smashed-data size) pair.  Complexity O((K+1) S E log V) cold with
S = |V^k| sources per stage (S <= 2 in the paper's scenarios), O((K+1) S V)
warm, matching the paper's Sec. V-D up to the candidate-set factor.
"""
from __future__ import annotations

import numpy as np

from .costmodel import BW, FW, PIPE, TR, ModelProfile
from .network import PhysicalNetwork, transmission_time_s
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest
from .trainpipe import round_trip_taus, segment_comp_dir_s

INF = float("inf")


def _backtrack(parent: dict[str, str | None], end: str, sources: set[str]) -> list[str]:
    path, cur = [end], end
    while cur not in sources:
        cur = parent[cur]
        assert cur is not None, "broken parent chain"
        path.append(cur)
    return path[::-1]


def _relax_stage_scalar(
    net: PhysicalNetwork,
    best: dict[str, float],
    fw_bytes: float,
    bw_bytes: float | None,
    targets: list[str],
    trans_cap: float | None = None,
    trans_scale: float = 1.0,
    trans_cap_bw: float | None = None,
) -> dict[str, tuple[float, str]]:
    """Reference scalar relaxation: per-target min over cached frontier dicts.
    Kept as the equivalence oracle for `_relax_stage` (tests assert bit-for-bit
    agreement); the hot path below vectorizes the same min-plus composition."""
    frontiers = {s: net.sssp(s, fw_bytes, bw_bytes, trans_cap, trans_scale,
                             trans_cap_bw)
                 for s in best}
    out: dict[str, tuple[float, str]] = {}
    for t in targets:
        bd, bs = INF, None
        for s, d0 in best.items():
            d = d0 + frontiers[s][0][t]
            if d < bd:
                bd, bs = d, s
        if bs is not None:
            out[t] = (bd, bs)
    return out


def _relax_stage(
    net: PhysicalNetwork,
    best: dict[str, float],
    fw_bytes: float,
    bw_bytes: float | None,
    targets: list[str],
    trans_cap: float | None = None,
    trans_scale: float = 1.0,
    trans_cap_bw: float | None = None,
) -> dict[str, tuple[float, str]]:
    """target -> (dist, argmin source) as a vectorized min-plus composition.

    dist = (d0[:, None] + D)[.., targets].min(axis=0) over the network's dense
    [S, V] frontier matrix D (`PhysicalNetwork.frontier_matrix`), which is
    cached per (sources, smashed-data size) and therefore shared across every
    relaxation of an admission round / BCD iteration.  Bit-for-bit identical
    to `_relax_stage_scalar`: same additions in the same source order, and
    `argmin` picks the first minimal source exactly like the scalar scan.
    """
    if not targets:
        return {}
    srcs = tuple(best)
    D = net.frontier_matrix(srcs, fw_bytes, bw_bytes, trans_cap, trans_scale,
                            trans_cap_bw)
    idx = net.node_index()
    cols = [idx[t] for t in targets]
    comp = np.asarray([best[s] for s in srcs])[:, None] + D[:, cols]  # [S, T]
    amin = np.argmin(comp, axis=0)
    out: dict[str, tuple[float, str]] = {}
    for j, t in enumerate(targets):
        d = comp[amin[j], j]
        if d < INF:
            out[t] = (float(d), srcs[amin[j]])
    return out


def _stage_path(net: PhysicalNetwork, src: str, dst: str, fw_bytes: float,
                bw_bytes: float | None, trans_cap: float | None = None,
                trans_scale: float = 1.0,
                trans_cap_bw: float | None = None) -> list[str]:
    _, parent = net.sssp(src, fw_bytes, bw_bytes, trans_cap, trans_scale,
                         trans_cap_bw)
    return _backtrack(parent, dst, {src})


def dfts(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> Plan | None:
    """Optimal placement + chaining for fixed segments.  Returns None if every
    placement is capacity-infeasible (imaginary links pruned, Sec. V-C).

    Pipelined requests (schedule="pipe", M > 1) are routed to the
    bottleneck-capped tour search `_dfts_pipe`, which is exact for the
    pipelined objective fill + (M-1)*tau/M; pipelined *training* requests go
    through `_dfts_pipe_tr`, exact for the round-trip objective
    fill + (M-1)/M * (tau_fw + tau_bw) (docs/training.md)."""
    if request.schedule == PIPE and request.microbatches() > 1:
        if request.mode == TR:
            return _dfts_pipe_tr(net, profile, request, segments, candidates,
                                 cache)
        return _dfts_pipe(net, profile, request, segments, candidates, cache)
    K = len(segments)
    assert len(candidates) == K
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    training = request.mode == TR

    # stage 1: enter F^1 at each feasible candidate (subpath S_1 is uncharged in
    # Eq. (16); the paper pins V^1 = {s}).
    best: dict[str, float] = {}
    pred_node: list[dict[str, str]] = [dict() for _ in range(K)]
    cut_sizes: list[tuple[float, float | None]] = [(0.0, None)] * K
    lo, hi = segments[0]
    for i in candidates[0]:
        if ev.segment_fits(i, lo, hi):
            best[i] = ev.segment_comp_s(i, lo, hi)
    if not best:
        return None

    for k in range(1, K):
        cut = segments[k - 1][1]
        fw_bytes = b * profile.cut_bytes(cut, FW)
        bw_bytes = b * profile.cut_bytes(cut, BW) if training else None
        cut_sizes[k] = (fw_bytes, bw_bytes)
        lo, hi = segments[k]
        feas = [i for i in candidates[k] if ev.segment_fits(i, lo, hi)]
        reached = _relax_stage(net, best, fw_bytes, bw_bytes, feas)
        nxt: dict[str, float] = {}
        for i, (dist, src) in reached.items():
            if dist < INF:
                nxt[i] = dist + ev.segment_comp_s(i, segments[k][0], segments[k][1])
                pred_node[k][i] = src
        if not nxt:
            return None
        best = nxt

    # tail subpath S_{K+1}: psi_K = 0, propagation-only (FW + BW if training).
    tail_bw = 0.0 if training else None
    reached = _relax_stage(net, best, 0.0, tail_bw, [request.destination])
    if request.destination not in reached or reached[request.destination][0] == INF:
        return None
    tail_src = reached[request.destination][1]
    tail = _stage_path(net, tail_src, request.destination, 0.0, tail_bw)

    # backtrack placement and subpaths
    placement = [""] * K
    placement[K - 1] = tail_src
    for k in range(K - 1, 0, -1):
        placement[k - 1] = pred_node[k][placement[k]]
    paths = [
        _stage_path(net, placement[k - 1], placement[k], *cut_sizes[k])
        for k in range(1, K)
    ]
    tail_path = tail if len(tail) > 1 else []
    return Plan(segments=list(segments), placement=placement, paths=paths,
                tail_path=tail_path)


def _capped_tour(
    net: PhysicalNetwork,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    comp: list[dict[str, float]],
    cut_sizes: list[tuple[float, float | None]],
    cap: float | None,
    inv_M: float,
) -> Plan | None:
    """One bottleneck-capped tour: the sequential stage search with candidate
    nodes pruned to comp <= cap, links pruned to trans <= cap, and transmission
    scaled by 1/M — minimizes the pipeline *fill* among plans whose every stage
    fits under ``cap``."""
    K = len(segments)
    best = {i: c * inv_M for i, c in comp[0].items()
            if cap is None or c <= cap}
    if not best:
        return None
    pred_node: list[dict[str, str]] = [dict() for _ in range(K)]
    for k in range(1, K):
        fw_bytes, bw_bytes = cut_sizes[k]
        feas = [i for i, c in comp[k].items() if cap is None or c <= cap]
        reached = _relax_stage(net, best, fw_bytes, bw_bytes, feas, cap, inv_M)
        nxt: dict[str, float] = {}
        for i, (dist, src) in reached.items():
            if dist < INF:
                nxt[i] = dist + comp[k][i] * inv_M
                pred_node[k][i] = src
        if not nxt:
            return None
        best = nxt

    # The evaluator charges the psi_K = 0 tail in the FW direction only
    # (Eq. 16's S_{K+1}); the tour must use the same convention so its fill
    # equals the evaluator's and the cap-scan incumbent bound stays exact.
    tail_bw = None
    reached = _relax_stage(net, best, 0.0, tail_bw, [request.destination],
                           cap, inv_M)
    if request.destination not in reached:
        return None
    tail_src = reached[request.destination][1]
    tail = _stage_path(net, tail_src, request.destination, 0.0, tail_bw,
                       cap, inv_M)

    placement = [""] * K
    placement[K - 1] = tail_src
    for k in range(K - 1, 0, -1):
        placement[k - 1] = pred_node[k][placement[k]]
    paths = [
        _stage_path(net, placement[k - 1], placement[k], *cut_sizes[k],
                    cap, inv_M)
        for k in range(1, K)
    ]
    return Plan(segments=list(segments), placement=placement, paths=paths,
                tail_path=tail if len(tail) > 1 else [])


def _dfts_pipe(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> Plan | None:
    """Optimal placement + chaining for fixed segments under the *pipelined*
    objective fill + (M-1)/M * tau (docs/pipeline.md).

    The fill part is additive along the tour (comp/M imaginary links, trans/M +
    prop physical links) but the bottleneck tau = max stage time is not, so the
    search scans candidate bottleneck caps: for each cap tau, prune stages
    slower than tau and minimize fill with the sequential tour machinery; the
    optimum's bottleneck is one of the finitely many stage-time values, so
    taking the best evaluated plan over the scan is exact.  An incumbent bound
    prunes caps that can no longer contain the optimum's bottleneck
    ((M-1)/M * tau + min_fill >= best) and caps at or above the unconstrained
    plan's bottleneck (they reproduce the unconstrained plan).
    """
    K = len(segments)
    assert len(candidates) == K
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    training = request.mode == TR
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    comp: list[dict[str, float]] = []
    for k, (lo, hi) in enumerate(segments):
        comp.append({i: ev.segment_comp_s(i, lo, hi) for i in candidates[k]
                     if ev.segment_fits(i, lo, hi)})
        if not comp[k]:
            return None

    cut_sizes: list[tuple[float, float | None]] = [(0.0, None)] * K
    for k in range(1, K):
        cut = segments[k - 1][1]
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        cut_sizes[k] = (fw, bw)

    # Candidate bottleneck values: every stage time any plan can exhibit.
    lb = max(min(c.values()) for c in comp)
    taus = {v for c in comp for v in c.values()}
    for k in range(1, K):
        fw, bw = cut_sizes[k]
        for (u, v) in net.links:
            taus.add(net.link_trans_s(u, v, fw, bw))
    cand_taus = sorted(t for t in taus if t >= lb)

    plan0 = _capped_tour(net, request, segments, comp, cut_sizes, None, inv_M)
    if plan0 is None:
        return None
    best_plan, best_lb = plan0, ev.evaluate(plan0)
    best_lat = best_lb.total_s
    fill_min = (best_lb.computation_s + best_lb.transmission_s
                + best_lb.propagation_s)
    tau0 = ev.bottleneck_s(plan0)

    for tau in cand_taus:
        if tau >= tau0 or fill_min + c_bub * tau >= best_lat:
            break
        plan_t = _capped_tour(net, request, segments, comp, cut_sizes, tau,
                              inv_M)
        if plan_t is None:
            continue
        lat = ev.latency_s(plan_t)
        if lat < best_lat:
            best_plan, best_lat = plan_t, lat
    return best_plan


def _capped_tour_tr(
    net: PhysicalNetwork,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    comp: list[dict[str, float]],
    comp_fw: list[dict[str, float]],
    comp_bw: list[dict[str, float]],
    cut_sizes: list[tuple[float, float | None]],
    cap_fw: float,
    cap_bw: float,
    inv_M: float,
) -> Plan | None:
    """One per-direction-capped round-trip tour: candidates pruned to
    comp_fw <= cap_fw AND comp_bw <= cap_bw, links pruned per direction
    (activation occupancy <= cap_fw, gradient occupancy <= cap_bw), fused
    transmission scaled by 1/M — minimizes the round-trip *fill* (which is
    additive: both directions' t/M shares plus both propagation delays per
    link) among plans whose per-direction bottlenecks fit under the caps."""
    K = len(segments)
    best = {i: c * inv_M for i, c in comp[0].items()
            if comp_fw[0][i] <= cap_fw and comp_bw[0][i] <= cap_bw}
    if not best:
        return None
    pred_node: list[dict[str, str]] = [dict() for _ in range(K)]
    for k in range(1, K):
        fw_bytes, bw_bytes = cut_sizes[k]
        feas = [i for i in comp[k]
                if comp_fw[k][i] <= cap_fw and comp_bw[k][i] <= cap_bw]
        reached = _relax_stage(net, best, fw_bytes, bw_bytes, feas, cap_fw,
                               inv_M, cap_bw)
        nxt: dict[str, float] = {}
        for i, (dist, src) in reached.items():
            if dist < INF:
                nxt[i] = dist + comp[k][i] * inv_M
                pred_node[k][i] = src
        if not nxt:
            return None
        best = nxt

    # psi_K = 0 tail: FW-propagation-only, matching the round-trip evaluator
    # (zero bytes ship, so the caps never prune a tail link).
    tail_bw = None
    reached = _relax_stage(net, best, 0.0, tail_bw, [request.destination],
                           cap_fw, inv_M)
    if request.destination not in reached:
        return None
    tail_src = reached[request.destination][1]
    tail = _stage_path(net, tail_src, request.destination, 0.0, tail_bw,
                       cap_fw, inv_M)

    placement = [""] * K
    placement[K - 1] = tail_src
    for k in range(K - 1, 0, -1):
        placement[k - 1] = pred_node[k][placement[k]]
    paths = [
        _stage_path(net, placement[k - 1], placement[k], *cut_sizes[k],
                    cap_fw, inv_M, cap_bw)
        for k in range(1, K)
    ]
    return Plan(segments=list(segments), placement=placement, paths=paths,
                tail_path=tail if len(tail) > 1 else [])


def _dfts_pipe_tr(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    segments: list[tuple[int, int]],
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> Plan | None:
    """Optimal placement + chaining for fixed segments under the *round-trip*
    training objective fill_rt + (M-1)/M * (tau_fw + tau_bw)
    (docs/training.md).

    The fill is additive along the tour exactly like the fused pipelined fill
    (both directions' transmission/M + both propagation delays per link), but
    the drain couples two bottlenecks — the slowest forward stage and the
    slowest backward stage.  The search therefore scans candidate cap *pairs*
    (F, B) over the per-direction stage-time value sets, sorted by F + B
    ascending: for each pair, prune stages to comp_fw <= F, comp_bw <= B and
    links per direction, then minimize fill with the sequential tour
    machinery.  Any plan's exact (tau_fw, tau_bw) pair is in the grid, so
    taking the best evaluated plan over the scan is exact.  The incumbent
    bound min_fill + (M-1)/M * (F + B) >= best prunes the tail of the sorted
    scan (every remaining pair's optimum is at least that), and pairs
    dominating the unconstrained plan's bottlenecks (F >= tau_fw0 and
    B >= tau_bw0) reproduce plans that cannot beat it.
    """
    K = len(segments)
    assert len(candidates) == K
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    comp: list[dict[str, float]] = []
    comp_fw: list[dict[str, float]] = []
    comp_bw: list[dict[str, float]] = []
    for k, (lo, hi) in enumerate(segments):
        feas = [i for i in candidates[k] if ev.segment_fits(i, lo, hi)]
        if not feas:
            return None
        comp.append({i: ev.segment_comp_s(i, lo, hi) for i in feas})
        comp_fw.append({i: segment_comp_dir_s(ev, i, lo, hi, FW)
                        for i in feas})
        comp_bw.append({i: segment_comp_dir_s(ev, i, lo, hi, BW)
                        for i in feas})

    cut_sizes: list[tuple[float, float | None]] = [(0.0, None)] * K
    for k in range(1, K):
        cut = segments[k - 1][1]
        cut_sizes[k] = (b * profile.cut_bytes(cut, FW),
                        b * profile.cut_bytes(cut, BW))

    # Per-direction candidate bottleneck values: every forward (resp.
    # backward) stage time any plan over these segments can exhibit.
    lb_fw = max(min(c.values()) for c in comp_fw)
    lb_bw = max(min(c.values()) for c in comp_bw)
    fw_vals = {v for c in comp_fw for v in c.values()}
    bw_vals = {v for c in comp_bw for v in c.values()}
    for k in range(1, K):
        fw_bytes, bw_bytes = cut_sizes[k]
        for (u, v), spec in net.links.items():
            fw_vals.add(transmission_time_s(fw_bytes, spec.bw_fw))
            bw_vals.add(transmission_time_s(bw_bytes, spec.bw_bw))
    cand_fw = sorted(t for t in fw_vals if t >= lb_fw)
    cand_bw = sorted(t for t in bw_vals if t >= lb_bw)

    plan0 = _capped_tour(net, request, segments, comp, cut_sizes, None, inv_M)
    if plan0 is None:
        return None
    best_plan, best_lb = plan0, ev.evaluate(plan0)
    best_lat = best_lb.total_s
    fill_min = (best_lb.computation_s + best_lb.transmission_s
                + best_lb.propagation_s)
    tau_fw0, tau_bw0 = round_trip_taus(ev, plan0)

    pairs = sorted(((F, B) for F in cand_fw for B in cand_bw),
                   key=lambda p: (p[0] + p[1], p[0]))
    for F, B in pairs:
        if fill_min + c_bub * (F + B) >= best_lat:
            break
        if F >= tau_fw0 and B >= tau_bw0:
            continue
        plan_t = _capped_tour_tr(net, request, segments, comp, comp_fw,
                                 comp_bw, cut_sizes, F, B, inv_M)
        if plan_t is None:
            continue
        lat = ev.latency_s(plan_t)
        if lat < best_lat:
            best_plan, best_lat = plan_t, lat
    return best_plan
