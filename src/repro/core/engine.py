"""SolverEngine: capability-aware solver registry + the uniform solve() entry.

Every solver is a function with the uniform protocol

    fn(net, profile, request, K, candidates, *, cache=None, **kwargs)
      -> SolveResult

registered under a name with *declared capabilities*::

    @register_solver("bcd", schedules=(SEQ, PIPE), optimal=False,
                     description="paper Alg. 1 heuristic")
    def bcd_solve(net, profile, request, K, candidates, ...): ...

The registry is the single source of solver names (``solver_names()``) and
capability rules (``solver_supports()``): the layers that used to hardcode
checks like "ilp models schedule='seq' only" (sweep spec validation, serve
planner dispatch, the ilp pipe-raise) all route through it and get uniform,
actionable errors.  Adding a solver — learned, randomized, or external — is
one decorator; it immediately becomes sweepable (``ScenarioSpec(solver=...)``)
and servable (``ServePlanner(solver=...)``) with no other change.

:func:`solve` is the engine entry point: it takes a
:class:`~repro.core.problem.ProblemInstance`, validates capabilities, runs the
named solver, and wraps the raw :class:`SolveResult` into a
:class:`SolveOutcome` (status ∈ {optimal, feasible, infeasible} + stats).

The ``portfolio`` meta-solver (registered here like any other solver) runs a
configurable member set on one shared :class:`EvalCache` and returns the best
feasible outcome plus per-member stats.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Callable

from .costmodel import PIPE, SCHEDULES, SEQ, effective_microbatches
from .plan import EvalCache
from .problem import (INFEASIBLE, OPTIMAL, ProblemInstance, SolveOutcome,
                      SolveResult)


@dataclass(frozen=True)
class SolverInfo:
    """One registry entry: the solve function plus its declared capabilities."""

    name: str
    fn: Callable[..., SolveResult]
    schedules: tuple[str, ...]  # execution schedules the solver models
    optimal: bool  # provably latency-minimal when feasible
    meta: bool  # composes other registered solvers (e.g. portfolio)
    description: str
    # Optional vectorized entry: batch_fn(problems, *, cache=None, **kw) ->
    # list[SolveResult] aligned with `problems`.  solve_batch() dispatches to
    # it when present and falls back to a scalar solve() loop when not.
    batch_fn: Callable[..., list] | None = None

    def capabilities(self) -> dict:
        """Plain-data capability record (the --list-solvers CLI prints it)."""
        return {
            "name": self.name,
            "schedules": list(self.schedules),
            "optimal": self.optimal,
            "meta": self.meta,
            "batched": self.batch_fn is not None,
            "description": self.description,
        }


_REGISTRY: dict[str, SolverInfo] = {}


def register_solver(
    name: str,
    *,
    schedules: tuple[str, ...] = SCHEDULES,
    optimal: bool = False,
    meta: bool = False,
    description: str = "",
    batch: Callable[..., list] | None = None,
) -> Callable:
    """Decorator registering a solver function under ``name``.

    ``schedules`` declares which execution schedules the solver's objective
    models — a solver without ``PIPE`` is rejected (by ``solver_supports``)
    for requests whose effective pipeline depth exceeds 1, instead of each
    caller re-implementing that rule.  ``batch`` optionally supplies a
    vectorized ``batch(problems, *, cache=None, **kw) -> list[SolveResult]``
    entry that :func:`solve_batch` dispatches through.
    """
    schedules = tuple(schedules)
    unknown = [s for s in schedules if s not in SCHEDULES]
    if unknown or not schedules:
        raise ValueError(f"schedules must be a non-empty subset of "
                         f"{SCHEDULES}, got {schedules}")

    def deco(fn: Callable[..., SolveResult]) -> Callable[..., SolveResult]:
        if name in _REGISTRY:
            raise ValueError(f"solver {name!r} is already registered")
        doc = description or next(
            iter((fn.__doc__ or "").strip().splitlines()), "")
        _REGISTRY[name] = SolverInfo(name, fn, schedules, optimal, meta, doc,
                                     batch)
        return fn

    return deco


def unregister_solver(name: str) -> None:
    """Remove a registered solver (no-op if absent) — for tests and plugins."""
    _REGISTRY.pop(name, None)


_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    # Importing the solver modules runs their @register_solver decorators.
    # Lazy so `repro.core.engine` works standalone and import cycles can't
    # form (the solver modules import this module at their top level).  The
    # flag keeps the hot registry lookups (every solve/solve_batch item) from
    # re-walking the import machinery.
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    from . import baselines, bcd, exact, ilp  # noqa: F401
    try:
        from . import jax_solvers  # noqa: F401  (optional: needs jax)
    except ImportError:
        pass
    _BUILTINS_LOADED = True


def solver_names() -> tuple[str, ...]:
    """All registered solver names — THE solver-name list every layer uses."""
    _ensure_builtins()
    return tuple(_REGISTRY)


def get_solver(name: str) -> SolverInfo:
    """Registry lookup with a uniform, actionable unknown-name error."""
    _ensure_builtins()
    info = _REGISTRY.get(name)
    if info is None:
        raise ValueError(f"unknown solver {name!r}; registered solvers: "
                         f"{sorted(_REGISTRY)}")
    return info


def solver_capabilities() -> list[dict]:
    """Capability records of every registered solver (stable registry order)."""
    return [info.capabilities() for info in
            (_REGISTRY[n] for n in solver_names())]


def solver_supports(
    name: str,
    problem: ProblemInstance | None = None,
    *,
    schedule: str | None = None,
    batch_size: int | None = None,
    n_microbatches: int = 1,
) -> tuple[bool, str]:
    """THE capability query: can ``name`` solve this problem?

    Returns ``(ok, reason)``; ``reason`` is an actionable message naming the
    solvers that *do* support the instance.  Pass a full
    :class:`ProblemInstance`, or — before one can be built, e.g. while
    validating a declarative spec — the ``schedule``/``batch_size``/
    ``n_microbatches`` triple.  Raises ``ValueError`` for unknown names.
    """
    info = get_solver(name)
    if problem is not None:
        schedule = problem.request.schedule
        M = problem.request.microbatches()
    else:
        schedule = SEQ if schedule is None else schedule
        if schedule != PIPE:
            M = 1
        elif batch_size is not None:
            M = effective_microbatches(batch_size, n_microbatches)
        else:
            M = max(1, int(n_microbatches))
    effective = PIPE if (schedule == PIPE and M > 1) else SEQ
    if effective not in info.schedules:
        alt = sorted(n for n, i in _REGISTRY.items()
                     if effective in i.schedules and not i.meta)
        kind = "pipelined" if effective == PIPE else "sequential"
        return False, (
            f"solver {name!r} models schedule(s) {list(info.schedules)} only, "
            f"but the request is schedule={schedule!r} with {M} effective "
            f"microbatches; use one of {alt} for {kind} requests")
    return True, ""


def ensure_solver_supported(
    name: str,
    problem: ProblemInstance | None = None,
    **kwargs,
) -> SolverInfo:
    """Like :func:`solver_supports` but raises ``ValueError(reason)``."""
    ok, reason = solver_supports(name, problem, **kwargs)
    if not ok:
        raise ValueError(reason)
    return get_solver(name)


# Unique-instance count below which solve_batch prefers the scalar loop even
# when the solver registers a batch function.  Batched dispatch has fixed
# per-call overhead (encode/pad/jit re-entry) that only amortizes across
# enough instances: BENCH_solver.json puts warm batched dfts_jax at ~0.2x the
# scalar path for a single instance and ~1.2x by batch 8, so the measured
# crossover sits in between.  Override per call with ``min_batch=`` (1 forces
# batched dispatch, as before).
SOLVE_BATCH_MIN_BATCH = 4


# ---------------------------------------------------------------- entry point
def solve(
    problem: ProblemInstance,
    solver: str = "bcd",
    *,
    cache: EvalCache | None = None,
    **solver_kwargs,
) -> SolveOutcome:
    """Solve ``problem`` with the named registered solver.

    Validates capabilities first (uniform errors), then runs the solver with
    the uniform protocol and wraps its raw result into a
    :class:`SolveOutcome`.  Plans are bit-for-bit identical to calling the
    underlying solver function directly with the same arguments.
    """
    info = ensure_solver_supported(solver, problem)
    res = info.fn(*problem.solver_args(), cache=cache, **solver_kwargs)
    if isinstance(res, SolveOutcome):
        return res  # meta-solvers build their outcome (status, stats) inline
    return SolveOutcome.from_result(res, optimal=info.optimal)


def solve_batch(
    problems: list[ProblemInstance],
    solver: str = "bcd",
    *,
    cache: EvalCache | None = None,
    dedup: bool = True,
    min_batch: int | None = None,
    **solver_kwargs,
) -> list[SolveOutcome]:
    """Solve many problems with one named solver; returns aligned outcomes.

    Capability validation is per problem (same uniform errors as
    :func:`solve`, raised before any solving starts).  With ``dedup`` (the
    default), content-hash-equal instances are solved once and the outcome
    object is shared across their slots — sound because solvers are
    deterministic functions of the instance content.  Solvers registered with
    a ``batch`` function get the whole unique set in one call (the batched
    JAX solvers pad it into dense arrays); others fall back to a scalar
    :func:`solve` loop, so every registered solver is batch-dispatchable.

    ``min_batch`` (default :data:`SOLVE_BATCH_MIN_BATCH`, the measured
    batched-vs-scalar crossover) routes unique sets smaller than the
    threshold to the scalar loop even when a batch function is registered —
    tiny sets pay more in batch-dispatch overhead than they save.  Outcomes
    are identical either side of the threshold (the batched solvers are
    bit-for-bit twins of their scalar paths); only wall time changes.
    """
    # Support depends only on (schedule, effective M) — validate each distinct
    # signature once, raising at the *first* offending problem like the naive
    # per-problem loop would.
    seen_sigs: set[tuple[str, int]] = set()
    for p in problems:
        sig = (p.request.schedule, p.request.microbatches())
        if sig not in seen_sigs:
            seen_sigs.add(sig)
            ensure_solver_supported(solver, p)
    info = get_solver(solver)
    if not problems:
        return []

    if dedup:
        order: dict[str, int] = {}  # content hash -> index into `unique`
        unique: list[ProblemInstance] = []
        for p in problems:
            h = p.content_hash()
            if h not in order:
                order[h] = len(unique)
                unique.append(p)
        slot = [order[p.content_hash()] for p in problems]
    else:
        unique = list(problems)
        slot = list(range(len(problems)))

    threshold = SOLVE_BATCH_MIN_BATCH if min_batch is None else min_batch
    if info.batch_fn is not None and len(unique) >= threshold:
        results = info.batch_fn(unique, cache=cache, **solver_kwargs)
        outcomes = [r if isinstance(r, SolveOutcome)
                    else SolveOutcome.from_result(r, optimal=info.optimal)
                    for r in results]
    else:
        outcomes = [solve(p, solver, cache=cache, **solver_kwargs)
                    for p in unique]
    if not dedup:
        return outcomes
    return [outcomes[i] for i in slot]


# ------------------------------------------------------------ legacy shims
_WARNED_ALIASES: set[str] = set()


def deprecated_solver_alias(name: str, alias: str) -> Callable[..., SolveResult]:
    """A shim preserving a legacy ``*_solve(net, profile, request, K,
    candidates, **kwargs)`` entry point: emits one DeprecationWarning per
    process (the first call only), then dispatches to the registered solver —
    bit-for-bit the same plan as the engine path."""

    def shim(net, profile, request, K, candidates, **kwargs) -> SolveResult:
        if alias not in _WARNED_ALIASES:
            _WARNED_ALIASES.add(alias)
            warnings.warn(
                f"{alias}() is deprecated; use repro.core.solve("
                f"ProblemInstance(net, profile, request, K, candidates), "
                f"solver={name!r}) instead", DeprecationWarning, stacklevel=2)
        return get_solver(name).fn(net, profile, request, K, candidates,
                                   **kwargs)

    shim.__name__ = alias
    shim.__qualname__ = alias
    shim.__doc__ = (f"Deprecated alias for the registered {name!r} solver; "
                    f"use repro.core.solve(...) instead.")
    return shim


# ------------------------------------------------------- portfolio meta-solver
# Default member set: the heuristic family.  The optimal-class solvers are
# deliberately not defaulted in (exact *is* the answer wherever it is cheap
# enough to run — a portfolio adds nothing on top, and its pipelined
# bottleneck-cap scan is a small-instance oracle); opt them in per call with
# members=("exact", "bcd", ...).
PORTFOLIO_DEFAULT_MEMBERS = ("bcd", "comp-ms", "comm-ms")


@register_solver("portfolio", schedules=(SEQ, PIPE), meta=True,
                 description="best-of-N meta-solver over registered members "
                             "sharing one EvalCache")
def portfolio_solve(
    net,
    profile,
    request,
    K: int,
    candidates: list[list[str]],
    members: tuple[str, ...] | list[str] | None = None,
    cache: EvalCache | None = None,
    member_kwargs: dict[str, dict] | None = None,
) -> SolveOutcome:
    """Run every member solver on one shared cache; keep the best feasible.

    ``members`` defaults to :data:`PORTFOLIO_DEFAULT_MEMBERS`; unknown names
    raise, members that don't support the instance's schedule are skipped and
    recorded as ``unsupported`` in the per-member stats.  ``member_kwargs``
    maps member name -> extra kwargs for that member.  The returned outcome
    is the winning member's plan (objective <= every member's by
    construction), with ``stats["members"]`` carrying each member's status,
    objective, and wall time, and ``stats["winner"]`` the winning name.
    """
    t0 = time.perf_counter()
    cache = cache if cache is not None else EvalCache()
    names = tuple(members) if members is not None else PORTFOLIO_DEFAULT_MEMBERS
    if not names:
        raise ValueError("portfolio needs at least one member solver")
    extra = member_kwargs or {}

    best: SolveOutcome | None = None
    stats: dict = {"members": {}, "winner": None}
    for m in names:
        info = get_solver(m)
        if info.meta:
            raise ValueError(f"portfolio members must be base solvers, got "
                             f"meta-solver {m!r}")
        ok, reason = solver_supports(
            m, schedule=request.schedule, batch_size=request.batch_size,
            n_microbatches=request.n_microbatches)
        if not ok:
            stats["members"][m] = {"status": "unsupported", "reason": reason}
            continue
        res = info.fn(net, profile, request, K, candidates, cache=cache,
                      **extra.get(m, {}))
        out = (res if isinstance(res, SolveOutcome)
               else SolveOutcome.from_result(res, optimal=info.optimal))
        stats["members"][m] = {
            "status": out.status,
            "objective": None if out.plan is None else out.objective,
            "wall_time_s": out.wall_time_s,
            "iterations": out.iterations,
        }
        if out.plan is not None and (best is None
                                     or out.objective < best.objective):
            best = out
            stats["winner"] = m

    wall = time.perf_counter() - t0
    if best is None:
        return SolveOutcome(None, None, wall, solver="portfolio",
                            status=INFEASIBLE, stats=stats)
    # If an optimal-class member was feasible, min over members attains the
    # optimum, so the portfolio outcome inherits the optimality guarantee.
    optimal = any(get_solver(m).optimal
                  and stats["members"][m].get("objective") is not None
                  for m in names if m in stats["members"]
                  and stats["members"][m]["status"] != "unsupported")
    return SolveOutcome(best.plan, best.latency, wall, best.iterations,
                        list(best.history), "portfolio",
                        status=OPTIMAL if optimal else best.status,
                        stats=stats)
