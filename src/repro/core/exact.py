"""Exact joint solver for splitting + placement + chaining.

Because the ILP (Sec. IV) has *no link-capacity constraints* (only per-node
memory/storage, which bind per sub-model), every inter-stage subpath is
independently a shortest path for its cut's smashed-data size.  The joint problem
therefore admits an exact dynamic program over states (segment k, end layer e,
host node i):

  dp[k][e][i] = min over (e' < e, j in V^{k-1}) of
      dp[k-1][e'][j] + sp_cost(j -> i; delta_{e'}) + comp(i, layers e'+1..e)

with sp_cost from per-cut-size Dijkstras.  dp[K][L][i] + tail(i -> d) attains the
ILP optimum (cross-checked against the HiGHS MILP in tests).  Complexity
O(L V (E log V)) precompute + O(K L^2 |V^k|^2) DP — this is our fast optimal
oracle for the latency grids where the MILP would be slow.
"""
from __future__ import annotations

import time

from .costmodel import BW, FW, PIPE, SEQ, TR, ModelProfile
from .dfts import _backtrack
from .engine import register_solver
from .network import PhysicalNetwork, transmission_time_s
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest
from .problem import SolveResult
from .trainpipe import round_trip_taus, segment_comp_dir_s

INF = float("inf")


@register_solver("exact", schedules=(SEQ, PIPE), optimal=True,
                 description="ILP-equivalent joint DP (fast optimal oracle); "
                             "pipelined variant exact via bottleneck-cap scan")
def exact_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> SolveResult:
    if request.schedule == PIPE and request.microbatches() > 1:
        if request.mode == TR:
            return _exact_pipe_tr(net, profile, request, K, candidates, cache)
        return _exact_pipe(net, profile, request, K, candidates, cache)
    t0 = time.perf_counter()
    L = profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    training = request.mode == TR

    # --- per-cut shortest-path tables between candidate nodes ------------------
    # sp[cut][j] = (dist map, parent map) from source j with the cut's link costs;
    # served from the network's frontier cache, shared across solver calls.
    sources = sorted({j for cand in candidates[:-1] for j in cand})
    sp: dict[tuple[int, str], tuple[dict[str, float], dict[str, str | None]]] = {}
    for cut in range(1, L):
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        for j in sources:
            sp[(cut, j)] = net.sssp(j, fw, bw)

    # --- DP ---------------------------------------------------------------------
    # dp[k][e][i]; store parents for reconstruction.
    dp: list[dict[tuple[int, str], float]] = [dict() for _ in range(K + 1)]
    par: list[dict[tuple[int, str], tuple[int, str]]] = [dict() for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        for i in candidates[0]:
            if ev.segment_fits(i, 1, e):
                dp[1][(e, i)] = ev.segment_comp_s(i, 1, e)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for i in candidates[k - 1]:
                best, best_par = INF, None
                for (e2, j), prev in dp[k - 1].items():
                    if e2 >= e:
                        continue
                    if not ev.segment_fits(i, e2 + 1, e):
                        continue
                    d = sp[(e2, j)][0][i]
                    if d == INF:
                        continue
                    c = prev + d + ev.segment_comp_s(i, e2 + 1, e)
                    if c < best:
                        best, best_par = c, (e2, j)
                if best < INF:
                    dp[k][(e, i)] = best
                    par[k][(e, i)] = best_par  # type: ignore[assignment]

    # --- tail: placement of F^K -> destination, propagation only ---------------
    tail_bw = 0.0 if training else None
    best_total, best_state, tail_path = INF, None, []
    finals = {i: c for (e, i), c in dp[K].items() if e == L}
    if not finals:
        return SolveResult(None, None, time.perf_counter() - t0, solver="exact")
    dist, parent = net.dijkstra(dict(finals), 0.0, tail_bw)
    if dist[request.destination] == INF:
        return SolveResult(None, None, time.perf_counter() - t0, solver="exact")
    best_total = dist[request.destination]
    tail = _backtrack(parent, request.destination, set(finals))
    best_state = (L, tail[0])
    tail_path = tail if len(tail) > 1 else []

    # --- reconstruct ------------------------------------------------------------
    states = [best_state]
    for k in range(K, 1, -1):
        states.append(par[k][states[-1]])
    states.reverse()  # [(e_1, i_1), ..., (e_K=L, i_K)]
    segments, placement, paths = [], [], []
    lo = 1
    for (e, i) in states:
        segments.append((lo, e))
        placement.append(i)
        lo = e + 1
    for k in range(1, K):
        cut = segments[k - 1][1]
        j, i = placement[k - 1], placement[k]
        _, p = sp[(cut, j)]
        paths.append(_backtrack(p, i, {j}))
    plan = Plan(segments=segments, placement=placement, paths=paths,
                tail_path=tail_path)
    ev.check(plan)
    return SolveResult(plan, ev.evaluate(plan), time.perf_counter() - t0,
                       solver="exact")


def _joint_dp_capped(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    ev: PlanEvaluator,
    cap: float | None,
    inv_M: float,
) -> Plan | None:
    """One bottleneck-capped run of the joint DP: minimize the pipeline *fill*
    (comp/M at hosts, trans/M + propagation along subpaths) over splitting +
    placement + chaining, with every stage — host compute and single-link
    transmission — at most ``cap``.  The capped/scaled shortest paths come from
    the network's frontier cache, so repeated caps are free."""
    L = profile.L
    b = request.batch_size
    training = request.mode == TR

    def comp_ok(i: str, lo: int, hi: int) -> float | None:
        if not ev.segment_fits(i, lo, hi):
            return None
        c = ev.segment_comp_s(i, lo, hi)
        if cap is not None and c > cap:
            return None
        return c

    sources = sorted({j for cand in candidates[:-1] for j in cand})
    sp: dict[tuple[int, str], tuple[dict[str, float], dict[str, str | None]]] = {}
    for cut in range(1, L):
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        for j in sources:
            sp[(cut, j)] = net.sssp(j, fw, bw, cap, inv_M)

    dp: list[dict[tuple[int, str], float]] = [dict() for _ in range(K + 1)]
    par: list[dict[tuple[int, str], tuple[int, str]]] = [dict() for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        for i in candidates[0]:
            c = comp_ok(i, 1, e)
            if c is not None:
                dp[1][(e, i)] = c * inv_M
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for i in candidates[k - 1]:
                best, best_par = INF, None
                for (e2, j), prev in dp[k - 1].items():
                    if e2 >= e:
                        continue
                    c = comp_ok(i, e2 + 1, e)
                    if c is None:
                        continue
                    d = sp[(e2, j)][0][i]
                    if d == INF:
                        continue
                    tot = prev + d + c * inv_M
                    if tot < best:
                        best, best_par = tot, (e2, j)
                if best < INF:
                    dp[k][(e, i)] = best
                    par[k][(e, i)] = best_par  # type: ignore[assignment]

    # FW-only tail propagation, matching the evaluator's psi_K = 0 convention
    # (keeps the cap-scan incumbent bound exact; see _capped_tour in dfts.py).
    tail_bw = None
    finals = {i: c for (e, i), c in dp[K].items() if e == L}
    if not finals:
        return None
    dist, parent = net.dijkstra(dict(finals), 0.0, tail_bw, cap, inv_M)
    if dist[request.destination] == INF:
        return None
    tail = _backtrack(parent, request.destination, set(finals))
    states = [(L, tail[0])]
    for k in range(K, 1, -1):
        states.append(par[k][states[-1]])
    states.reverse()
    segments, placement, paths = [], [], []
    lo = 1
    for (e, i) in states:
        segments.append((lo, e))
        placement.append(i)
        lo = e + 1
    for k in range(1, K):
        cut = segments[k - 1][1]
        j, i = placement[k - 1], placement[k]
        _, p = sp[(cut, j)]
        paths.append(_backtrack(p, i, {j}))
    return Plan(segments=segments, placement=placement, paths=paths,
                tail_path=tail if len(tail) > 1 else [])


def _exact_pipe(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> SolveResult:
    """Exact joint solver for the *pipelined* objective fill + (M-1)/M * tau.

    Like `_dfts_pipe` this scans candidate bottleneck caps — here every
    feasible (host, segment) compute time and every (link, cut) transmission
    time — running the capped joint DP per cap and keeping the best evaluated
    plan; the optimum's bottleneck is one of the candidates, so the scan is
    exact.  The incumbent bound (M-1)/M * tau + min_fill >= best prunes the
    scan.  Intended as the parity oracle for BCD-pipe on small instances: the
    scan multiplies the joint DP's cost by the candidate count, so keep L and
    |V^k| small (tests use L <= 10); the sweep suites use BCD for pipelined
    scenarios.
    """
    t0 = time.perf_counter()
    L = profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    training = request.mode == TR
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    taus: set[float] = set()
    per_stage_min = []
    for k in range(K):
        best_k = INF
        hi_max = L - (K - 1 - k)
        for i in candidates[k]:
            for lo in range(k + 1, hi_max + 1):
                for hi in range(lo, hi_max + 1):
                    if ev.segment_fits(i, lo, hi):
                        c = ev.segment_comp_s(i, lo, hi)
                        taus.add(c)
                        best_k = min(best_k, c)
        if best_k == INF:
            return SolveResult(None, None, time.perf_counter() - t0,
                               solver="exact")
        per_stage_min.append(best_k)
    lb = max(per_stage_min)
    for cut in range(1, L):
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        for (u, v) in net.links:
            taus.add(net.link_trans_s(u, v, fw, bw))
    cand_taus = sorted(t for t in taus if t >= lb)

    plan0 = _joint_dp_capped(net, profile, request, K, candidates, ev, None,
                             inv_M)
    if plan0 is None:
        return SolveResult(None, None, time.perf_counter() - t0, solver="exact")
    lb0 = ev.evaluate(plan0)
    best_plan, best_lat = plan0, lb0.total_s
    fill_min = lb0.computation_s + lb0.transmission_s + lb0.propagation_s
    tau0 = ev.bottleneck_s(plan0)

    for tau in cand_taus:
        if tau >= tau0 or fill_min + c_bub * tau >= best_lat:
            break
        plan_t = _joint_dp_capped(net, profile, request, K, candidates, ev,
                                  tau, inv_M)
        if plan_t is None:
            continue
        lat = ev.latency_s(plan_t)
        if lat < best_lat:
            best_plan, best_lat = plan_t, lat

    ev.check(best_plan)
    return SolveResult(best_plan, ev.evaluate(best_plan),
                       time.perf_counter() - t0, solver="exact")


def _joint_dp_capped_tr(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    ev: PlanEvaluator,
    cap_fw: float,
    cap_bw: float,
    inv_M: float,
) -> Plan | None:
    """One per-direction-capped run of the joint DP (round-trip training):
    minimize the round-trip pipeline *fill* over splitting + placement +
    chaining with every forward stage <= cap_fw and every backward stage
    <= cap_bw — hosts pruned on their per-direction compute, links pruned per
    direction inside the capped shortest paths (docs/training.md)."""
    L = profile.L
    b = request.batch_size

    def comp_ok(i: str, lo: int, hi: int) -> float | None:
        if not ev.segment_fits(i, lo, hi):
            return None
        if (segment_comp_dir_s(ev, i, lo, hi, FW) > cap_fw
                or segment_comp_dir_s(ev, i, lo, hi, BW) > cap_bw):
            return None
        return ev.segment_comp_s(i, lo, hi)

    sources = sorted({j for cand in candidates[:-1] for j in cand})
    sp: dict[tuple[int, str], tuple[dict[str, float], dict[str, str | None]]] = {}
    for cut in range(1, L):
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW)
        for j in sources:
            sp[(cut, j)] = net.sssp(j, fw, bw, cap_fw, inv_M, cap_bw)

    dp: list[dict[tuple[int, str], float]] = [dict() for _ in range(K + 1)]
    par: list[dict[tuple[int, str], tuple[int, str]]] = [dict() for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        for i in candidates[0]:
            c = comp_ok(i, 1, e)
            if c is not None:
                dp[1][(e, i)] = c * inv_M
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for i in candidates[k - 1]:
                best, best_par = INF, None
                for (e2, j), prev in dp[k - 1].items():
                    if e2 >= e:
                        continue
                    c = comp_ok(i, e2 + 1, e)
                    if c is None:
                        continue
                    d = sp[(e2, j)][0][i]
                    if d == INF:
                        continue
                    tot = prev + d + c * inv_M
                    if tot < best:
                        best, best_par = tot, (e2, j)
                if best < INF:
                    dp[k][(e, i)] = best
                    par[k][(e, i)] = best_par  # type: ignore[assignment]

    # psi_K = 0 tail: FW propagation only, matching the round-trip evaluator.
    tail_bw = None
    finals = {i: c for (e, i), c in dp[K].items() if e == L}
    if not finals:
        return None
    dist, parent = net.dijkstra(dict(finals), 0.0, tail_bw, cap_fw, inv_M)
    if dist[request.destination] == INF:
        return None
    tail = _backtrack(parent, request.destination, set(finals))
    states = [(L, tail[0])]
    for k in range(K, 1, -1):
        states.append(par[k][states[-1]])
    states.reverse()
    segments, placement, paths = [], [], []
    lo = 1
    for (e, i) in states:
        segments.append((lo, e))
        placement.append(i)
        lo = e + 1
    for k in range(1, K):
        cut = segments[k - 1][1]
        j, i = placement[k - 1], placement[k]
        _, p = sp[(cut, j)]
        paths.append(_backtrack(p, i, {j}))
    return Plan(segments=segments, placement=placement, paths=paths,
                tail_path=tail if len(tail) > 1 else [])


def _exact_pipe_tr(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> SolveResult:
    """Exact joint solver for the *round-trip* training objective
    fill_rt + (M-1)/M * (tau_fw + tau_bw) (docs/training.md).

    Like `_dfts_pipe_tr` this scans candidate per-direction cap pairs (F, B)
    — every feasible (host, segment) per-direction compute time and every
    (link, cut) per-direction transmission time — sorted by F + B ascending
    with the incumbent bound min_fill + (M-1)/M * (F + B) >= best, running
    the per-direction-capped joint DP per pair.  The optimum's exact
    (tau_fw, tau_bw) pair is in the grid, so the scan is exact.  The pair
    grid multiplies the joint DP's cost quadratically: this is the parity
    oracle for BCD-TR-pipe on *small* instances only (tests use L <= 10);
    the sweep suites use BCD for pipelined scenarios.
    """
    t0 = time.perf_counter()
    L = profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    b = request.batch_size
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    fw_vals: set[float] = set()
    bw_vals: set[float] = set()
    lb_fw = lb_bw = 0.0
    for k in range(K):
        best_fw = best_bw = INF
        hi_max = L - (K - 1 - k)
        for i in candidates[k]:
            for lo in range(k + 1, hi_max + 1):
                for hi in range(lo, hi_max + 1):
                    if ev.segment_fits(i, lo, hi):
                        cf = segment_comp_dir_s(ev, i, lo, hi, FW)
                        cb = segment_comp_dir_s(ev, i, lo, hi, BW)
                        fw_vals.add(cf)
                        bw_vals.add(cb)
                        best_fw = min(best_fw, cf)
                        best_bw = min(best_bw, cb)
        if best_fw == INF:
            return SolveResult(None, None, time.perf_counter() - t0,
                               solver="exact")
        lb_fw = max(lb_fw, best_fw)
        lb_bw = max(lb_bw, best_bw)
    for cut in range(1, L):
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW)
        for (u, v), spec in net.links.items():
            fw_vals.add(transmission_time_s(fw, spec.bw_fw))
            bw_vals.add(transmission_time_s(bw, spec.bw_bw))
    cand_fw = sorted(t for t in fw_vals if t >= lb_fw)
    cand_bw = sorted(t for t in bw_vals if t >= lb_bw)

    plan0 = _joint_dp_capped(net, profile, request, K, candidates, ev, None,
                             inv_M)
    if plan0 is None:
        return SolveResult(None, None, time.perf_counter() - t0, solver="exact")
    lb0 = ev.evaluate(plan0)
    best_plan, best_lat = plan0, lb0.total_s
    fill_min = lb0.computation_s + lb0.transmission_s + lb0.propagation_s
    tau_fw0, tau_bw0 = round_trip_taus(ev, plan0)

    pairs = sorted(((F, B) for F in cand_fw for B in cand_bw),
                   key=lambda p: (p[0] + p[1], p[0]))
    for F, B in pairs:
        if fill_min + c_bub * (F + B) >= best_lat:
            break
        if F >= tau_fw0 and B >= tau_bw0:
            continue
        plan_t = _joint_dp_capped_tr(net, profile, request, K, candidates,
                                     ev, F, B, inv_M)
        if plan_t is None:
            continue
        lat = ev.latency_s(plan_t)
        if lat < best_lat:
            best_plan, best_lat = plan_t, lat

    ev.check(best_plan)
    return SolveResult(best_plan, ev.evaluate(best_plan),
                       time.perf_counter() - t0, solver="exact")
