"""Faithful MILP of the paper's P_IF / P_TR (Sec. IV, Eqs. (1)-(15)).

Solved with scipy's HiGHS `milp` (exact branch-and-bound — Gurobi is not
installable offline; HiGHS returns provably optimal solutions, so this is the
paper's "ILP" scheme).  The non-linearities the paper mentions (products of
binaries in Eq. (16), the max in (12)/(15)) are linearized with the standard
techniques the paper cites [20]:

  * u_{k,l} = y_{k,l} (1 - y_{k,l+1})      -> AND linearization (cut indicator)
  * x * psi transmission products          -> big-M lower-bounded epigraph t_{k,e}
  * x * kappa compute products             -> big-M epigraph g_{k,i}
  * max(0, y_l - y_{l-1}) in (12)          -> rise variables m_{k,l}, sum = 1
  * max_l y delta in (15)                  -> peak variable h_k >= delta_l y_{k,l}

Subpath semantics follow Eq. (16): transmission + propagation are charged on
subpaths S_2..S_{K+1} (S_{K+1} ships psi_K = 0, i.e. propagation only); S_1 is
uncharged (V^1 is pinned to {s} in all evaluations, as in the paper).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, milp

from .costmodel import BW, FW, SEQ, TR, ModelProfile, dirs_for_mode
from .engine import register_solver
from .network import PhysicalNetwork, transmission_time_s
from .plan import Plan, PlanEvaluator, ServiceChainRequest
from .problem import SolveResult

EPS_SUBPATH1 = 1e-9  # tiny cost on S_1 physical edges to keep solutions loop-free


@dataclass
class _Var:
    lo: float
    hi: float
    integral: bool
    obj: float = 0.0


class _Builder:
    def __init__(self) -> None:
        self.vars: list[_Var] = []
        self.rows: list[tuple[dict[int, float], float, float]] = []

    def add_var(self, lo=0.0, hi=1.0, integral=False, obj=0.0) -> int:
        self.vars.append(_Var(lo, hi, integral, obj))
        return len(self.vars) - 1

    def add_row(self, coeffs: dict[int, float], lb: float, ub: float) -> None:
        self.rows.append((coeffs, lb, ub))

    def solve(self, time_limit_s: float | None):
        n = len(self.vars)
        c = np.array([v.obj for v in self.vars])
        integrality = np.array([1 if v.integral else 0 for v in self.vars])
        bounds = Bounds(np.array([v.lo for v in self.vars]),
                        np.array([v.hi for v in self.vars]))
        data, ri, ci, lbs, ubs = [], [], [], [], []
        for r, (coeffs, lb, ub) in enumerate(self.rows):
            for j, a in coeffs.items():
                ri.append(r)
                ci.append(j)
                data.append(a)
            lbs.append(lb)
            ubs.append(ub)
        A = sparse.csr_matrix((data, (ri, ci)), shape=(len(self.rows), n))
        cons = LinearConstraint(A, np.array(lbs), np.array(ubs))
        options = {"mip_rel_gap": 1e-9}
        if time_limit_s is not None:
            options["time_limit"] = time_limit_s
        return milp(c=c, constraints=cons, integrality=integrality, bounds=bounds,
                    options=options)


@register_solver("ilp", schedules=(SEQ,), optimal=True,
                 description="faithful HiGHS MILP of Eqs. (1)-(15); "
                             "sequential schedule only")
def ilp_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    time_limit_s: float | None = 1000.0,
    cache: object | None = None,  # accepted for solver-API uniformity; the MILP
    # builds its own coefficient tables and has nothing to memoize across calls.
) -> SolveResult:
    # The MILP linearizes the *sequential* Eq. (16) objective; the pipelined
    # bottleneck max has no formulation here.  The capability check yields the
    # same uniform error as the engine path for direct/legacy callers.
    from .engine import ensure_solver_supported

    ensure_solver_supported("ilp", schedule=request.schedule,
                            batch_size=request.batch_size,
                            n_microbatches=request.n_microbatches)
    t0 = time.perf_counter()
    L = profile.L
    b = request.batch_size
    dirs = dirs_for_mode(request.mode)
    phys_edges = sorted(net.links)
    B = _Builder()

    # ---------------------------------------------------------------- variables
    # x[k][edge]: subpaths k = 1..K+1 over the augmented edge set (constraint (5)
    # enforced structurally: only subpath k may enter v_hat_k, only subpath k+1
    # may leave it).
    x: list[dict[tuple, int]] = [dict() for _ in range(K + 2)]
    for k in range(1, K + 2):
        prop = 0.0 if k == 1 else None  # propagation charged on S_2..S_{K+1}
        for (u_, v_) in phys_edges:
            link = net.links[(u_, v_)]
            if k == 1:
                cost = EPS_SUBPATH1
            else:
                cost = link.delay_fw + (link.delay_bw if request.mode == TR else 0.0)
            x[k][(u_, v_)] = B.add_var(0, 1, True, obj=cost)
        if k <= K:  # (i, v_hat_k) entries
            for i in candidates[k - 1]:
                x[k][(i, ("hat", k))] = B.add_var(0, 1, True)
        if k >= 2:  # (v_hat_{k-1}, i) exits
            for i in candidates[k - 2]:
                x[k][(("hat", k - 1), i)] = B.add_var(0, 1, True)

    y = [[B.add_var(0, 1, True) for _ in range(L + 1)] for _ in range(K + 1)]  # y[k][l], 1-idx
    u = [[B.add_var(0, 1, False) for _ in range(L)] for _ in range(K)]  # u[k][l], k=1..K-1 used
    mv = [[B.add_var(0, 1, False) for _ in range(L + 1)] for _ in range(K + 1)]
    h = [B.add_var(0, np.inf, False) for _ in range(K + 1)]  # h[k], 1-idx

    # ------------------------------------------------------- splitting constraints
    B.add_row({y[1][1]: 1}, 1, 1)  # (7)
    B.add_row({y[K][L]: 1}, 1, 1)  # (8)
    for l in range(1, L + 1):  # (9)
        B.add_row({y[k][l]: 1 for k in range(1, K + 1)}, 1, 1)
    for k in range(1, K + 1):  # (10)
        B.add_row({y[k][l]: 1 for l in range(1, L + 1)}, 1, np.inf)
    for k in range(1, K + 1):  # (11)-(12): rise vars, y[k][0] == 0 dummy
        B.add_row({mv[k][1]: 1, y[k][1]: -1}, 0, np.inf)
        for l in range(2, L + 1):
            B.add_row({mv[k][l]: 1, y[k][l]: -1, y[k][l - 1]: 1}, 0, np.inf)
        B.add_row({mv[k][l]: 1 for l in range(1, L + 1)}, 1, 1)
    for k in range(2, K + 1):  # (13)
        for l in range(2, L + 1):
            B.add_row({y[k][l]: 1, y[k][l - 1]: -1, y[k - 1][l - 1]: -1},
                      -np.inf, 0)
    for k in range(1, K):  # u = AND(y_l, NOT y_{l+1}); exactly one cut per k < K
        for l in range(1, L):
            B.add_row({u[k][l - 1]: 1, y[k][l]: -1}, -np.inf, 0)
            B.add_row({u[k][l - 1]: 1, y[k][l + 1]: 1}, -np.inf, 1)
            B.add_row({u[k][l - 1]: 1, y[k][l]: -1, y[k][l + 1]: 1}, 0, np.inf)
        B.add_row({u[k][l - 1]: 1 for l in range(1, L)}, 1, 1)
    for k in range(1, K + 1):  # h_k >= delta_l^dir y_{k,l}
        for l in range(1, L + 1):
            for d in dirs:
                delta = (profile.layers[l - 1].act_bytes if d == FW
                         else profile.layers[l - 1].grad_bytes)
                B.add_row({h[k]: 1, y[k][l]: -delta}, 0, np.inf)

    # ------------------------------------------------- flow conservation (2)-(4)
    def nodes_of_subpath(k: int) -> list:
        ns: list = list(net.nodes)
        if 2 <= k:
            ns.append(("hat", k - 1))
        if k <= K:
            ns.append(("hat", k))
        return ns

    for k in range(1, K + 2):
        a_k = request.source if k == 1 else ("hat", k - 1)
        b_k = ("hat", k) if k <= K else request.destination
        for nd in nodes_of_subpath(k):
            coeffs: dict[int, float] = {}
            for e, idx in x[k].items():
                if e[0] == nd:
                    coeffs[idx] = coeffs.get(idx, 0.0) + 1.0
                if e[1] == nd:
                    coeffs[idx] = coeffs.get(idx, 0.0) - 1.0
            rhs = 1.0 if nd == a_k else (-1.0 if nd == b_k else 0.0)
            if coeffs or rhs:
                B.add_row(coeffs, rhs, rhs)
    for k in range(1, K + 1):  # (4) connectivity
        for i in candidates[k - 1]:
            B.add_row({x[k][(i, ("hat", k))]: 1, x[k + 1][(("hat", k), i)]: -1}, 0, 0)

    # -------------------------------------- computation epigraph g (Eqs. 16-17)
    g: dict[tuple[int, str], int] = {}
    for k in range(1, K + 1):
        for i in candidates[k - 1]:
            cm = net.nodes[i].compute
            coefs = np.zeros(L + 1)
            tau_total = 0.0
            for d in dirs:
                a_, beta_ = cm._coeffs(b)
                for l in range(1, L + 1):
                    coefs[l] += (a_ * b + beta_) / 1e3 * profile.layers[l - 1].flops(d)
                tau_total += cm.tau_s(b)
            gi = B.add_var(0, np.inf, False, obj=1.0)
            g[(k, i)] = gi
            M = float(coefs.sum()) + tau_total
            row = {gi: 1.0, x[k][(i, ("hat", k))]: -M}
            for l in range(1, L + 1):
                row[y[k][l]] = -float(coefs[l])
            B.add_row(row, tau_total - M, np.inf)

    # ------------------------------------- transmission epigraph t (Eqs. 16, 18)
    for k in range(1, K):  # cut k ships on subpath k+1
        for (u_, v_) in phys_edges:
            link = net.links[(u_, v_)]
            w = np.zeros(L)  # w[l-1]: cost if cut after layer l
            for l in range(1, L):
                w[l - 1] += transmission_time_s(b * profile.cut_bytes(l, FW), link.bw_fw)
                if request.mode == TR:
                    w[l - 1] += transmission_time_s(b * profile.cut_bytes(l, BW), link.bw_bw)
            M = float(w.max())
            ti = B.add_var(0, np.inf, False, obj=1.0)
            row = {ti: 1.0, x[k + 1][(u_, v_)]: -M}
            for l in range(1, L):
                row[u[k][l - 1]] = -float(w[l - 1])
            B.add_row(row, -M, np.inf)

    # --------------------------------------------- capacity (14) and (15) big-M
    for k in range(1, K + 1):
        for i in candidates[k - 1]:
            spec = net.nodes[i]
            xi = x[k][(i, ("hat", k))]
            Md = sum(l.disk_bytes for l in profile.layers)
            row = {xi: Md}
            for l in range(1, L + 1):
                row[y[k][l]] = profile.layers[l - 1].disk_bytes
            B.add_row(row, -np.inf, spec.disk_capacity + Md)
            peak = max(max(l.act_bytes, l.grad_bytes) for l in profile.layers)
            Mm = sum(l.mem_bytes for l in profile.layers) + b * peak
            row = {xi: Mm, h[k]: b}
            for l in range(1, L + 1):
                row[y[k][l]] = profile.layers[l - 1].mem_bytes
            B.add_row(row, -np.inf, spec.mem_capacity + Mm)

    res = B.solve(time_limit_s)
    wall = time.perf_counter() - t0
    if res.status != 0 or res.x is None:
        return SolveResult(None, None, wall, solver="ilp")

    # ------------------------------------------------------------- extraction
    xv = res.x

    def sel(idx: int) -> bool:
        return xv[idx] > 0.5

    segments = []
    for k in range(1, K + 1):
        ls = [l for l in range(1, L + 1) if sel(y[k][l])]
        segments.append((min(ls), max(ls)))
    placement = []
    for k in range(1, K + 1):
        hosts = [i for i in candidates[k - 1] if sel(x[k][(i, ("hat", k))])]
        assert len(hosts) == 1, f"subpath {k}: hosts={hosts}"
        placement.append(hosts[0])

    def walk(k: int, start: str, goal: str) -> list[str]:
        succ = {}
        for (e, idx) in x[k].items():
            if isinstance(e[0], str) and isinstance(e[1], str) and sel(idx):
                succ[e[0]] = e[1]
        path, cur = [start], start
        while cur != goal:
            cur = succ[cur]
            path.append(cur)
        return path

    paths = [walk(k + 2, placement[k], placement[k + 1]) for k in range(K - 1)]
    tail = walk(K + 1, placement[K - 1], request.destination)
    plan = Plan(segments=segments, placement=placement, paths=paths,
                tail_path=tail if len(tail) > 1 else [])
    ev = PlanEvaluator(net, profile, request)
    ev.check(plan)
    latency = ev.evaluate(plan)
    # self-check: extracted plan must reproduce the MILP objective
    if abs(latency.total_s - res.fun) > 1e-6 + 1e-6 * abs(res.fun):
        raise AssertionError(
            f"ILP objective {res.fun} != extracted plan latency {latency.total_s}")
    return SolveResult(plan, latency, wall, solver="ilp")
