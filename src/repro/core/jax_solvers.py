"""Batched, jitted JAX solver core: ``dfts_jax`` / ``bcd_jax`` / ``dfts_np``.

The scalar solvers (dfts.py, segmentation.py, bcd.py) walk Python dicts per
stage; this module runs the *same* recurrences as dense array programs:

* the DFTS tour relaxation is a min-plus composition of per-stage frontier
  matrices, executed as one ``lax.scan`` over stages (optionally through the
  tiled Pallas tropical-matmul kernel ``repro.kernels.minplus``), batched over
  N problem instances at once;
* the K-sequence segmentation DPs (seq and bottleneck-capped pipe variants)
  are ``lax.scan``s over segment count with dense (e2, e[, tau]) transition
  tensors; the round-trip training variants (mode=TR, schedule=pipe, M > 1 —
  docs/training.md) reuse the same scans under per-direction (F, B) cap
  scans mirroring dfts._dfts_pipe_tr and segmentation._run_k_seq_pipe_tr.

Bit-parity contract (tests/test_jax_solvers.py): every encoded cost uses the
exact same IEEE-754 operations in the same order as the scalar oracles, +inf
marks infeasible/padded entries (absorbing under min-plus), and every argmin
is first-occurrence — so plans, latencies, and BCD trajectories are
bit-identical to the NumPy solvers, not merely close.  Padding (candidate
sets to a power-of-two S, batches to a power-of-two N with all-inf dummies,
tau grids to a power-of-two T) can therefore never change a result, only
bound the number of jit specializations.

JAX is imported lazily (first solve), under a local ``enable_x64`` scope so
the global precision default is untouched.  Importing this module without
jax installed raises ImportError, which the engine's ``_ensure_builtins``
treats as "scalar solvers only".
"""
from __future__ import annotations

import functools
import importlib.util
import time
from dataclasses import replace
from types import SimpleNamespace

import numpy as np

if importlib.util.find_spec("jax") is None:  # pragma: no cover
    raise ImportError("repro.core.jax_solvers requires jax "
                      "(scalar solvers remain available without it)")

from .costmodel import (BW, FW, PIPE, SEQ, TR, ModelProfile, dirs_for_mode,
                        even_split)
from .dfts import _stage_path, dfts
from .engine import register_solver
from .network import PhysicalNetwork, transmission_time_s
from .plan import (EvalCache, LatencyBreakdown, Plan, PlanEvaluator,
                   ServiceChainRequest)
from .problem import ProblemInstance, SolveResult

INF = float("inf")

# ----------------------------------------------------------------- memo tables
# All memos key on *content* (net.content_key() / profile.content_key()), so
# they are safe across distinct-but-equal objects and are never invalidated by
# mutation (a mutated network has a new content key).  Bounded: cleared
# wholesale past _MEMO_CAP entries — they are caches, not state.
_MEMO_CAP = 4096
_ENCODE_MEMO: dict = {}   # (inst key, segments) -> _EncodedSeq
_GRID_MEMO: dict = {}     # (net, profile, b, mode, node) -> (L+1, L+1) grid
_SHIP_MEMO: dict = {}     # per-path cut-shipping vectors (seq segmentation)
_PATH_MEMO: dict = {}     # (net, src, dst, fw, bw, cap, scale) -> path tuple
_PATHCOST_MEMO: dict = {}  # (net, path, fw, bw) -> (trans, prop, max link)
_NODEVEC_MEMO: dict = {}  # (net, b) -> per-node coefficient arrays
_PROFILE_MEMO: dict = {}  # (profile, mode) -> dense cumsum/peak tables
_PLAN_MEMO: dict = {}     # (enc key, scan output, cap, scale) -> (Plan, lb)


def _memo_put(memo: dict, key, val):
    if len(memo) >= _MEMO_CAP:
        memo.clear()
    memo[key] = val
    return val


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _inst_key(net: PhysicalNetwork, profile: ModelProfile,
              request: ServiceChainRequest, cands) -> tuple:
    # fast path: engine-canonical candidates are already tuple-of-tuples
    if not (type(cands) is tuple
            and (not cands or type(cands[0]) is tuple)):
        cands = tuple(tuple(c) for c in cands)
    return (net.content_key(), profile.content_key(), request, cands)


@functools.lru_cache(maxsize=1024)
def _even_split_t(L: int, K: int) -> tuple:
    """``even_split`` as a hashable tuple-of-tuples (hot in the batch path)."""
    return tuple(even_split(L, K))


# ------------------------------------------------------------- lazy jax bundle
@functools.lru_cache(maxsize=1)
def _jx() -> SimpleNamespace:
    """Import jax once and build the jitted scan kernels.

    Everything here runs in float64 (callers wrap calls in ``enable_x64``):
    bit-parity with the NumPy oracles needs full doubles, and the DP state is
    tiny, so there is no precision/perf trade to make.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.minplus import minplus_matmul

    @functools.partial(jax.jit, static_argnames=("use_pallas",))
    def dfts_scan(comp, D, tail, *, use_pallas=False):
        """Batched DFTS tour relaxation.

        comp (N, K, S): per-stage candidate compute (+inf infeasible/padded),
        already cap-filtered and 1/M-scaled by the caller for capped tours.
        D (N, K-1, S, S): frontier matrix of stage k-1 sources x stage k
        targets.  tail (N, S): last-stage candidate -> destination frontier.
        Returns (total (N,), tail_src (N,), srcs (K-1, N, S)).
        """
        best0 = comp[:, 0, :]
        xs = (jnp.moveaxis(D, 1, 0), jnp.moveaxis(comp[:, 1:, :], 1, 0))

        def step(best, x):
            d_k, c_k = x
            if use_pallas:
                val, idx = minplus_matmul(best[:, None, :], d_k)
                dist, src = val[:, 0, :], idx[:, 0, :]
            else:
                cand = best[:, :, None] + d_k  # (N, S, S)
                dist = cand.min(axis=1)
                src = cand.argmin(axis=1)
            return dist + c_k, src

        best, srcs = jax.lax.scan(step, best0, xs)
        tot = best + tail
        return tot.min(axis=1), tot.argmin(axis=1), srcs

    @jax.jit
    def kseq_scan(scost, valid):
        """Sequential K-sequence segmentation DP.

        scost (K, L+1, L+1): scost[k, e2, e] = segcost(stage k, lo=e2+1,
        hi=e) (+inf infeasible); valid (K, L+1): admissible e per stage.
        Returns (dp_K (L+1,), choices (K-1, L+1)) with first-argmin choices,
        matching the oracle's first-strict-improvement update.
        """
        Lp1 = scost.shape[1]
        tri = jnp.arange(Lp1)[:, None] < jnp.arange(Lp1)[None, :]
        dp1 = jnp.where(valid[0], scost[0, 0, :], jnp.inf)

        def step(dp, x):
            sc_k, valid_k = x
            cand = jnp.where(tri, dp[:, None] + sc_k, jnp.inf)
            return (jnp.where(valid_k, cand.min(axis=0), jnp.inf),
                    cand.argmin(axis=0))

        dp, choices = jax.lax.scan(step, dp1, (scost[1:], valid[1:]))
        return dp, choices

    @jax.jit
    def kseq_pipe_scan(sfill, ssmax, valid, taus):
        """Pipelined segmentation DP, vectorized over bottleneck caps.

        sfill/ssmax (K, L+1, L+1): fill cost and stage-time max of segment
        (lo=e2+1, hi=e) per stage; valid (K, L+1); taus (T,) candidate caps
        (+inf padded).  dp[k, e, t] considers only segments with stage time
        <= taus[t].  Returns (dp_K (L+1, T), choices (K-1, L+1, T)).
        """
        Lp1 = sfill.shape[1]
        tri = jnp.arange(Lp1)[:, None] < jnp.arange(Lp1)[None, :]
        dp1 = jnp.where(
            valid[0][:, None] & (taus[None, :] >= ssmax[0, 0, :, None]),
            sfill[0, 0, :, None], jnp.inf)

        def step(dp, x):
            sf, sm, valid_k = x
            segc = jnp.where(taus[None, None, :] >= sm[:, :, None],
                             sf[:, :, None], jnp.inf)  # (e2, e, T)
            cand = jnp.where(tri[:, :, None], dp[:, None, :] + segc, jnp.inf)
            dp_new = jnp.where(valid_k[:, None], cand.min(axis=0), jnp.inf)
            return dp_new, cand.argmin(axis=0)

        dp, choices = jax.lax.scan(step, dp1,
                                   (sfill[1:], ssmax[1:], valid[1:]))
        return dp, choices

    return SimpleNamespace(jax=jax, jnp=jnp, x64=enable_x64,
                           dfts_scan=dfts_scan, kseq_scan=kseq_scan,
                           kseq_pipe_scan=kseq_pipe_scan)


# --------------------------------------------------------------- dense encode
def _node_vectors(net: PhysicalNetwork, b: int) -> SimpleNamespace:
    """Per-node compute/capacity coefficient arrays in node_index order."""
    key = (net.content_key(), b)
    hit = _NODEVEC_MEMO.get(key)
    if hit is not None:
        return hit
    names = sorted(net.nodes)
    n = len(names)
    a = np.empty(n)
    beta = np.empty(n)
    tau = np.empty(n)
    mem = np.empty(n)
    disk = np.empty(n)
    for i, name in enumerate(names):
        spec = net.nodes[name]
        ak, bk = spec.compute._coeffs(b)
        a[i], beta[i] = ak, bk
        # exactly ComputeModel.tau_s
        tau[i] = max(0.0, (spec.compute.alpha_tau * b
                           + spec.compute.beta_tau)) / 1e3
        mem[i], disk[i] = spec.mem_capacity, spec.disk_capacity
    return _memo_put(_NODEVEC_MEMO, key, SimpleNamespace(
        a=a, beta=beta, tau=tau, mem=mem, disk=disk))


def _profile_tables(profile: ModelProfile, mode: str) -> SimpleNamespace:
    """Dense prefix-sum / peak-smashed tables mirroring ModelProfile exactly.

    The cumsum arrays are numpy views of the profile's own python-float
    prefix sums, so ``c[hi] - c[lo-1]`` is the same subtraction of the same
    doubles the scalar ``seg_*`` methods perform.
    """
    key = (profile.content_key(), mode)
    hit = _PROFILE_MEMO.get(key)
    if hit is not None:
        return hit
    cum = profile._cumsums()
    L = profile.L
    cfw = np.asarray(cum[(FW, "flops")])
    cbw = np.asarray(cum[(BW, "flops")])
    cmem = np.asarray(cum["mem"])
    cdisk = np.asarray(cum["disk"])
    m = np.asarray([max(layer.smashed_bytes(d) for d in dirs_for_mode(mode))
                    for layer in profile.layers])
    # peak[lo, hi] = max(m[lo-1 .. hi-1]); IEEE max is order-independent,
    # matching seg_peak_smashed's running max.
    peak = np.zeros((L + 1, L + 1))
    for lo in range(1, L + 1):
        peak[lo, lo:] = np.maximum.accumulate(m[lo - 1:])

    def seg_grid(c):
        lo = np.arange(L + 1)
        return c[None, :] - c[np.maximum(lo - 1, 0)][:, None]  # [lo, hi]

    out = SimpleNamespace(L=L, phi_fw=seg_grid(cfw), phi_bw=seg_grid(cbw),
                          mem=seg_grid(cmem), disk=seg_grid(cdisk), peak=peak)
    return _memo_put(_PROFILE_MEMO, key, out)


def _comp_fits_grid(net: PhysicalNetwork, profile: ModelProfile,
                    request: ServiceChainRequest, node: str) -> np.ndarray:
    """(L+1, L+1) grid [lo, hi] of segment_comp_s at ``node`` (+inf where
    segment_fits fails or lo > hi).  Bit-equal to the EvalCache entries."""
    b = request.batch_size
    key = (net.content_key(), profile.content_key(), b, request.mode, node)
    hit = _GRID_MEMO.get(key)
    if hit is not None:
        return hit
    pt = _profile_tables(profile, request.mode)
    spec = net.nodes[node]
    a, beta = spec.compute._coeffs(b)
    tau = max(0.0, (spec.compute.alpha_tau * b + spec.compute.beta_tau)) / 1e3
    # total = (kappa_fw + tau) [+ (kappa_bw + tau)] — the oracle's 0.0 + FW
    # + BW accumulation order.
    comp = np.maximum(0.0, (a * b + beta) * pt.phi_fw) / 1e3 + tau
    if request.mode == TR:
        comp = comp + (np.maximum(0.0, (a * b + beta) * pt.phi_bw) / 1e3 + tau)
    mem_load = pt.mem + b * pt.peak  # mem += b * peak
    fits = (pt.disk <= spec.disk_capacity) & (mem_load <= spec.mem_capacity)
    grid = np.where(fits, comp, INF)
    lo = np.arange(pt.L + 1)
    grid[(lo[:, None] > lo[None, :]) | (lo[:, None] < 1)] = INF
    grid.setflags(write=False)
    return _memo_put(_GRID_MEMO, key, grid)


def _comp_fits_grid_dir(net: PhysicalNetwork, profile: ModelProfile,
                        request: ServiceChainRequest, node: str,
                        direction: str) -> np.ndarray:
    """(L+1, L+1) grid [lo, hi] of ``trainpipe.segment_comp_dir_s`` at
    ``node`` (+inf where segment_fits fails or lo > hi) — the per-direction
    twin of `_comp_fits_grid`, keyed with the direction appended (the 6-tuple
    is length-disjoint from the fused 5-tuple keys in the shared memo)."""
    b = request.batch_size
    key = (net.content_key(), profile.content_key(), b, request.mode, node,
           direction)
    hit = _GRID_MEMO.get(key)
    if hit is not None:
        return hit
    pt = _profile_tables(profile, request.mode)
    spec = net.nodes[node]
    a, beta = spec.compute._coeffs(b)
    tau = max(0.0, (spec.compute.alpha_tau * b + spec.compute.beta_tau)) / 1e3
    phi = pt.phi_fw if direction == FW else pt.phi_bw
    comp = np.maximum(0.0, (a * b + beta) * phi) / 1e3 + tau
    mem_load = pt.mem + b * pt.peak
    fits = (pt.disk <= spec.disk_capacity) & (mem_load <= spec.mem_capacity)
    grid = np.where(fits, comp, INF)
    lo = np.arange(pt.L + 1)
    grid[(lo[:, None] > lo[None, :]) | (lo[:, None] < 1)] = INF
    grid.setflags(write=False)
    return _memo_put(_GRID_MEMO, key, grid)


class _EncodedSeq(SimpleNamespace):
    """Dense arrays of one (instance, segments) DFTS tour: comp (K, Sp),
    D (K-1, Sp, Sp), tail (Sp,), plus cut_sizes/cands/tail_bw metadata."""


def _encode_seq(net: PhysicalNetwork, profile: ModelProfile,
                request: ServiceChainRequest, K: int, cands,
                segments) -> _EncodedSeq:
    if not (type(segments) is tuple
            and (not segments or type(segments[0]) is tuple)):
        segments = tuple(tuple(s) for s in segments)
    key = (_inst_key(net, profile, request, cands), segments)
    hit = _ENCODE_MEMO.get(key)
    if hit is not None:
        return hit
    cands = [list(c) for c in cands]
    b = request.batch_size
    training = request.mode == TR
    round_trip = (training and request.schedule == PIPE
                  and request.microbatches() > 1)
    idx = net.node_index()
    Sp = _pow2(max(len(c) for c in cands))
    comp = np.full((K, Sp), INF)
    comp_fw = comp_bw = None
    if round_trip:
        comp_fw = np.full((K, Sp), INF)
        comp_bw = np.full((K, Sp), INF)
    for k, (lo, hi) in enumerate(segments):
        # one memoized grid per node: gather the (lo, hi) scalar per candidate
        comp[k, :len(cands[k])] = [
            _comp_fits_grid(net, profile, request, n)[lo, hi]
            for n in cands[k]]
        if round_trip:
            comp_fw[k, :len(cands[k])] = [
                _comp_fits_grid_dir(net, profile, request, n, FW)[lo, hi]
                for n in cands[k]]
            comp_bw[k, :len(cands[k])] = [
                _comp_fits_grid_dir(net, profile, request, n, BW)[lo, hi]
                for n in cands[k]]
    cut_sizes: list[tuple[float, float | None]] = [(0.0, None)] * K
    D = np.full((K - 1, Sp, Sp), INF)
    for k in range(1, K):
        cut = segments[k - 1][1]
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        cut_sizes[k] = (fw, bw)
        Dfull = net.frontier_matrix(tuple(cands[k - 1]), fw, bw)
        cols = [idx[n] for n in cands[k]]
        D[k - 1, :len(cands[k - 1]), :len(cands[k])] = Dfull[:, cols]
    tail_bw = 0.0 if training else None
    tail = np.full(Sp, INF)
    tail_mat = net.frontier_matrix(tuple(cands[K - 1]), 0.0, tail_bw)
    tail[:len(cands[K - 1])] = tail_mat[:, idx[request.destination]]
    enc = _EncodedSeq(comp=comp, comp_fw=comp_fw, comp_bw=comp_bw, D=D,
                      tail=tail, cut_sizes=cut_sizes, cands=cands,
                      segments=segments, tail_bw=tail_bw, Sp=Sp, key=key)
    return _memo_put(_ENCODE_MEMO, key, enc)


# --------------------------------------------------------- decode + fast eval
def _stage_path_memo(net: PhysicalNetwork, src: str, dst: str, fw: float,
                     bw: float | None, cap: float | None = None,
                     scale: float = 1.0,
                     cap_bw: float | None = None) -> tuple:
    key = (net.content_key(), src, dst, fw, bw, cap, scale, cap_bw)
    hit = _PATH_MEMO.get(key)
    if hit is None:
        hit = _memo_put(_PATH_MEMO, key,
                        tuple(_stage_path(net, src, dst, fw, bw, cap, scale,
                                          cap_bw)))
    return hit


def _path_cost(net: PhysicalNetwork, path: tuple, fw: float,
               bw: float | None) -> tuple[float, float, float]:
    """(transmission, propagation, max single-link transmission) of a path —
    computed by the network's own exact functions, memoized by content."""
    key = (net.content_key(), path, fw, bw)
    hit = _PATHCOST_MEMO.get(key)
    if hit is None:
        trans, prop = net.path_cost_breakdown(list(path), fw, bw)
        maxlink = 0.0
        for u, v in zip(path, path[1:]):
            maxlink = max(maxlink, net.link_trans_s(u, v, fw, bw))
        hit = _memo_put(_PATHCOST_MEMO, key, (trans, prop, maxlink))
    return hit


def _path_dir_vectors(net: PhysicalNetwork, path: tuple, size_bytes: float,
                      direction: str) -> tuple[tuple, tuple]:
    """Per-link (transmission times, propagation delays) of shipping
    ``size_bytes`` along ``path`` in one direction, in link order — the
    round-trip evaluator accumulates per link, so the memo keeps the vectors
    (the direction string keeps keys disjoint from `_path_cost` entries)."""
    key = (net.content_key(), path, size_bytes, direction)
    hit = _PATHCOST_MEMO.get(key)
    if hit is None:
        ts, ds = [], []
        for u, v in zip(path, path[1:]):
            link = net.links[(u, v)]
            ts.append(transmission_time_s(size_bytes, link.rate(direction)))
            ds.append(link.delay(direction))
        hit = _memo_put(_PATHCOST_MEMO, key, (tuple(ts), tuple(ds)))
    return hit


def _plan_comp_vals(net: PhysicalNetwork, profile: ModelProfile,
                    request: ServiceChainRequest, plan: Plan) -> list[float]:
    return [float(_comp_fits_grid(net, profile, request, node)[lo, hi])
            for (lo, hi), node in zip(plan.segments, plan.placement)]


def _plan_comp_vals_dir(net: PhysicalNetwork, profile: ModelProfile,
                        request: ServiceChainRequest, plan: Plan,
                        direction: str) -> list[float]:
    return [float(_comp_fits_grid_dir(net, profile, request, node,
                                      direction)[lo, hi])
            for (lo, hi), node in zip(plan.segments, plan.placement)]


def _fast_evaluate(net: PhysicalNetwork, profile: ModelProfile,
                   request: ServiceChainRequest, plan: Plan) -> LatencyBreakdown:
    """PlanEvaluator.evaluate, bit-for-bit, from memoized components."""
    b = request.batch_size
    training = request.mode == TR
    if (training and request.schedule == PIPE
            and request.microbatches() > 1):
        return _fast_evaluate_round_trip(net, profile, request, plan)
    comp_vals = _plan_comp_vals(net, profile, request, plan)
    if request.schedule == PIPE:
        M = request.microbatches()
        comp_s = trans_s = prop_s = 0.0
        tau = 0.0
        for t in comp_vals:
            comp_s += t / M
            tau = max(tau, t)
        for k, path in enumerate(plan.paths):
            cut = plan.segments[k][1]
            fw = b * profile.cut_bytes(cut, FW)
            bw = b * profile.cut_bytes(cut, BW) if training else None
            trans, prop, ml = _path_cost(net, tuple(path), fw, bw)
            trans_s += trans / M
            prop_s += prop
            tau = max(tau, ml)
        if plan.tail_path:
            _, prop, _ = _path_cost(net, tuple(plan.tail_path), 0.0, None)
            prop_s += prop
        return LatencyBreakdown(comp_s, trans_s, prop_s, (M - 1) * tau / M)
    comp_s = trans_s = prop_s = 0.0
    for t in comp_vals:
        comp_s += t
    for k, path in enumerate(plan.paths):
        cut = plan.segments[k][1]
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        trans, prop, _ = _path_cost(net, tuple(path), fw, bw)
        trans_s += trans
        prop_s += prop
    if plan.tail_path:
        _, prop, _ = _path_cost(net, tuple(plan.tail_path), 0.0, None)
        prop_s += prop
    return LatencyBreakdown(comp_s, trans_s, prop_s)


def _fast_evaluate_round_trip(net: PhysicalNetwork, profile: ModelProfile,
                              request: ServiceChainRequest,
                              plan: Plan) -> LatencyBreakdown:
    """``trainpipe.evaluate_round_trip``, bit-for-bit, from memoized
    components — the same per-link / per-stage accumulation order (forward
    wave, psi_K = 0 tail, backward wave), so totals are identical doubles."""
    b = request.batch_size
    M = request.microbatches()
    comp_s = trans_s = prop_s = 0.0
    tau_fw = tau_bw = 0.0
    for t in _plan_comp_vals_dir(net, profile, request, plan, FW):
        comp_s += t / M
        tau_fw = max(tau_fw, t)
    for k, path in enumerate(plan.paths):
        fw = b * profile.cut_bytes(plan.segments[k][1], FW)
        ts, ds = _path_dir_vectors(net, tuple(path), fw, FW)
        for t, d in zip(ts, ds):
            trans_s += t / M
            prop_s += d
            tau_fw = max(tau_fw, t)
    if plan.tail_path:  # psi_K = 0: forward propagation only
        _, prop, _ = _path_cost(net, tuple(plan.tail_path), 0.0, None)
        prop_s += prop
    for t in _plan_comp_vals_dir(net, profile, request, plan, BW):
        comp_s += t / M
        tau_bw = max(tau_bw, t)
    for k, path in enumerate(plan.paths):
        bw = b * profile.cut_bytes(plan.segments[k][1], BW)
        ts, ds = _path_dir_vectors(net, tuple(path), bw, BW)
        for t, d in zip(ts, ds):
            trans_s += t / M
            prop_s += d
            tau_bw = max(tau_bw, t)
    return LatencyBreakdown(comp_s, trans_s, prop_s,
                            (M - 1) * (tau_fw + tau_bw) / M)


def _fast_round_trip_taus(net: PhysicalNetwork, profile: ModelProfile,
                          request: ServiceChainRequest,
                          plan: Plan) -> tuple[float, float]:
    """``trainpipe.round_trip_taus`` from the memoized components."""
    b = request.batch_size
    tau_fw = max(_plan_comp_vals_dir(net, profile, request, plan, FW))
    tau_bw = max(_plan_comp_vals_dir(net, profile, request, plan, BW))
    for k, path in enumerate(plan.paths):
        cut = plan.segments[k][1]
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW)
        for t in _path_dir_vectors(net, tuple(path), fw, FW)[0]:
            tau_fw = max(tau_fw, t)
        for t in _path_dir_vectors(net, tuple(path), bw, BW)[0]:
            tau_bw = max(tau_bw, t)
    return tau_fw, tau_bw


def _fast_latency(net, profile, request, plan) -> float:
    return _fast_evaluate(net, profile, request, plan).total_s


def _fast_bottleneck(net: PhysicalNetwork, profile: ModelProfile,
                     request: ServiceChainRequest, plan: Plan) -> float:
    b = request.batch_size
    training = request.mode == TR
    tau = max(_plan_comp_vals(net, profile, request, plan))
    for k, path in enumerate(plan.paths):
        cut = plan.segments[k][1]
        fw = b * profile.cut_bytes(cut, FW)
        bw = b * profile.cut_bytes(cut, BW) if training else None
        tau = max(tau, _path_cost(net, tuple(path), fw, bw)[2])
    return tau


def _decode_seq(net: PhysicalNetwork, request: ServiceChainRequest,
                enc: _EncodedSeq, tail_src: int, srcs: np.ndarray,
                cap: float | None = None, scale: float = 1.0,
                cap_bw: float | None = None) -> Plan:
    """Backtrack one instance's placement/paths from the scan outputs —
    exactly the oracle's backtracking (same memoized sssp parent trees)."""
    K = len(enc.segments)
    placement = [""] * K
    pi = int(tail_src)
    placement[K - 1] = enc.cands[K - 1][pi]
    for k in range(K - 1, 0, -1):
        pi = int(srcs[k - 1, pi])
        placement[k - 1] = enc.cands[k - 1][pi]
    paths = [list(_stage_path_memo(net, placement[k - 1], placement[k],
                                   *enc.cut_sizes[k], cap, scale, cap_bw))
             for k in range(1, K)]
    # the tail ships zero bytes, so the backward cap never prunes its links
    tail = _stage_path_memo(net, placement[K - 1], request.destination, 0.0,
                            enc.tail_bw if cap is None and scale == 1.0
                            else None, cap, scale)
    return Plan(segments=[tuple(s) for s in enc.segments],
                placement=placement, paths=paths,
                tail_path=list(tail) if len(tail) > 1 else [])


def _decode_eval_seq(net: PhysicalNetwork, profile: ModelProfile,
                     request: ServiceChainRequest, enc: _EncodedSeq,
                     tail_src, srcs: np.ndarray, cap: float | None = None,
                     scale: float = 1.0, cap_bw: float | None = None
                     ) -> tuple[Plan, LatencyBreakdown]:
    """Backtrack + evaluate, memoized by the *scan output* (plus the encode's
    content key): recurring instances pay only the DP scan on warm calls —
    the optimization itself always runs; only the derived backtracking/
    path/latency reconstruction is cached, like the oracle's EvalCache."""
    key = (enc.key, int(tail_src), srcs.tobytes(), cap, scale, cap_bw)
    hit = _PLAN_MEMO.get(key)
    if hit is None:
        plan = _decode_seq(net, request, enc, tail_src, srcs, cap, scale,
                           cap_bw)
        hit = _memo_put(_PLAN_MEMO, key,
                        (plan, _fast_evaluate(net, profile, request, plan)))
    return hit


# ------------------------------------------------------------------- DFTS jax
def _run_dfts_scan(comp, D, tail, use_pallas: bool):
    J = _jx()
    with J.x64():
        total, tail_src, srcs = J.dfts_scan(
            J.jnp.asarray(comp), J.jnp.asarray(D), J.jnp.asarray(tail),
            use_pallas=use_pallas)
        return (np.asarray(total), np.asarray(tail_src), np.asarray(srcs))


def _dfts_jax_seq(net, profile, request, K, cands, segments,
                  use_pallas: bool) -> tuple[Plan, LatencyBreakdown] | None:
    enc = _encode_seq(net, profile, request, K, cands, segments)
    total, tail_src, srcs = _run_dfts_scan(
        enc.comp[None], enc.D[None], enc.tail[None], use_pallas)
    if not np.isfinite(total[0]):
        return None
    return _decode_eval_seq(net, profile, request, enc, tail_src[0],
                            srcs[:, 0])


def _capped_tour_jax(net, profile, request, enc: _EncodedSeq,
                     cap: float | None, inv_M: float, use_pallas: bool
                     ) -> tuple[Plan, LatencyBreakdown] | None:
    """The bottleneck-capped tour of `_dfts_pipe`, on the dense encode."""
    K = len(enc.segments)
    cap_cmp = INF if cap is None else cap
    ceff = np.where(enc.comp <= cap_cmp, enc.comp * inv_M, INF)
    idx = net.node_index()
    Sp = enc.Sp
    D = np.full((K - 1, Sp, Sp), INF)
    for k in range(1, K):
        fw, bw = enc.cut_sizes[k]
        Dfull = net.frontier_matrix(tuple(enc.cands[k - 1]), fw, bw, cap,
                                    inv_M)
        cols = [idx[n] for n in enc.cands[k]]
        D[k - 1, :len(enc.cands[k - 1]), :len(enc.cands[k])] = Dfull[:, cols]
    tail = np.full(Sp, INF)
    tail_mat = net.frontier_matrix(tuple(enc.cands[K - 1]), 0.0, None, cap,
                                   inv_M)
    tail[:len(enc.cands[K - 1])] = tail_mat[:, idx[request.destination]]
    total, tail_src, srcs = _run_dfts_scan(ceff[None], D[None], tail[None],
                                           use_pallas)
    if not np.isfinite(total[0]):
        return None
    return _decode_eval_seq(net, profile, request, enc, tail_src[0],
                            srcs[:, 0], cap, inv_M)


def _dfts_jax_pipe(net, profile, request, K, cands, segments,
                   use_pallas: bool) -> Plan | None:
    """`_dfts_pipe` with every capped tour on the jitted scan; identical
    candidate-tau enumeration, incumbent bounds, and break conditions."""
    enc = _encode_seq(net, profile, request, K, cands, segments)
    comp = enc.comp
    for k in range(K):
        if not np.isfinite(comp[k, :len(enc.cands[k])]).any():
            return None
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    lb = max(float(comp[k][np.isfinite(comp[k])].min()) for k in range(K))
    taus = {float(v) for k in range(K) for v in comp[k][np.isfinite(comp[k])]}
    for k in range(1, K):
        fw, bw = enc.cut_sizes[k]
        for (u, v) in net.links:
            taus.add(net.link_trans_s(u, v, fw, bw))
    cand_taus = sorted(t for t in taus if t >= lb)

    pair0 = _capped_tour_jax(net, profile, request, enc, None, inv_M,
                             use_pallas)
    if pair0 is None:
        return None
    plan0, best_lb = pair0
    best_pair, best_lat = pair0, best_lb.total_s
    fill_min = (best_lb.computation_s + best_lb.transmission_s
                + best_lb.propagation_s)
    tau0 = _fast_bottleneck(net, profile, request, plan0)

    for tau in cand_taus:
        if tau >= tau0 or fill_min + c_bub * tau >= best_lat:
            break
        pair_t = _capped_tour_jax(net, profile, request, enc, tau, inv_M,
                                  use_pallas)
        if pair_t is None:
            continue
        lat = pair_t[1].total_s
        if lat < best_lat:
            best_pair, best_lat = pair_t, lat
    return best_pair


def _capped_tour_jax_tr(net, profile, request, enc: _EncodedSeq,
                        cap_fw: float, cap_bw: float, inv_M: float,
                        use_pallas: bool
                        ) -> tuple[Plan, LatencyBreakdown] | None:
    """The per-direction-capped round-trip tour of `dfts._capped_tour_tr`,
    on the dense encode: candidates pruned to comp_fw <= cap_fw AND
    comp_bw <= cap_bw, links pruned per direction inside the frontier
    matrices."""
    K = len(enc.segments)
    ceff = np.where((enc.comp_fw <= cap_fw) & (enc.comp_bw <= cap_bw),
                    enc.comp * inv_M, INF)
    idx = net.node_index()
    Sp = enc.Sp
    D = np.full((K - 1, Sp, Sp), INF)
    for k in range(1, K):
        fw, bw = enc.cut_sizes[k]
        Dfull = net.frontier_matrix(tuple(enc.cands[k - 1]), fw, bw, cap_fw,
                                    inv_M, cap_bw)
        cols = [idx[n] for n in enc.cands[k]]
        D[k - 1, :len(enc.cands[k - 1]), :len(enc.cands[k])] = Dfull[:, cols]
    # psi_K = 0 tail: zero bytes ship, so the caps never prune a tail link
    tail = np.full(Sp, INF)
    tail_mat = net.frontier_matrix(tuple(enc.cands[K - 1]), 0.0, None, cap_fw,
                                   inv_M)
    tail[:len(enc.cands[K - 1])] = tail_mat[:, idx[request.destination]]
    total, tail_src, srcs = _run_dfts_scan(ceff[None], D[None], tail[None],
                                           use_pallas)
    if not np.isfinite(total[0]):
        return None
    return _decode_eval_seq(net, profile, request, enc, tail_src[0],
                            srcs[:, 0], cap_fw, inv_M, cap_bw)


def _dfts_jax_pipe_tr(net, profile, request, K, cands, segments,
                      use_pallas: bool
                      ) -> tuple[Plan, LatencyBreakdown] | None:
    """`dfts._dfts_pipe_tr` with every capped tour on the jitted scan:
    identical (F, B) pair enumeration, incumbent bound, and skip/break
    conditions, so plans and latencies are bit-identical to the scalar
    oracle (docs/training.md)."""
    enc = _encode_seq(net, profile, request, K, cands, segments)
    for k in range(K):
        if not np.isfinite(enc.comp[k, :len(enc.cands[k])]).any():
            return None
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M

    lb_fw = max(float(enc.comp_fw[k][np.isfinite(enc.comp_fw[k])].min())
                for k in range(K))
    lb_bw = max(float(enc.comp_bw[k][np.isfinite(enc.comp_bw[k])].min())
                for k in range(K))
    fw_vals = {float(v) for k in range(K)
               for v in enc.comp_fw[k][np.isfinite(enc.comp_fw[k])]}
    bw_vals = {float(v) for k in range(K)
               for v in enc.comp_bw[k][np.isfinite(enc.comp_bw[k])]}
    for k in range(1, K):
        fw, bw = enc.cut_sizes[k]
        for (u, v), spec in net.links.items():
            fw_vals.add(transmission_time_s(fw, spec.bw_fw))
            bw_vals.add(transmission_time_s(bw, spec.bw_bw))
    cand_fw = sorted(t for t in fw_vals if t >= lb_fw)
    cand_bw = sorted(t for t in bw_vals if t >= lb_bw)

    pair0 = _capped_tour_jax(net, profile, request, enc, None, inv_M,
                             use_pallas)
    if pair0 is None:
        return None
    plan0, lb0 = pair0
    best_pair, best_lat = pair0, lb0.total_s
    fill_min = lb0.computation_s + lb0.transmission_s + lb0.propagation_s
    tau_fw0, tau_bw0 = _fast_round_trip_taus(net, profile, request, plan0)

    pairs = sorted(((F, B) for F in cand_fw for B in cand_bw),
                   key=lambda p: (p[0] + p[1], p[0]))
    for F, B in pairs:
        if fill_min + c_bub * (F + B) >= best_lat:
            break
        if F >= tau_fw0 and B >= tau_bw0:
            continue
        pair_t = _capped_tour_jax_tr(net, profile, request, enc, F, B, inv_M,
                                     use_pallas)
        if pair_t is None:
            continue
        lat = pair_t[1].total_s
        if lat < best_lat:
            best_pair, best_lat = pair_t, lat
    return best_pair


def _dfts_jax_plan(net, profile, request, segments, cands,
                   use_pallas: bool = False
                   ) -> tuple[Plan, LatencyBreakdown] | None:
    """JAX counterpart of :func:`repro.core.dfts.dfts` (same dispatch),
    returning the plan together with its (memoized) latency breakdown."""
    K = len(segments)
    if request.schedule == PIPE and request.microbatches() > 1:
        if request.mode == TR:
            return _dfts_jax_pipe_tr(net, profile, request, K, cands,
                                     segments, use_pallas)
        return _dfts_jax_pipe(net, profile, request, K, cands, segments,
                              use_pallas)
    return _dfts_jax_seq(net, profile, request, K, cands, segments,
                         use_pallas)


# ----------------------------------------------------------- segmentation jax
def _ship_vectors(net: PhysicalNetwork, profile: ModelProfile,
                  request: ServiceChainRequest, path: tuple):
    """(trans[hi] (L+1,), prop) of shipping the cut after layer hi along
    ``path`` — the oracle's cut_transfer_s, vectorized over hi in link order."""
    b = request.batch_size
    training = request.mode == TR
    key = (net.content_key(), profile.content_key(), b, training, path)
    hit = _SHIP_MEMO.get(key)
    if hit is not None:
        return hit
    L = profile.L
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = (np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
            if training else None)
    trans = np.full(L + 1, INF)
    trans[1:L] = 0.0
    prop = 0.0
    for u, v in zip(path, path[1:]):
        spec = net.links[(u, v)]
        trans[1:L] += transmission_time_s(fw_b, spec.bw_fw)
        prop += spec.delay_fw
        if bw_b is not None:
            trans[1:L] += transmission_time_s(bw_b, spec.bw_bw)
            prop += spec.delay_bw
    return _memo_put(_SHIP_MEMO, key, (trans, prop))


def _valid_mask(K: int, L: int) -> np.ndarray:
    """Admissible dp end-layers per stage: the oracle's e ranges."""
    valid = np.zeros((K, L + 1), dtype=bool)
    valid[0, 1:L - K + 2] = True  # stage 1: e in [1, L-K+1]
    for k in range(2, K):
        valid[k - 1, k:L - K + k + 1] = True
    if K > 1:
        valid[K - 1, :] = False
        valid[K - 1, L] = True  # stage K: e = L only
    return valid


def _segments_from_cuts(cuts: list[int], L: int) -> list[tuple[int, int]]:
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments


def _kseq_jax_seq(net, profile, request, plan: Plan):
    K, L = plan.K, profile.L
    scost = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        cost = np.array(_comp_fits_grid(net, profile, request,
                                        plan.placement[k]))
        if k < K - 1:
            trans, prop = _ship_vectors(net, profile, request,
                                        tuple(plan.paths[k]))
            cost = cost + (trans[None, :] + prop)  # cost += trans + prop
        scost[k, :L, :] = cost[1:, :]  # scost[k, e2, e] = cost[e2+1, e]
    J = _jx()
    with J.x64():
        dp, choices = J.kseq_scan(J.jnp.asarray(scost),
                                  J.jnp.asarray(_valid_mask(K, L)))
        dp = np.asarray(dp)
        choices = np.asarray(choices)
    if not np.isfinite(dp[L]):
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = int(choices[k - 2, e])
        cuts.append(e)
    cuts.reverse()
    return _segments_from_cuts(cuts, L)


def _kseq_jax_pipe(net, profile, request, plan: Plan):
    K, L = plan.K, profile.L
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M
    b = request.batch_size
    training = request.mode == TR

    comp = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        lo_min, hi_max = k + 1, L - (K - 1 - k)
        grid = _comp_fits_grid(net, profile, request, plan.placement[k])
        comp[k, lo_min:hi_max + 1, lo_min:hi_max + 1] = \
            grid[lo_min:hi_max + 1, lo_min:hi_max + 1]

    # shipping tables — the oracle's exact loops (same accumulation order)
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = (np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
            if training else None)
    ship_sum = np.zeros((max(K - 1, 1), L + 1))
    ship_max = np.zeros((max(K - 1, 1), L + 1))
    ship_prop = np.zeros(max(K - 1, 1))
    for k in range(K - 1):
        for u, v in zip(plan.paths[k], plan.paths[k][1:]):
            spec = net.links[(u, v)]
            t = transmission_time_s(fw_b, spec.bw_fw)
            ship_prop[k] += spec.delay_fw
            if bw_b is not None:
                t = t + transmission_time_s(bw_b, spec.bw_bw)
                ship_prop[k] += spec.delay_bw
            ship_sum[k, 1:L] += t
            ship_max[k, 1:L] = np.maximum(ship_max[k, 1:L], t)

    per_stage_min = []
    for k in range(K):
        fin = comp[k][np.isfinite(comp[k])]
        if fin.size == 0:
            return None
        per_stage_min.append(float(fin.min()))
    lb = max(per_stage_min)
    tau_set = set(comp[np.isfinite(comp)].tolist())
    for k in range(K - 1):
        tau_set.update(ship_max[k, 1:L].tolist())
    taus = np.array(sorted(t for t in tau_set if t >= lb))
    if taus.size == 0:
        return None
    T = taus.size

    fill = comp * inv_M
    smax = comp.copy()
    for k in range(K - 1):
        fill[k] = fill[k] + (ship_sum[k][None, :] * inv_M + ship_prop[k])
        smax[k] = np.maximum(smax[k], ship_max[k][None, :])
    sfill = np.full((K, L + 1, L + 1), INF)
    ssmax = np.full((K, L + 1, L + 1), INF)
    sfill[:, :L, :] = fill[:, 1:, :]
    ssmax[:, :L, :] = smax[:, 1:, :]

    taus_pad = np.full(_pow2(T), INF)
    taus_pad[:T] = taus
    J = _jx()
    with J.x64():
        dp, choices = J.kseq_pipe_scan(
            J.jnp.asarray(sfill), J.jnp.asarray(ssmax),
            J.jnp.asarray(_valid_mask(K, L)), J.jnp.asarray(taus_pad))
        dp_KL = np.asarray(dp[L])
        choices = np.asarray(choices)

    tot = dp_KL + c_bub * taus_pad
    t_idx = int(np.argmin(tot))
    if not np.isfinite(tot[t_idx]):
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = int(choices[k - 2, e, t_idx])
        cuts.append(e)
    cuts.reverse()
    return _segments_from_cuts(cuts, L)


def _run_pipe_dp_jax(sfill, ssmax, valid, taus):
    """``segmentation._pipe_dp_np`` on the jitted ``kseq_pipe_scan``: pads
    the cap grid to a power of two with +inf caps (absorbing; the first
    ``len(taus)`` columns stay aligned, as the shared driver requires) and
    returns the dp row at [K, L] plus the scan's first-occurrence choice
    lookup."""
    L = sfill.shape[1] - 1
    taus_pad = np.full(_pow2(max(taus.size, 1)), INF)
    taus_pad[:taus.size] = taus
    J = _jx()
    with J.x64():
        dp, choices = J.kseq_pipe_scan(
            J.jnp.asarray(sfill), J.jnp.asarray(ssmax),
            J.jnp.asarray(valid), J.jnp.asarray(taus_pad))
        dp_KL = np.asarray(dp[L])
        choices = np.asarray(choices)
    return dp_KL, lambda k, e, t: int(choices[k - 2, e, t])


def _kseq_jax_pipe_tr(net, profile, request, plan: Plan):
    """`segmentation._k_seq_pipe_tr` with the inner DP on the jitted scan:
    the (K, L+1, L+1) grids are rebuilt bit-identically from the memoized
    dense tables, then the *shared* driver `_run_k_seq_pipe_tr` executes the
    forward-cap scan — same control flow by construction, so segment choices
    match the scalar oracle exactly (docs/training.md)."""
    from .segmentation import _run_k_seq_pipe_tr

    K, L = plan.K, profile.L
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M
    b = request.batch_size
    paths = plan.paths

    comp = np.full((K, L + 1, L + 1), INF)
    comp_fw = np.full((K, L + 1, L + 1), INF)
    comp_bw = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        lo_min, hi_max = k + 1, L - (K - 1 - k)
        w = slice(lo_min, hi_max + 1)
        node = plan.placement[k]
        comp[k, w, w] = _comp_fits_grid(net, profile, request, node)[w, w]
        comp_fw[k, w, w] = _comp_fits_grid_dir(net, profile, request, node,
                                               FW)[w, w]
        comp_bw[k, w, w] = _comp_fits_grid_dir(net, profile, request, node,
                                               BW)[w, w]

    # shipping tables — segmentation._tr_stage_grids' exact loops
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
    ship_sum = np.zeros((max(K - 1, 1), L + 1))
    ship_prop = np.zeros(max(K - 1, 1))
    ship_max_fw = np.zeros((max(K - 1, 1), L + 1))
    ship_max_bw = np.zeros((max(K - 1, 1), L + 1))
    for k in range(K - 1):
        for u, v in zip(paths[k], paths[k][1:]):
            spec = net.links[(u, v)]
            t_fw = transmission_time_s(fw_b, spec.bw_fw)
            t_bw = transmission_time_s(bw_b, spec.bw_bw)
            ship_prop[k] += spec.delay_fw + spec.delay_bw
            ship_sum[k, 1:L] += t_fw + t_bw
            ship_max_fw[k, 1:L] = np.maximum(ship_max_fw[k, 1:L], t_fw)
            ship_max_bw[k, 1:L] = np.maximum(ship_max_bw[k, 1:L], t_bw)

    fill = comp * inv_M
    sfmax = comp_fw.copy()
    sbmax = comp_bw.copy()
    for k in range(K - 1):
        fill[k] = fill[k] + (ship_sum[k][None, :] * inv_M + ship_prop[k])
        sfmax[k] = np.maximum(sfmax[k], ship_max_fw[k][None, :])
        sbmax[k] = np.maximum(sbmax[k], ship_max_bw[k][None, :])
    return _run_k_seq_pipe_tr(K, L, c_bub, fill, sfmax, sbmax,
                              _run_pipe_dp_jax)


def _kseq_jax(net, profile, request, plan: Plan):
    """JAX counterpart of k_sequence_segmentation (same dispatch)."""
    if request.schedule == PIPE and request.microbatches() > 1:
        if request.mode == TR:
            return _kseq_jax_pipe_tr(net, profile, request, plan)
        return _kseq_jax_pipe(net, profile, request, plan)
    return _kseq_jax_seq(net, profile, request, plan)


# ----------------------------------------------------------------- solvers
def _split_place(net, profile, request, K, candidates, dfts_fn):
    """Shared even-split -> DFTS -> min-memory-fallback control flow of the
    ``dfts_np``/``dfts_jax`` one-shot solvers (identical by construction).
    ``dfts_fn`` returns a Plan (np) or a (Plan, breakdown) pair (jax); this
    only checks feasibility (None) and passes the result through."""
    segments = even_split(profile.L, K)
    res = dfts_fn(segments)
    if res is None:
        from .baselines import min_memory_split  # local import avoids a cycle

        alt = min_memory_split(profile, request, K)
        if alt is not None and alt != segments:
            res = dfts_fn(alt)
    return res


def dfts_np_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
) -> SolveResult:
    """Scalar NumPy twin of ``dfts_jax``: even split + one DFTS tour (the
    oracle implementation), min-memory fallback.  The benchmark's baseline."""
    t0 = time.perf_counter()
    cache = cache if cache is not None else EvalCache()
    ev = PlanEvaluator(net, profile, request, cache=cache)
    plan = _split_place(
        net, profile, request, K, candidates,
        lambda segs: dfts(net, profile, request, segs, candidates,
                          cache=cache))
    if plan is None:
        return SolveResult(None, None, time.perf_counter() - t0, 0,
                           solver="dfts_np")
    return SolveResult(plan, ev.evaluate(plan), time.perf_counter() - t0, 1,
                       solver="dfts_np")


def dfts_jax_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    cache: EvalCache | None = None,
    use_pallas: bool = False,
) -> SolveResult:
    t0 = time.perf_counter()
    pair = _split_place(
        net, profile, request, K, candidates,
        lambda segs: _dfts_jax_plan(net, profile, request, segs, candidates,
                                    use_pallas=use_pallas))
    if pair is None:
        return SolveResult(None, None, time.perf_counter() - t0, 0,
                           solver="dfts_jax")
    return SolveResult(pair[0], pair[1], time.perf_counter() - t0, 1,
                       solver="dfts_jax")


def _dfts_jax_batch(problems: list[ProblemInstance], *,
                    cache: EvalCache | None = None,
                    use_pallas: bool = False) -> list[SolveResult]:
    """Batched ``dfts_jax``: pad all sequential instances into shared
    (N, K, S) tensors per (K, S-bucket) group and run one scan per group and
    split round; pipelined instances solve per-instance (their bottleneck-cap
    scan is inherently sequential)."""
    t0 = time.perf_counter()
    problems = list(problems)
    results: list[SolveResult | None] = [None] * len(problems)
    plans: dict[int, tuple[Plan, LatencyBreakdown] | None] = {}
    pending: list[tuple[int, list]] = []
    for i, p in enumerate(problems):
        if p.request.schedule == PIPE and p.request.microbatches() > 1:
            results[i] = dfts_jax_solve(*p.solver_args(), cache=cache,
                                        use_pallas=use_pallas)
        else:
            pending.append((i, _even_split_t(p.profile.L, p.K)))

    for round_no in (1, 2):
        if not pending:
            break
        groups: dict[tuple, list[tuple[int, _EncodedSeq]]] = {}
        # recurring batches repeat the same ProblemInstance objects; resolve
        # each distinct (object, segments) through the encode memo once
        enc_by_id: dict[tuple, _EncodedSeq] = {}
        for i, segs in pending:
            p = problems[i]
            ekey = (id(p), segs)
            enc = enc_by_id.get(ekey)
            if enc is None:
                enc = enc_by_id[ekey] = _encode_seq(
                    p.net, p.profile, p.request, p.K, p.candidates, segs)
            groups.setdefault((p.K, enc.Sp), []).append((i, enc))
        failed: list[int] = []
        for (K, Sp), items in groups.items():
            n = len(items)
            Np = _pow2(n)
            comp = np.full((Np, K, Sp), INF)
            D = np.full((Np, K - 1, Sp, Sp), INF)
            tail = np.full((Np, Sp), INF)
            comp[:n] = [enc.comp for _, enc in items]
            D[:n] = [enc.D for _, enc in items]
            tail[:n] = [enc.tail for _, enc in items]
            total, tail_src, srcs = _run_dfts_scan(comp, D, tail, use_pallas)
            finite = np.isfinite(total)
            # (K-1, N, S) -> contiguous (N, K-1, S): per-row views, one copy
            srcs_rows = np.ascontiguousarray(np.moveaxis(srcs, 1, 0))
            for j, (i, enc) in enumerate(items):
                if finite[j]:
                    p = problems[i]
                    plans[i] = _decode_eval_seq(p.net, p.profile, p.request,
                                                enc, tail_src[j],
                                                srcs_rows[j])
                else:
                    plans[i] = None
                    failed.append(i)
        pending = []
        if round_no == 1:
            from .baselines import min_memory_split  # local: avoids a cycle

            for i in failed:
                p = problems[i]
                alt = min_memory_split(p.profile, p.request, p.K)
                if alt is not None:
                    alt = tuple(alt)
                    if alt != _even_split_t(p.profile.L, p.K):
                        pending.append((i, alt))

    share = (time.perf_counter() - t0) / max(1, len(problems))
    for i in range(len(problems)):
        if results[i] is not None:
            continue
        pair = plans.get(i)
        if pair is None:
            results[i] = SolveResult(None, None, share, 0, solver="dfts_jax")
        else:
            results[i] = SolveResult(pair[0], pair[1], share, 1,
                                     solver="dfts_jax")
    return results  # aligned with `problems`


def _bcd_jax_batch(problems: list[ProblemInstance], *,
                   cache: EvalCache | None = None,
                   **kwargs) -> list[SolveResult]:
    """Batched ``bcd_jax``: a shared-jit per-instance loop (BCD trajectories
    have data-dependent lengths, so instances don't pad into one scan; the
    win over scalar BCD is the jitted DP blocks staying warm across the
    batch)."""
    return [bcd_jax_solve(*p.solver_args(), cache=cache, **kwargs)
            for p in problems]


@register_solver("dfts_np", schedules=(SEQ, PIPE),
                 description="scalar one-shot baseline: even split (min-mem "
                             "fallback) + one exact DFTS placement/chaining "
                             "tour — the NumPy twin of dfts_jax")
def _dfts_np_registered(net, profile, request, K, candidates,
                        cache: EvalCache | None = None) -> SolveResult:
    return dfts_np_solve(net, profile, request, K, candidates, cache=cache)


register_solver("dfts_jax", schedules=(SEQ, PIPE), batch=_dfts_jax_batch,
                description="batched jitted one-shot solver: even split "
                            "(min-mem fallback) + DFTS tour as a vmap'd "
                            "lax.scan min-plus DP (optional Pallas kernel); "
                            "bit-identical to dfts_np")(dfts_jax_solve)


def bcd_jax_solve(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    K: int,
    candidates: list[list[str]],
    eps: float = 0.0,
    max_iters: int = 50,
    cache: EvalCache | None = None,
    use_pallas: bool = False,
) -> SolveResult:
    """`bcd_solve` with both block minimizations on the jitted DP scans —
    same trajectories, same plans, bit-identical latencies."""
    t0 = time.perf_counter()
    cache = cache if cache is not None else EvalCache()
    pipelined = request.schedule == PIPE and request.microbatches() > 1

    def alternate(segments):
        pair = _dfts_jax_plan(net, profile, request, segments, candidates,
                              use_pallas=use_pallas)
        if pair is None:
            return None, INF, [], 0
        plan, prev = pair[0], pair[1].total_s
        history = [prev]
        iters = 0
        for iters in range(1, max_iters + 1):
            new_segments = _kseq_jax(net, profile, request, plan)
            if new_segments is None:
                break
            new_pair = _dfts_jax_plan(net, profile, request, new_segments,
                                      candidates, use_pallas=use_pallas)
            if new_pair is None:
                break
            plan, cur = new_pair[0], new_pair[1].total_s
            history.append(cur)
            if abs(cur - prev) <= eps:
                prev = cur
                break
            prev = cur
        return plan, prev, history, iters

    segments = even_split(profile.L, K)
    plan, prev, history, iters = alternate(segments)
    if plan is None:
        from .baselines import min_memory_split  # local import avoids a cycle

        segments = min_memory_split(profile, request, K)
        if segments is not None:
            plan, prev, history, iters = alternate(segments)
    if plan is None:
        return SolveResult(None, None, time.perf_counter() - t0, 0,
                           solver="bcd_jax")

    if pipelined:
        from .baselines import comp_balance_split  # local import avoids cycle

        bal = comp_balance_split(net, profile, request, K, candidates,
                                 cache=cache)
        if bal is not None and bal != segments:
            plan2, prev2, history2, iters2 = alternate(bal)
            if plan2 is not None and prev2 < prev:
                plan, prev, history, iters = plan2, prev2, history2, iters2

        seq_req = replace(request, schedule=SEQ, n_microbatches=1)
        seq_res = bcd_jax_solve(net, profile, seq_req, K, candidates,
                                eps=eps, max_iters=max_iters, cache=cache,
                                use_pallas=use_pallas)
        if seq_res.plan is not None:
            anchor = _fast_latency(net, profile, request, seq_res.plan)
            if anchor < prev:
                plan, prev = seq_res.plan, anchor
                history.append(anchor)

    return SolveResult(plan, _fast_evaluate(net, profile, request, plan),
                       time.perf_counter() - t0, iters, history,
                       solver="bcd_jax")


register_solver("bcd_jax", schedules=(SEQ, PIPE), batch=_bcd_jax_batch,
                description="paper Alg. 1 on the jitted DP scans: alternate "
                            "the lax.scan K-seq segmentation and DFTS "
                            "min-plus blocks; bit-identical to bcd")(
    bcd_jax_solve)
