"""Physical network model G = (V, E) (paper Sec. III-C).

Directed links; each link (i, j) carries a forward-direction bandwidth/propagation
delay (used by activations flowing i->j) and a backward-direction pair (used by
gradients flowing back along the same subpath, i.e. j->i traffic charged on link
(i, j) per the paper's R^BW_{i,j} convention).
"""
from __future__ import annotations

import heapq
import json
from dataclasses import dataclass, field

import numpy as np

from .costmodel import FW, ComputeModel


@dataclass(frozen=True)
class NodeSpec:
    name: str
    compute: ComputeModel
    mem_capacity: float  # C_i^mem, bytes
    disk_capacity: float  # C_i^disk, bytes


@dataclass(frozen=True)
class LinkSpec:
    """R^FW/R^BW in bits/s, d^FW/d^BW in seconds."""

    bw_fw: float
    bw_bw: float
    delay_fw: float
    delay_bw: float

    def rate(self, direction: str) -> float:
        return self.bw_fw if direction == FW else self.bw_bw

    def delay(self, direction: str) -> float:
        return self.delay_fw if direction == FW else self.delay_bw


def transmission_time_s(size_bytes: float, rate_bps: float) -> float:
    """T^trans = b*psi / R  (Eq. 18); sizes in bytes, rates in bits/s."""
    return size_bytes * 8.0 / rate_bps


@dataclass
class PhysicalNetwork:
    nodes: dict[str, NodeSpec] = field(default_factory=dict)
    links: dict[tuple[str, str], LinkSpec] = field(default_factory=dict)
    # Cached single-source Dijkstra frontiers keyed (source, fw_bytes, bw_bytes);
    # invalidated whenever the topology mutates.  Shared by DFTS / the exact DP
    # across solver calls and across sweep grid points on the same network.
    _sssp_cache: dict = field(default_factory=dict, init=False, repr=False,
                              compare=False)
    # Dense [S, V] frontier matrices keyed (sources, fw_bytes, bw_bytes) and the
    # node -> column index; assembled from _sssp_cache rows for the vectorized
    # min-plus stage relaxation, invalidated together with it.
    _frontier_mats: dict = field(default_factory=dict, init=False, repr=False,
                                 compare=False)
    _node_idx: dict | None = field(default=None, init=False, repr=False,
                                   compare=False)
    # Canonical content serialization (ProblemInstance identity); computed
    # lazily, invalidated together with the routing caches on mutation.
    _content_key: str | None = field(default=None, init=False, repr=False,
                                     compare=False)

    def _invalidate(self) -> None:
        self._sssp_cache.clear()
        self._frontier_mats.clear()
        self._node_idx = None
        self._content_key = None

    def add_node(self, spec: NodeSpec) -> None:
        self.nodes[spec.name] = spec
        self._invalidate()

    def add_link(self, u: str, v: str, spec: LinkSpec) -> None:
        assert u in self.nodes and v in self.nodes
        self.links[(u, v)] = spec
        self._invalidate()

    def add_bidirectional(self, u: str, v: str, spec: LinkSpec) -> None:
        self.add_link(u, v, spec)
        self.add_link(v, u, spec)

    @property
    def node_names(self) -> list[str]:
        return list(self.nodes)

    def out_edges(self, u: str) -> list[tuple[str, LinkSpec]]:
        return [(v, s) for (a, v), s in self.links.items() if a == u]

    # ------------------------------------------------------------------ routing
    def link_trans_s(self, u: str, v: str, fw_bytes: float,
                     bw_bytes: float | None) -> float:
        """Transmission time only (no propagation) of one cut's smashed data on
        link (u, v) — the link's *occupancy* per batch, i.e. its pipeline-stage
        time in the pipelined execution model (docs/pipeline.md)."""
        link = self.links[(u, v)]
        t = transmission_time_s(fw_bytes, link.bw_fw)
        if bw_bytes is not None:
            t += transmission_time_s(bw_bytes, link.bw_bw)
        return t

    def link_trans_dir_s(self, u: str, v: str, size_bytes: float,
                         direction: str) -> float:
        """Single-direction transmission time of one cut's smashed data on
        link (u, v): the link's per-batch occupancy as a *forward* (activation)
        or *backward* (gradient) pipeline stage in the round-trip training
        model (docs/training.md)."""
        link = self.links[(u, v)]
        return transmission_time_s(size_bytes, link.rate(direction))

    def edge_cost(self, u: str, v: str, fw_bytes: float, bw_bytes: float | None,
                  trans_scale: float = 1.0) -> float:
        """Per-link chaining cost c^k_{i,j} (Sec. V-C): FW transfer (+ BW if
        training).  ``trans_scale`` multiplies only the transmission terms —
        the pipelined solvers route with scale 1/M (a microbatch's share of the
        fill cost) while propagation is charged in full."""
        link = self.links[(u, v)]
        cost = transmission_time_s(fw_bytes, link.bw_fw) * trans_scale + link.delay_fw
        if bw_bytes is not None:
            cost += (transmission_time_s(bw_bytes, link.bw_bw) * trans_scale
                     + link.delay_bw)
        return cost

    def dijkstra(
        self,
        sources: dict[str, float],
        fw_bytes: float,
        bw_bytes: float | None,
        trans_cap: float | None = None,
        trans_scale: float = 1.0,
        trans_cap_bw: float | None = None,
    ) -> tuple[dict[str, float], dict[str, str | None]]:
        """Multi-source Dijkstra with smashed-data-dependent link costs.

        `sources` maps node -> initial distance (enables the stage-wise shortest
        path *tour* with a single Dijkstra per stage, as in the DFTS layered
        search).  Returns (dist, parent).

        ``trans_cap`` excludes links whose per-batch transmission time
        (``link_trans_s``) exceeds the cap — the bottleneck-capped searches of
        the pipelined solvers; ``trans_scale`` scales transmission (not
        propagation) in the edge cost.  When ``trans_cap_bw`` is given
        (round-trip training searches, docs/training.md) the caps are
        *per-direction* instead: a link is excluded when its forward
        (activation) occupancy exceeds ``trans_cap`` or its backward
        (gradient) occupancy exceeds ``trans_cap_bw``; ``bw_bytes`` must then
        be a concrete size.  The defaults reproduce the sequential behaviour
        exactly (scaling by 1.0 is an IEEE identity).
        """
        adj: dict[str, list[tuple[str, float]]] = {n: [] for n in self.nodes}
        for (u, v), spec in self.links.items():
            if trans_cap_bw is not None:
                assert bw_bytes is not None
                if (transmission_time_s(fw_bytes, spec.bw_fw) > trans_cap
                        or transmission_time_s(bw_bytes, spec.bw_bw)
                        > trans_cap_bw):
                    continue
            elif (trans_cap is not None
                    and self.link_trans_s(u, v, fw_bytes, bw_bytes) > trans_cap):
                continue
            adj[u].append((v, self.edge_cost(u, v, fw_bytes, bw_bytes,
                                             trans_scale)))
        dist = {n: float("inf") for n in self.nodes}
        parent: dict[str, str | None] = {n: None for n in self.nodes}
        pq: list[tuple[float, str]] = []
        for s, d0 in sources.items():
            dist[s] = min(dist[s], d0)
            heapq.heappush(pq, (dist[s], s))
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist[u]:
                continue
            for v, w in adj[u]:
                nd = d + w
                if nd < dist[v]:
                    dist[v] = nd
                    parent[v] = u
                    heapq.heappush(pq, (nd, v))
                elif nd == dist[v] and parent[v] is not None and u < parent[v]:
                    # Deterministic equal-cost tie-break: among all optimal
                    # predecessors take the lexicographically smallest, so the
                    # parent tree (and every reconstructed path) is independent
                    # of dict/heap iteration order.  Source nodes keep
                    # parent=None — they are roots of the tour stage.
                    parent[v] = u
        return dist, parent

    def sssp(
        self, source: str, fw_bytes: float, bw_bytes: float | None,
        trans_cap: float | None = None, trans_scale: float = 1.0,
        trans_cap_bw: float | None = None,
    ) -> tuple[dict[str, float], dict[str, str | None]]:
        """Cached single-source Dijkstra frontier for one smashed-data size.

        The (dist, parent) maps are memoized per (source, fw_bytes, bw_bytes,
        trans_cap, trans_scale); treat them as immutable.  Stage relaxations
        over a candidate *set* are the min-composition of these frontiers
        (dist_S(v) = min_s d0[s] + dist_s(v)), so one cache serves every
        multi-source tour query — including the capped/scaled frontiers of the
        pipelined solvers' bottleneck scans.
        """
        key = (source, fw_bytes, bw_bytes, trans_cap, trans_scale,
               trans_cap_bw)
        hit = self._sssp_cache.get(key)
        if hit is None:
            hit = self.dijkstra({source: 0.0}, fw_bytes, bw_bytes,
                                trans_cap, trans_scale, trans_cap_bw)
            self._sssp_cache[key] = hit
        return hit

    def clear_routing_cache(self) -> None:
        """Drop cached frontiers (needed only after mutating a LinkSpec in place)."""
        self._invalidate()

    def content_key(self) -> str:
        """Canonical serialization of the topology's *content* — every node
        spec (incl. its compute model constants) and every directed link.
        Two networks built independently from equal data produce equal keys;
        cached and invalidated with the routing caches on mutation."""
        if self._content_key is None:
            self._content_key = json.dumps({
                "nodes": {
                    n: [s.compute.name, [list(p) for p in s.compute.pieces],
                        s.compute.alpha_tau, s.compute.beta_tau,
                        s.mem_capacity, s.disk_capacity]
                    for n, s in sorted(self.nodes.items())
                },
                "links": [
                    [u, v, s.bw_fw, s.bw_bw, s.delay_fw, s.delay_bw]
                    for (u, v), s in sorted(self.links.items())
                ],
            }, sort_keys=True, separators=(",", ":"))
        return self._content_key

    def node_index(self) -> dict[str, int]:
        """Stable node -> dense-column index (sorted names; cached)."""
        if self._node_idx is None:
            self._node_idx = {n: i for i, n in enumerate(sorted(self.nodes))}
        return self._node_idx

    def frontier_matrix(
        self, sources: tuple[str, ...], fw_bytes: float, bw_bytes: float | None,
        trans_cap: float | None = None, trans_scale: float = 1.0,
        trans_cap_bw: float | None = None,
    ) -> np.ndarray:
        """Dense [S, V] matrix of cached single-source frontiers.

        Row r is the full Dijkstra distance frontier of ``sources[r]`` for the
        given smashed-data size, columns ordered by :meth:`node_index`.  The
        matrix is assembled once per (sources, size) key and shared by every
        min-plus stage relaxation that composes these frontiers — across BCD
        iterations, solver calls, and all requests of a serve admission round.
        Read-only; invalidated with the frontier cache on topology mutation.
        """
        key = (sources, fw_bytes, bw_bytes, trans_cap, trans_scale,
               trans_cap_bw)
        mat = self._frontier_mats.get(key)
        if mat is None:
            idx = self.node_index()
            mat = np.full((len(sources), len(idx)), float("inf"))
            for r, s in enumerate(sources):
                dist, _ = self.sssp(s, fw_bytes, bw_bytes, trans_cap,
                                    trans_scale, trans_cap_bw)
                for n, d in dist.items():
                    mat[r, idx[n]] = d
            mat.setflags(write=False)
            self._frontier_mats[key] = mat
        return mat

    def shortest_path(
        self, src: str, dst: str, fw_bytes: float, bw_bytes: float | None
    ) -> tuple[float, list[str]]:
        """Least-cost loop-free path src->dst for a given smashed-data size."""
        if src == dst:
            return 0.0, [src]
        dist, parent = self.dijkstra({src: 0.0}, fw_bytes, bw_bytes)
        if dist[dst] == float("inf"):
            raise ValueError(f"no path {src} -> {dst}")
        path, cur = [dst], dst
        while cur != src:
            cur = parent[cur]  # type: ignore[assignment]
            assert cur is not None
            path.append(cur)
        return dist[dst], path[::-1]

    def path_cost_breakdown(
        self, path: list[str], fw_bytes: float, bw_bytes: float | None
    ) -> tuple[float, float]:
        """(transmission_s, propagation_s) along a concrete path (FW + optional BW)."""
        trans = prop = 0.0
        for u, v in zip(path, path[1:]):
            link = self.links[(u, v)]
            trans += transmission_time_s(fw_bytes, link.bw_fw)
            prop += link.delay_fw
            if bw_bytes is not None:
                trans += transmission_time_s(bw_bytes, link.bw_bw)
                prop += link.delay_bw
        return trans, prop
