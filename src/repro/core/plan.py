"""Service chain requests, plans (splitting + placement + chaining) and the latency
objective T(x, y, b, mode) with its computation / transmission / propagation
breakdown (paper Eqs. (1), (16)-(18); Figs. 8-9 breakdowns).

Two execution schedules are supported (see docs/pipeline.md):

* ``seq`` — the paper's model: stage k+1 starts only after stage k finished and
  its smashed data fully arrived; latency is the plain sum of Eq. (16).
* ``pipe`` — the batch is split into M microbatches that flow through the
  placed chain like a pipeline.  Each *resource* (a hosting node, or one
  physical link of a subpath) is a pipeline stage occupied ``t/M`` per
  microbatch, where ``t`` is its full-batch time; end-to-end latency is
  pipeline fill (sum of per-microbatch stage times + all propagation) plus the
  drain term ``(M-1) * max_stage / M`` recorded as ``bubble_s``.  With M = 1
  this is bit-for-bit the sequential sum.

Training requests (``mode=TR``) under ``pipe`` with M > 1 use the *round-trip*
model of ``trainpipe.py`` (docs/training.md): the backward pass is a second
pipeline wave over the reverse subpaths with its own ``delta^BW`` gradient
sizes and per-direction stage times, and the drain term is
``(M-1) * (tau_fw + tau_bw) / M``.  ``seq``+TR and every IF path are
unaffected by that dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .costmodel import (BW, FW, IF, PIPE, SCHEDULES, SEQ, TR, ModelProfile,
                        dirs_for_mode, effective_microbatches, validate_segments)
from .network import PhysicalNetwork


@dataclass(frozen=True)
class ServiceChainRequest:
    """R = (id, s, d, b, mode) — paper Sec. III-A — plus the execution
    schedule (``seq`` | ``pipe`` with ``n_microbatches``)."""

    model_id: str
    source: str
    destination: str
    batch_size: int
    mode: str  # IF | TR
    schedule: str = SEQ  # seq | pipe
    n_microbatches: int = 1

    def __post_init__(self) -> None:
        assert self.mode in (IF, TR)
        assert self.schedule in SCHEDULES, f"unknown schedule {self.schedule!r}"
        assert self.n_microbatches >= 1

    def microbatches(self) -> int:
        """Effective pipeline depth M: 1 under ``seq``, else clamped to [1, b]."""
        if self.schedule != PIPE:
            return 1
        return effective_microbatches(self.batch_size, self.n_microbatches)


@dataclass
class LatencyBreakdown:
    computation_s: float = 0.0
    transmission_s: float = 0.0
    propagation_s: float = 0.0
    bubble_s: float = 0.0  # pipeline drain (M-1)*max_stage/M; 0 under seq

    @property
    def total_s(self) -> float:
        return (self.computation_s + self.transmission_s + self.propagation_s
                + self.bubble_s)

    def __add__(self, other: "LatencyBreakdown") -> "LatencyBreakdown":
        return LatencyBreakdown(
            self.computation_s + other.computation_s,
            self.transmission_s + other.transmission_s,
            self.propagation_s + other.propagation_s,
            self.bubble_s + other.bubble_s,
        )


@dataclass
class Plan:
    """A complete solution: y (segments), placement, and chaining subpaths.

    segments:   K 1-indexed inclusive layer ranges [lo, hi].
    placement:  node name hosting each sub-model F^k.
    paths:      K-1 physical node paths; paths[k] carries the smashed data of the
                cut after segment k (placement[k] -> placement[k+1]).
    tail_path:  physical path placement[K-1] -> destination (subpath S_{K+1};
                psi_K = 0 so only propagation is charged, per Eq. (16)).
    """

    segments: list[tuple[int, int]]
    placement: list[str]
    paths: list[list[str]]
    tail_path: list[str] = field(default_factory=list)

    @property
    def K(self) -> int:
        return len(self.segments)

    def cuts(self) -> list[int]:
        return [hi for (_, hi) in self.segments[:-1]]


class EvalCache:
    """Memo tables for per-(node, segment) compute time and capacity checks.

    Entries are batch-size-, mode- and schedule-dependent, so all are part of
    the memo key: a single instance is safe to share across heterogeneous
    requests of one (network, profile) — the serve layer admits whole fleets
    against one cache that way, and the sweep runner keys shared instances per
    problem cell.  (Full-batch stage times are in fact schedule-invariant;
    keeping the schedule in the key keeps seq/pipe entries disjoint by design
    so schedule-specific tables can be added without aliasing.)  Solvers that
    receive no cache build a private one per call, which still collapses the
    repeated segment queries inside their own DP loops.

    `fits` additionally depends on node capacities, so a cache must never be
    shared across *networks* (e.g. residual-capacity views); `comp` depends
    only on the node compute models and may be (see :meth:`fork_fits`).

    ``hits`` / ``misses`` count lookups across both tables — the serve layer
    surfaces them per admission round (``ServeOutcome.solver_stats()``);
    forked caches count their own traffic even though the comp table is
    shared.
    """

    __slots__ = ("comp", "fits", "hits", "misses")

    def __init__(self) -> None:
        # keys: (node, lo, hi, batch_size, mode, schedule, n_microbatches);
        # per-direction round-trip entries (trainpipe.segment_comp_dir_s) use
        # 8-tuples (node, lo, hi, direction, ...) — disjoint by length.
        self.comp: dict[tuple, float] = {}
        self.fits: dict[tuple, bool] = {}
        self.hits = 0
        self.misses = 0

    def fork_fits(self) -> "EvalCache":
        """A cache sharing this one's compute table but with fresh fit tables —
        for residual-capacity views of the same network (same compute models,
        different node capacities).  Counters start fresh: the fork counts its
        own traffic."""
        out = EvalCache()
        out.comp = self.comp
        return out

    @property
    def hit_rate(self) -> float | None:
        total = self.hits + self.misses
        return self.hits / total if total else None

    def stats(self) -> dict:
        """Counter snapshot for observability blocks (JSON-able)."""
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate,
                "n_comp": len(self.comp), "n_fits": len(self.fits)}


class PlanEvaluator:
    """Evaluates T(x, y, b, mode) and checks constraints for concrete plans."""

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 request: ServiceChainRequest, cache: EvalCache | None = None):
        self.net = net
        self.profile = profile
        self.request = request
        self.cache = cache if cache is not None else EvalCache()
        # memo-key suffix: EvalCache entries are batch/mode/schedule-dependent
        self._ck = (request.batch_size, request.mode, request.schedule,
                    request.n_microbatches)

    # ------------------------------------------------------------- feasibility
    def segment_fits(self, node: str, lo: int, hi: int) -> bool:
        """Constraints (14) disk and (15) memory for sub-model [lo, hi] at node."""
        key = (node, lo, hi, *self._ck)
        hit = self.cache.fits.get(key)
        if hit is not None:
            self.cache.hits += 1
            return hit
        self.cache.misses += 1
        spec = self.net.nodes[node]
        ok = self.profile.seg_disk_bytes(lo, hi) <= spec.disk_capacity
        if ok:
            mem = self.profile.seg_mem_bytes(lo, hi)
            mem += (self.request.batch_size
                    * self.profile.seg_peak_smashed(lo, hi, self.request.mode))
            ok = mem <= spec.mem_capacity
        self.cache.fits[key] = ok
        return ok

    def check(self, plan: Plan) -> None:
        validate_segments(plan.segments, self.profile.L)
        assert len(plan.placement) == plan.K and len(plan.paths) == plan.K - 1
        for (lo, hi), node in zip(plan.segments, plan.placement):
            if not self.segment_fits(node, lo, hi):
                raise ValueError(f"segment [{lo},{hi}] violates capacity at {node}")
        for k, path in enumerate(plan.paths):
            assert path[0] == plan.placement[k] and path[-1] == plan.placement[k + 1]
            for u, v in zip(path, path[1:]):
                assert (u, v) in self.net.links, f"missing link {u}->{v}"

    # ------------------------------------------------------------------ latency
    def segment_comp_s(self, node: str, lo: int, hi: int) -> float:
        """T^comp for sub-model [lo, hi] at node, FW (+BW if training) — Eq. (17)."""
        key = (node, lo, hi, *self._ck)
        hit = self.cache.comp.get(key)
        if hit is not None:
            self.cache.hits += 1
            return hit
        self.cache.misses += 1
        cm = self.net.nodes[node].compute
        b = self.request.batch_size
        total = 0.0
        for d in dirs_for_mode(self.request.mode):
            total += cm.comp_time_s(b, self.profile.seg_flops(lo, hi, d))
        self.cache.comp[key] = total
        return total

    def cut_transfer_s(self, path: list[str], cut_after: int) -> tuple[float, float]:
        """(transmission, propagation) shipping delta_cut along `path`, FW (+BW)."""
        b = self.request.batch_size
        fw_bytes = b * self.profile.cut_bytes(cut_after, FW)
        bw_bytes = (b * self.profile.cut_bytes(cut_after, BW)
                    if self.request.mode == TR else None)
        return self.net.path_cost_breakdown(path, fw_bytes, bw_bytes)

    def _cut_sizes(self, cut_after: int) -> tuple[float, float | None]:
        b = self.request.batch_size
        fw = b * self.profile.cut_bytes(cut_after, FW)
        bw = (b * self.profile.cut_bytes(cut_after, BW)
              if self.request.mode == TR else None)
        return fw, bw

    def plan_stage_times(self, plan: Plan) -> list[float]:
        """Full-batch occupancy time of every pipeline *resource* of the plan:
        the K hosting nodes (Eq. 17 compute) and each physical link of each
        inter-stage subpath (transmission only — propagation occupies no
        resource).  ``max(...)`` of these is the pipeline bottleneck tau."""
        times = [self.segment_comp_s(node, lo, hi)
                 for (lo, hi), node in zip(plan.segments, plan.placement)]
        for k, path in enumerate(plan.paths):
            fw, bw = self._cut_sizes(plan.segments[k][1])
            for u, v in zip(path, path[1:]):
                times.append(self.net.link_trans_s(u, v, fw, bw))
        return times

    def bottleneck_s(self, plan: Plan) -> float:
        """tau: the slowest full-batch pipeline stage (node or link) of the plan."""
        return max(self.plan_stage_times(plan))

    def evaluate_pipelined(self, plan: Plan, n_microbatches: int) -> LatencyBreakdown:
        """Pipelined latency (docs/pipeline.md): fill + (M-1)*tau/M.

        Fill charges every stage its per-microbatch share t/M plus full
        propagation on every link; the drain/bubble term is (M-1) steady-state
        steps of the bottleneck stage.  With M = 1 every division is by 1 and
        the bubble is exactly 0.0, so the result is bit-for-bit equal to the
        sequential :meth:`evaluate`.
        """
        M = n_microbatches
        out = LatencyBreakdown()
        tau = 0.0
        for (lo, hi), node in zip(plan.segments, plan.placement):
            t = self.segment_comp_s(node, lo, hi)
            out.computation_s += t / M
            tau = max(tau, t)
        for k, path in enumerate(plan.paths):
            cut = plan.segments[k][1]
            trans, prop = self.cut_transfer_s(path, cut)
            out.transmission_s += trans / M
            out.propagation_s += prop
            fw, bw = self._cut_sizes(cut)
            for u, v in zip(path, path[1:]):
                tau = max(tau, self.net.link_trans_s(u, v, fw, bw))
        if plan.tail_path:  # psi_K = 0: propagation only, reserves no stage
            _, prop = self.net.path_cost_breakdown(plan.tail_path, 0.0, None)
            out.propagation_s += prop
        out.bubble_s = (M - 1) * tau / M
        return out

    def evaluate(self, plan: Plan) -> LatencyBreakdown:
        if self.request.schedule == PIPE:
            M = self.request.microbatches()
            if self.request.mode == TR and M > 1:
                # round-trip training pipeline (docs/training.md); M = 1
                # stays on the fused path below — bit-equal to seq.
                from .trainpipe import evaluate_round_trip

                return evaluate_round_trip(self, plan, M)
            return self.evaluate_pipelined(plan, M)
        out = LatencyBreakdown()
        for (lo, hi), node in zip(plan.segments, plan.placement):
            out.computation_s += self.segment_comp_s(node, lo, hi)
        for k, path in enumerate(plan.paths):
            cut = plan.segments[k][1]
            trans, prop = self.cut_transfer_s(path, cut)
            out.transmission_s += trans
            out.propagation_s += prop
        if plan.tail_path:  # psi_K = 0: propagation only
            _, prop = self.net.path_cost_breakdown(plan.tail_path, 0.0, None)
            out.propagation_s += prop
        return out

    def latency_s(self, plan: Plan) -> float:
        return self.evaluate(plan).total_s
