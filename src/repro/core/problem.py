"""First-class problem and outcome types of the solver engine.

A :class:`ProblemInstance` is the one canonical description of a solve: the
physical network, the model profile, the service chain request, the cut count
K, and the per-stage candidate sets V^k.  It is frozen and *content*-hashable
— two instances built independently from equal data hash equal — so it is the
single identity used for presolve dedup in ``repro.serve`` and instance
grouping/caching in ``repro.sweep`` (it subsumes the solve_key / instance_key
conventions those layers used to re-implement).

:class:`SolveResult` is the raw record every solver implementation returns;
:class:`SolveOutcome` extends it with a solve status (``optimal`` |
``feasible`` | ``infeasible``) and a free-form solver-stats dict, and is what
the engine's :func:`repro.core.engine.solve` entry point hands back.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

from .costmodel import SEQ, ModelProfile
from .network import PhysicalNetwork
from .plan import LatencyBreakdown, Plan, ServiceChainRequest

# Solve status vocabulary (SolveOutcome.status).
OPTIMAL = "optimal"  # feasible and provably latency-minimal for the instance
FEASIBLE = "feasible"  # a valid plan with no optimality guarantee
INFEASIBLE = "infeasible"  # the solver found no capacity-feasible plan
STATUSES = (OPTIMAL, FEASIBLE, INFEASIBLE)


@dataclass(frozen=True, eq=False)
class ProblemInstance:
    """One complete splitting/placement/chaining problem (paper Sec. III).

    ``candidates`` is a tuple of K tuples of node names (V^1..V^K).  Identity
    is by *content*: :meth:`content_key` canonicalizes the network's nodes and
    links, the profile's layer table, the request, K, and the candidate sets;
    ``__eq__``/``__hash__`` and :meth:`content_hash` derive from it.  Requests
    whose effective pipeline depth is 1 normalize to the sequential schedule
    in the key (``pipe`` with M = 1 is bit-for-bit the sequential objective),
    so trivially-equal problems can never hash apart.
    """

    net: PhysicalNetwork
    profile: ModelProfile
    request: ServiceChainRequest
    K: int
    candidates: tuple[tuple[str, ...], ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "candidates",
                           tuple(tuple(c) for c in self.candidates))
        if len(self.candidates) != self.K:
            raise ValueError(
                f"need exactly K={self.K} candidate sets, got "
                f"{len(self.candidates)}")
        object.__setattr__(self, "_ckey", None)

    # ---------------------------------------------------------------- identity
    def content_key(self) -> str:
        """Canonical JSON of everything that defines the problem."""
        if self._ckey is None:  # type: ignore[attr-defined]
            r = self.request
            M = r.microbatches()
            schedule = r.schedule if M > 1 else SEQ
            key = json.dumps({
                "net": self.net.content_key(),
                "profile": self.profile.content_key(),
                "request": [r.model_id, r.source, r.destination, r.batch_size,
                            r.mode, schedule, M],
                "K": self.K,
                "candidates": [list(c) for c in self.candidates],
            }, sort_keys=True, separators=(",", ":"))
            object.__setattr__(self, "_ckey", key)
        return self._ckey  # type: ignore[attr-defined]

    def content_hash(self) -> str:
        return hashlib.sha256(self.content_key().encode()).hexdigest()[:16]

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, ProblemInstance):
            return NotImplemented
        return self.content_key() == other.content_key()

    def __hash__(self) -> int:
        return hash(self.content_key())

    def __repr__(self) -> str:  # the field repr would dump the whole network
        r = self.request
        return (f"ProblemInstance({r.model_id!r}, {r.source}->{r.destination},"
                f" b={r.batch_size}, mode={r.mode}, schedule={r.schedule},"
                f" K={self.K}, |V|={len(self.net.nodes)},"
                f" hash={self.content_hash()})")

    # ------------------------------------------------------------- convenience
    def candidate_lists(self) -> list[list[str]]:
        """The mutable ``list[list[str]]`` shape the solver protocol takes."""
        return [list(c) for c in self.candidates]

    def solver_args(self) -> tuple:
        """Positional args of the solver protocol:
        ``(net, profile, request, K, candidates)``."""
        return (self.net, self.profile, self.request, self.K,
                self.candidate_lists())


@dataclass
class SolveResult:
    """Raw record returned by every solver implementation."""

    plan: Plan | None
    latency: LatencyBreakdown | None
    wall_time_s: float
    iterations: int = 0
    history: list[float] = field(default_factory=list)
    solver: str = "bcd"

    @property
    def feasible(self) -> bool:
        return self.plan is not None

    @property
    def latency_s(self) -> float:
        return self.latency.total_s if self.latency else float("inf")


@dataclass
class SolveOutcome(SolveResult):
    """A :class:`SolveResult` plus solve status and solver stats.

    ``status`` is one of :data:`STATUSES`; ``stats`` is free-form JSON-able
    solver detail (the portfolio meta-solver reports per-member outcomes
    here).  ``objective`` is the minimized end-to-end latency in seconds
    (``inf`` when infeasible).
    """

    status: str = INFEASIBLE
    stats: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        assert self.status in STATUSES, f"unknown status {self.status!r}"

    @property
    def objective(self) -> float:
        return self.latency_s

    @classmethod
    def from_result(cls, res: SolveResult, *, optimal: bool,
                    stats: dict | None = None) -> "SolveOutcome":
        """Wrap a raw solver result; ``optimal`` is the solver's declared
        optimality guarantee (applied only when a plan was found)."""
        if res.plan is None:
            status = INFEASIBLE
        else:
            status = OPTIMAL if optimal else FEASIBLE
        return cls(res.plan, res.latency, res.wall_time_s, res.iterations,
                   list(res.history), res.solver, status=status,
                   stats=dict(stats or {}))
