"""ResNet101 building-block profile — exact Table I of the paper.

37 layers (building blocks), 3x224x224 ImageNet input, b = 1 per-sample values.
FW FLOPs = 2 x MACs; BW FLOPs = 2 x FW FLOPs; smashed data / layer sizes assume
fp32.  M/K/G columns reproduced verbatim (decimal multipliers as in the paper).
"""
from __future__ import annotations

from .costmodel import LayerProfile, ModelProfile

M = 1e6
K = 1e3
G = 1e9

# (name, rho_FW, rho_BW, delta_FW, delta_BW, r_mem == r_disk)
_TABLE_I: list[tuple[str, float, float, float, float, float]] = []
_TABLE_I.append(("conv1", 236.02 * M, 472.04 * M, 3.21 * M, 3.21 * M, 37 * K))
_TABLE_I.append(("conv2_x_pre", 6.43 * M, 12.9 * M, 0.80 * M, 0.80 * M, 512))
_TABLE_I.append(("conv2_x_3", 4.74 * G, 9.48 * G, 3.21 * M, 3.21 * M, 3.02 * M))
for i in (4, 5):
    _TABLE_I.append((f"conv2_x_{i}", 7.40 * G, 14.80 * G, 3.21 * M, 3.21 * M, 4.72 * M))
_TABLE_I.append(("conv3_x_6", 5.76 * G, 11.52 * G, 1.61 * M, 1.61 * M, 14.68 * M))
for i in (7, 8, 9):
    _TABLE_I.append((f"conv3_x_{i}", 7.40 * G, 14.80 * G, 1.61 * M, 1.61 * M, 18.88 * M))
_TABLE_I.append(("conv4_x_10", 5.76 * G, 11.52 * G, 0.80 * M, 0.80 * M, 58.76 * M))
for i in range(11, 33):
    _TABLE_I.append((f"conv4_x_{i}", 7.40 * G, 14.80 * G, 0.80 * M, 0.80 * M, 75.52 * M))
_TABLE_I.append(("conv5_x_33", 5.76 * G, 11.52 * G, 0.40 * M, 0.40 * M, 234.92 * M))
for i in (34, 35):
    _TABLE_I.append((f"conv5_x_{i}", 7.40 * G, 14.80 * G, 0.40 * M, 0.40 * M, 302.04 * M))
_TABLE_I.append(("avgpool", 200.70 * K, 401.40 * K, 8192.0, 8192.0, 0.0))
_TABLE_I.append(("fc", 4.10 * M, 8.20 * M, 4000.0, 4000.0, 8.20 * M))

assert len(_TABLE_I) == 37


def resnet101_profile() -> ModelProfile:
    layers = [
        LayerProfile(name, fw, bw, act, grad, mem, mem)
        for (name, fw, bw, act, grad, mem) in _TABLE_I
    ]
    return ModelProfile("resnet101", layers)
