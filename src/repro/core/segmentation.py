"""K-sequence segmentation via dynamic programming (paper Alg. 2, [23]).

Optimizes the model splitting y_t for a *fixed* placement + chaining x_{t-1}:
segment k's cost is its compute time at the node currently hosting F^k plus the
cost of shipping its output cut along the current (k)th inter-stage path.
Capacity violations (constraints (14)-(15)) yield +inf, as in the paper.

We index dp[k][e] = min cost of covering layers 1..e with k segments (the paper's
dp_{k,l} covers 1..l-1; the shift removes its off-by-one at the last segment).
Complexity O(K L^2) segment evaluations, per Sec. V-D.
"""
from __future__ import annotations

import numpy as np

from .costmodel import BW, FW, PIPE, TR, ModelProfile
from .network import PhysicalNetwork, transmission_time_s
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest
from .trainpipe import segment_comp_dir_s

INF = float("inf")


def _segment_cost(
    ev: PlanEvaluator,
    profile: ModelProfile,
    net: PhysicalNetwork,
    request: ServiceChainRequest,
    k: int,
    K: int,
    lo: int,
    hi: int,
    placement: list[str],
    paths: list[list[str]],
) -> float:
    """T(x^k, 1^k_{lo,hi}, b, mode): compute at placement[k] + outgoing cut shipping."""
    node = placement[k]
    if not ev.segment_fits(node, lo, hi):
        return INF
    cost = ev.segment_comp_s(node, lo, hi)
    if k < K - 1:  # ship delta_hi along the existing (k+1)-th subpath
        trans, prop = ev.cut_transfer_s(paths[k], hi)
        cost += trans + prop
    return cost


def k_sequence_segmentation(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """Re-split L layers into K segments for plan's fixed placement/chaining.

    Pipelined requests (schedule="pipe", M > 1) go through `_k_seq_pipe`,
    which optimizes the pipelined objective (balanced stages beat
    front-loaded ones once the bottleneck term dominates); pipelined
    *training* requests go through `_k_seq_pipe_tr`, which optimizes the
    round-trip objective with its two per-direction bottlenecks
    (docs/training.md)."""
    if request.schedule == PIPE and request.microbatches() > 1:
        if request.mode == TR:
            return _k_seq_pipe_tr(net, profile, request, plan, cache)
        return _k_seq_pipe(net, profile, request, plan, cache)
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    placement, paths = plan.placement, plan.paths

    def segcost(k: int, lo: int, hi: int) -> float:
        return _segment_cost(ev, profile, net, request, k, K, lo, hi, placement, paths)

    # dp[k][e]: k segments covering layers 1..e; e in [k, L-(K-k)]
    dp = [[INF] * (L + 1) for _ in range(K + 1)]
    choice = [[-1] * (L + 1) for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        dp[1][e] = segcost(0, 1, e)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                prev = dp[k - 1][e2]
                if prev == INF:
                    continue
                c = prev + segcost(k - 1, e2 + 1, e)
                if c < dp[k][e]:
                    dp[k][e] = c
                    choice[k][e] = e2
    if dp[K][L] == INF:
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = choice[k][e]
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments


def _k_seq_pipe(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """K-sequence segmentation under the pipelined objective (docs/pipeline.md).

    For the fixed placement/chaining, stage times are the per-stage compute
    plus each link's transmission of the stage's outgoing cut; the objective
    fill + (M-1)/M * tau couples segments through the bottleneck tau, which a
    plain min-sum DP cannot express.  We therefore run the DP *vectorized over
    candidate bottleneck caps*: dp[k][e] is an array over caps tau (segments
    slower than tau cost +inf), and the answer is the cap minimizing
    dp[K][L][tau] + (M-1)/M * tau.  The optimum's bottleneck is always one of
    the finitely many candidate stage-time values, so the scan is exact for
    this block.  O(K L^2) transitions, each an O(|taus|) NumPy op.
    """
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    placement, paths = plan.placement, plan.paths
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M
    b = request.batch_size
    training = request.mode == TR

    # full-batch compute per (stage, lo, hi); +inf where capacity-infeasible
    comp = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        node = placement[k]
        lo_min, hi_max = k + 1, L - (K - 1 - k)
        for lo in range(lo_min, hi_max + 1):
            for hi in range(lo, hi_max + 1):
                if ev.segment_fits(node, lo, hi):
                    comp[k, lo, hi] = ev.segment_comp_s(node, lo, hi)

    # shipping along the existing (k)-th subpath, tabulated per cut position c:
    # total link transmission (fill), slowest single link (bottleneck), and the
    # cut-independent propagation sum
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = (np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
            if training else None)
    ship_sum = np.zeros((max(K - 1, 1), L + 1))
    ship_max = np.zeros((max(K - 1, 1), L + 1))
    ship_prop = np.zeros(max(K - 1, 1))
    for k in range(K - 1):
        for u, v in zip(paths[k], paths[k][1:]):
            spec = net.links[(u, v)]
            t = transmission_time_s(fw_b, spec.bw_fw)
            ship_prop[k] += spec.delay_fw
            if bw_b is not None:
                t = t + transmission_time_s(bw_b, spec.bw_bw)
                ship_prop[k] += spec.delay_bw
            ship_sum[k, 1:L] += t
            ship_max[k, 1:L] = np.maximum(ship_max[k, 1:L], t)

    # candidate bottleneck caps: every stage time any segmentation can exhibit
    per_stage_min = []
    for k in range(K):
        fin = comp[k][np.isfinite(comp[k])]
        if fin.size == 0:
            return None  # stage k fits nowhere for any segment
        per_stage_min.append(float(fin.min()))
    lb = max(per_stage_min)
    tau_set = set(comp[np.isfinite(comp)].tolist())
    for k in range(K - 1):
        tau_set.update(ship_max[k, 1:L].tolist())
    taus = np.array(sorted(t for t in tau_set if t >= lb))
    if taus.size == 0:
        return None
    T = taus.size

    def seg_cost(k0: int, lo: int, hi: int):
        """(fill, stage max) of zero-based stage k0 hosting [lo, hi]."""
        c = comp[k0, lo, hi]
        if c == INF:
            return None
        fill = c * inv_M
        smax = c
        if k0 < K - 1:
            fill += ship_sum[k0, hi] * inv_M + ship_prop[k0]
            smax = max(smax, ship_max[k0, hi])
        return fill, smax

    dp = np.full((K + 1, L + 1, T), INF)
    choice = np.full((K + 1, L + 1, T), -1, dtype=np.int32)
    for e in range(1, L - K + 2):
        sc = seg_cost(0, 1, e)
        if sc is not None:
            dp[1, e] = np.where(taus >= sc[1], sc[0], INF)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                sc = seg_cost(k - 1, e2 + 1, e)
                if sc is None:
                    continue
                cand = dp[k - 1, e2] + np.where(taus >= sc[1], sc[0], INF)
                better = cand < dp[k, e]
                if better.any():
                    dp[k, e][better] = cand[better]
                    choice[k, e][better] = e2

    tot = dp[K, L] + c_bub * taus
    t_idx = int(np.argmin(tot))
    if not np.isfinite(tot[t_idx]):
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = int(choice[k, e, t_idx])
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments


# ------------------------------------------------- round-trip (TR) pipelining
def _tr_valid_mask(K: int, L: int) -> np.ndarray:
    """Admissible dp end-layers per stage (the oracle's e ranges)."""
    valid = np.zeros((K, L + 1), dtype=bool)
    valid[0, 1:L - K + 2] = True  # stage 1: e in [1, L-K+1]
    for k in range(2, K):
        valid[k - 1, k:L - K + k + 1] = True
    if K > 1:
        valid[K - 1, :] = False
        valid[K - 1, L] = True  # stage K: e = L only
    return valid


def _pipe_dp_np(sfill: np.ndarray, ssmax: np.ndarray, valid: np.ndarray,
                taus: np.ndarray):
    """Reference NumPy pipelined segmentation DP on dense (K, L+1, L+1)
    transition tensors (sfill[k, e2, e] = fill of segment lo=e2+1..hi=e at
    stage k, ssmax its capped stage-time; +inf infeasible), vectorized over
    the candidate caps ``taus``.  First-strict-improvement updates, matching
    the jitted ``kseq_pipe_scan`` twin's first-occurrence argmin.  Returns
    (dp[K, L] over caps, choice lookup (k, e, t) -> e2)."""
    K, Lp1, _ = sfill.shape
    L = Lp1 - 1
    T = taus.size
    dp = np.full((K + 1, Lp1, T), INF)
    choice = np.full((K + 1, Lp1, T), -1, dtype=np.int32)
    for e in range(1, Lp1):
        if valid[0, e]:
            dp[1, e] = np.where(taus >= ssmax[0, 0, e], sfill[0, 0, e], INF)
    for k in range(2, K + 1):
        for e in range(1, Lp1):
            if not valid[k - 1, e]:
                continue
            for e2 in range(k - 1, e):
                sf = sfill[k - 1, e2, e]
                if sf == INF:
                    continue
                cand = dp[k - 1, e2] + np.where(taus >= ssmax[k - 1, e2, e],
                                                sf, INF)
                better = cand < dp[k, e]
                if better.any():
                    dp[k, e][better] = cand[better]
                    choice[k, e][better] = e2
    return dp[K, L], lambda k, e, t: int(choice[k, e, t])


def _run_k_seq_pipe_tr(K: int, L: int, c_bub: float, fill: np.ndarray,
                       sfmax: np.ndarray, sbmax: np.ndarray, run_pipe_dp):
    """Shared round-trip segmentation scan (docs/training.md): the control
    flow of `_k_seq_pipe_tr` and its jitted twin, parameterized only by the
    inner DP so the two stay bit-identical by construction.

    ``fill``/``sfmax``/``sbmax`` are (K, L+1, L+1) [lo, hi]-indexed per-stage
    fill costs and per-direction stage maxima (+inf infeasible).  The
    round-trip objective fill + (M-1)/M * (tau_fw + tau_bw) couples segments
    through *two* bottlenecks, so the cap-vectorized DP handles the backward
    caps while an outer scan enumerates candidate forward caps F ascending
    (segments with forward stage time > F masked +inf): the answer for a pair
    is dp[K, L][B] + c_bub * (F + B), any segmentation's exact (tau_fw,
    tau_bw) appears in the grid, and the incumbent bound
    min_fill + c_bub * (F + lb_bw) >= best stops the scan — exact for this
    block, like the 1D scan of `_k_seq_pipe`.

    ``run_pipe_dp(sfill, ssmax, valid, taus)`` returns (dp over caps at
    [K, L], choice lookup); any +inf cap padding it adds internally must keep
    the first ``len(taus)`` columns aligned.
    """
    feas = np.isfinite(fill)
    lb_f, lb_b = 0.0, 0.0
    f_vals: set[float] = set()
    b_vals: set[float] = set()
    for k in range(K):
        if not feas[k].any():
            return None
        lb_f = max(lb_f, float(sfmax[k][feas[k]].min()))
        lb_b = max(lb_b, float(sbmax[k][feas[k]].min()))
        f_vals.update(sfmax[k][feas[k]].tolist())
        b_vals.update(sbmax[k][feas[k]].tolist())
    cand_f = sorted(t for t in f_vals if t >= lb_f)
    taus_b = np.array(sorted(t for t in b_vals if t >= lb_b))
    if not cand_f or taus_b.size == 0:
        return None

    # dense e2-shift: d[k, e2, e] = grid[k, lo=e2+1, e]
    def shift(grid):
        d = np.full((K, L + 1, L + 1), INF)
        d[:, :L, :] = grid[:, 1:, :]
        return d

    fill_d, sfmax_d, sbmax_d = shift(fill), shift(sfmax), shift(sbmax)
    valid = _tr_valid_mask(K, L)

    def backtrack(choice_fn, t_idx):
        cuts = []
        e = L
        for k in range(K, 1, -1):
            e = choice_fn(k, e, t_idx)
            cuts.append(e)
        cuts.reverse()
        segments, lo = [], 1
        for c in cuts + [L]:
            segments.append((lo, c))
            lo = c + 1
        return segments

    # unconstrained pass: global fill lower bound + incumbent segmentation
    dp0, ch0 = run_pipe_dp(fill_d, sbmax_d, valid, taus_b)
    dp0 = np.asarray(dp0)[:taus_b.size]
    if not np.isfinite(dp0).any():
        return None
    fill_min = float(dp0[np.isfinite(dp0)].min())
    t0 = int(np.argmin(dp0 + c_bub * taus_b))
    best_segments = backtrack(ch0, t0)
    obj = 0.0
    tau_f = tau_b = 0.0
    for k, (lo, hi) in enumerate(best_segments):
        obj += float(fill[k, lo, hi])
        tau_f = max(tau_f, float(sfmax[k, lo, hi]))
        tau_b = max(tau_b, float(sbmax[k, lo, hi]))
    best_obj = obj + c_bub * (tau_f + tau_b)

    for F in cand_f:
        if fill_min + c_bub * (F + lb_b) >= best_obj:
            break
        dp, ch = run_pipe_dp(np.where(sfmax_d <= F, fill_d, INF), sbmax_d,
                             valid, taus_b)
        dp = np.asarray(dp)[:taus_b.size]
        tot = dp + c_bub * (F + taus_b)
        t_idx = int(np.argmin(tot))
        if not np.isfinite(tot[t_idx]):
            continue
        if tot[t_idx] < best_obj:
            best_segments = backtrack(ch, t_idx)
            best_obj = float(tot[t_idx])
    return best_segments


def _tr_stage_grids(net, profile, request, plan, ev):
    """Dense (K, L+1, L+1) [lo, hi] grids for the round-trip segmentation
    scan: fused fill cost plus per-direction stage-time maxima, +inf where
    capacity-infeasible — the oracle's exact cost values (EvalCache-served)."""
    K, L = plan.K, profile.L
    M = request.microbatches()
    inv_M = 1.0 / M
    b = request.batch_size
    placement, paths = plan.placement, plan.paths

    comp = np.full((K, L + 1, L + 1), INF)
    comp_fw = np.full((K, L + 1, L + 1), INF)
    comp_bw = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        node = placement[k]
        lo_min, hi_max = k + 1, L - (K - 1 - k)
        for lo in range(lo_min, hi_max + 1):
            for hi in range(lo, hi_max + 1):
                if ev.segment_fits(node, lo, hi):
                    comp[k, lo, hi] = ev.segment_comp_s(node, lo, hi)
                    comp_fw[k, lo, hi] = segment_comp_dir_s(ev, node, lo, hi,
                                                            FW)
                    comp_bw[k, lo, hi] = segment_comp_dir_s(ev, node, lo, hi,
                                                            BW)

    # per-subpath shipping: fused fill terms, per-direction slowest links
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
    ship_sum = np.zeros((max(K - 1, 1), L + 1))
    ship_prop = np.zeros(max(K - 1, 1))
    ship_max_fw = np.zeros((max(K - 1, 1), L + 1))
    ship_max_bw = np.zeros((max(K - 1, 1), L + 1))
    for k in range(K - 1):
        for u, v in zip(paths[k], paths[k][1:]):
            spec = net.links[(u, v)]
            t_fw = transmission_time_s(fw_b, spec.bw_fw)
            t_bw = transmission_time_s(bw_b, spec.bw_bw)
            ship_prop[k] += spec.delay_fw + spec.delay_bw
            ship_sum[k, 1:L] += t_fw + t_bw
            ship_max_fw[k, 1:L] = np.maximum(ship_max_fw[k, 1:L], t_fw)
            ship_max_bw[k, 1:L] = np.maximum(ship_max_bw[k, 1:L], t_bw)

    fill = comp * inv_M
    sfmax = comp_fw.copy()
    sbmax = comp_bw.copy()
    for k in range(K - 1):
        fill[k] = fill[k] + (ship_sum[k][None, :] * inv_M + ship_prop[k])
        sfmax[k] = np.maximum(sfmax[k], ship_max_fw[k][None, :])
        sbmax[k] = np.maximum(sbmax[k], ship_max_bw[k][None, :])
    return fill, sfmax, sbmax


def _k_seq_pipe_tr(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """K-sequence segmentation under the round-trip training objective
    (docs/training.md): `_run_k_seq_pipe_tr` on the oracle grids with the
    reference NumPy DP."""
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    M = request.microbatches()
    c_bub = (M - 1) / M
    fill, sfmax, sbmax = _tr_stage_grids(net, profile, request, plan, ev)
    return _run_k_seq_pipe_tr(K, L, c_bub, fill, sfmax, sbmax, _pipe_dp_np)
