"""K-sequence segmentation via dynamic programming (paper Alg. 2, [23]).

Optimizes the model splitting y_t for a *fixed* placement + chaining x_{t-1}:
segment k's cost is its compute time at the node currently hosting F^k plus the
cost of shipping its output cut along the current (k)th inter-stage path.
Capacity violations (constraints (14)-(15)) yield +inf, as in the paper.

We index dp[k][e] = min cost of covering layers 1..e with k segments (the paper's
dp_{k,l} covers 1..l-1; the shift removes its off-by-one at the last segment).
Complexity O(K L^2) segment evaluations, per Sec. V-D.
"""
from __future__ import annotations

import numpy as np

from .costmodel import BW, FW, PIPE, TR, ModelProfile
from .network import PhysicalNetwork, transmission_time_s
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest

INF = float("inf")


def _segment_cost(
    ev: PlanEvaluator,
    profile: ModelProfile,
    net: PhysicalNetwork,
    request: ServiceChainRequest,
    k: int,
    K: int,
    lo: int,
    hi: int,
    placement: list[str],
    paths: list[list[str]],
) -> float:
    """T(x^k, 1^k_{lo,hi}, b, mode): compute at placement[k] + outgoing cut shipping."""
    node = placement[k]
    if not ev.segment_fits(node, lo, hi):
        return INF
    cost = ev.segment_comp_s(node, lo, hi)
    if k < K - 1:  # ship delta_hi along the existing (k+1)-th subpath
        trans, prop = ev.cut_transfer_s(paths[k], hi)
        cost += trans + prop
    return cost


def k_sequence_segmentation(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """Re-split L layers into K segments for plan's fixed placement/chaining.

    Pipelined requests (schedule="pipe", M > 1) go through `_k_seq_pipe`,
    which optimizes the pipelined objective (balanced stages beat
    front-loaded ones once the bottleneck term dominates)."""
    if request.schedule == PIPE and request.microbatches() > 1:
        return _k_seq_pipe(net, profile, request, plan, cache)
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    placement, paths = plan.placement, plan.paths

    def segcost(k: int, lo: int, hi: int) -> float:
        return _segment_cost(ev, profile, net, request, k, K, lo, hi, placement, paths)

    # dp[k][e]: k segments covering layers 1..e; e in [k, L-(K-k)]
    dp = [[INF] * (L + 1) for _ in range(K + 1)]
    choice = [[-1] * (L + 1) for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        dp[1][e] = segcost(0, 1, e)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                prev = dp[k - 1][e2]
                if prev == INF:
                    continue
                c = prev + segcost(k - 1, e2 + 1, e)
                if c < dp[k][e]:
                    dp[k][e] = c
                    choice[k][e] = e2
    if dp[K][L] == INF:
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = choice[k][e]
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments


def _k_seq_pipe(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """K-sequence segmentation under the pipelined objective (docs/pipeline.md).

    For the fixed placement/chaining, stage times are the per-stage compute
    plus each link's transmission of the stage's outgoing cut; the objective
    fill + (M-1)/M * tau couples segments through the bottleneck tau, which a
    plain min-sum DP cannot express.  We therefore run the DP *vectorized over
    candidate bottleneck caps*: dp[k][e] is an array over caps tau (segments
    slower than tau cost +inf), and the answer is the cap minimizing
    dp[K][L][tau] + (M-1)/M * tau.  The optimum's bottleneck is always one of
    the finitely many candidate stage-time values, so the scan is exact for
    this block.  O(K L^2) transitions, each an O(|taus|) NumPy op.
    """
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    placement, paths = plan.placement, plan.paths
    M = request.microbatches()
    inv_M = 1.0 / M
    c_bub = (M - 1) / M
    b = request.batch_size
    training = request.mode == TR

    # full-batch compute per (stage, lo, hi); +inf where capacity-infeasible
    comp = np.full((K, L + 1, L + 1), INF)
    for k in range(K):
        node = placement[k]
        lo_min, hi_max = k + 1, L - (K - 1 - k)
        for lo in range(lo_min, hi_max + 1):
            for hi in range(lo, hi_max + 1):
                if ev.segment_fits(node, lo, hi):
                    comp[k, lo, hi] = ev.segment_comp_s(node, lo, hi)

    # shipping along the existing (k)-th subpath, tabulated per cut position c:
    # total link transmission (fill), slowest single link (bottleneck), and the
    # cut-independent propagation sum
    fw_b = np.array([b * profile.cut_bytes(c, FW) for c in range(1, L)])
    bw_b = (np.array([b * profile.cut_bytes(c, BW) for c in range(1, L)])
            if training else None)
    ship_sum = np.zeros((max(K - 1, 1), L + 1))
    ship_max = np.zeros((max(K - 1, 1), L + 1))
    ship_prop = np.zeros(max(K - 1, 1))
    for k in range(K - 1):
        for u, v in zip(paths[k], paths[k][1:]):
            spec = net.links[(u, v)]
            t = transmission_time_s(fw_b, spec.bw_fw)
            ship_prop[k] += spec.delay_fw
            if bw_b is not None:
                t = t + transmission_time_s(bw_b, spec.bw_bw)
                ship_prop[k] += spec.delay_bw
            ship_sum[k, 1:L] += t
            ship_max[k, 1:L] = np.maximum(ship_max[k, 1:L], t)

    # candidate bottleneck caps: every stage time any segmentation can exhibit
    per_stage_min = []
    for k in range(K):
        fin = comp[k][np.isfinite(comp[k])]
        if fin.size == 0:
            return None  # stage k fits nowhere for any segment
        per_stage_min.append(float(fin.min()))
    lb = max(per_stage_min)
    tau_set = set(comp[np.isfinite(comp)].tolist())
    for k in range(K - 1):
        tau_set.update(ship_max[k, 1:L].tolist())
    taus = np.array(sorted(t for t in tau_set if t >= lb))
    if taus.size == 0:
        return None
    T = taus.size

    def seg_cost(k0: int, lo: int, hi: int):
        """(fill, stage max) of zero-based stage k0 hosting [lo, hi]."""
        c = comp[k0, lo, hi]
        if c == INF:
            return None
        fill = c * inv_M
        smax = c
        if k0 < K - 1:
            fill += ship_sum[k0, hi] * inv_M + ship_prop[k0]
            smax = max(smax, ship_max[k0, hi])
        return fill, smax

    dp = np.full((K + 1, L + 1, T), INF)
    choice = np.full((K + 1, L + 1, T), -1, dtype=np.int32)
    for e in range(1, L - K + 2):
        sc = seg_cost(0, 1, e)
        if sc is not None:
            dp[1, e] = np.where(taus >= sc[1], sc[0], INF)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                sc = seg_cost(k - 1, e2 + 1, e)
                if sc is None:
                    continue
                cand = dp[k - 1, e2] + np.where(taus >= sc[1], sc[0], INF)
                better = cand < dp[k, e]
                if better.any():
                    dp[k, e][better] = cand[better]
                    choice[k, e][better] = e2

    tot = dp[K, L] + c_bub * taus
    t_idx = int(np.argmin(tot))
    if not np.isfinite(tot[t_idx]):
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = int(choice[k, e, t_idx])
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments
