"""K-sequence segmentation via dynamic programming (paper Alg. 2, [23]).

Optimizes the model splitting y_t for a *fixed* placement + chaining x_{t-1}:
segment k's cost is its compute time at the node currently hosting F^k plus the
cost of shipping its output cut along the current (k)th inter-stage path.
Capacity violations (constraints (14)-(15)) yield +inf, as in the paper.

We index dp[k][e] = min cost of covering layers 1..e with k segments (the paper's
dp_{k,l} covers 1..l-1; the shift removes its off-by-one at the last segment).
Complexity O(K L^2) segment evaluations, per Sec. V-D.
"""
from __future__ import annotations

from .costmodel import BW, FW, TR, ModelProfile
from .network import PhysicalNetwork
from .plan import EvalCache, Plan, PlanEvaluator, ServiceChainRequest

INF = float("inf")


def _segment_cost(
    ev: PlanEvaluator,
    profile: ModelProfile,
    net: PhysicalNetwork,
    request: ServiceChainRequest,
    k: int,
    K: int,
    lo: int,
    hi: int,
    placement: list[str],
    paths: list[list[str]],
) -> float:
    """T(x^k, 1^k_{lo,hi}, b, mode): compute at placement[k] + outgoing cut shipping."""
    node = placement[k]
    if not ev.segment_fits(node, lo, hi):
        return INF
    cost = ev.segment_comp_s(node, lo, hi)
    if k < K - 1:  # ship delta_hi along the existing (k+1)-th subpath
        trans, prop = ev.cut_transfer_s(paths[k], hi)
        cost += trans + prop
    return cost


def k_sequence_segmentation(
    net: PhysicalNetwork,
    profile: ModelProfile,
    request: ServiceChainRequest,
    plan: Plan,
    cache: EvalCache | None = None,
) -> list[tuple[int, int]] | None:
    """Re-split L layers into K segments for plan's fixed placement/chaining."""
    K, L = plan.K, profile.L
    ev = PlanEvaluator(net, profile, request, cache=cache)
    placement, paths = plan.placement, plan.paths

    def segcost(k: int, lo: int, hi: int) -> float:
        return _segment_cost(ev, profile, net, request, k, K, lo, hi, placement, paths)

    # dp[k][e]: k segments covering layers 1..e; e in [k, L-(K-k)]
    dp = [[INF] * (L + 1) for _ in range(K + 1)]
    choice = [[-1] * (L + 1) for _ in range(K + 1)]
    for e in range(1, L - K + 2):
        dp[1][e] = segcost(0, 1, e)
    for k in range(2, K + 1):
        e_vals = range(k, L - K + k + 1) if k < K else [L]
        for e in e_vals:
            for e2 in range(k - 1, e):
                prev = dp[k - 1][e2]
                if prev == INF:
                    continue
                c = prev + segcost(k - 1, e2 + 1, e)
                if c < dp[k][e]:
                    dp[k][e] = c
                    choice[k][e] = e2
    if dp[K][L] == INF:
        return None
    cuts = []
    e = L
    for k in range(K, 1, -1):
        e = choice[k][e]
        cuts.append(e)
    cuts.reverse()
    segments, lo = [], 1
    for c in cuts + [L]:
        segments.append((lo, c))
        lo = c + 1
    return segments
