"""Network topologies: NSFNET (paper Sec. VI-A2), random G(V, p), TPU pod graphs."""
from __future__ import annotations

import random

from .costmodel import CPU_XEON_6226R, GPU_RTX_A6000, tpu_group_compute_model
from .network import LinkSpec, NodeSpec, PhysicalNetwork

GB = 1024**3
GBPS = 1e9  # 1 Gb/s in bits/s

# NSFNET 14-node / 21-undirected-edge (42 directed links) topology with fiber
# distances in km (standard published distance set; the paper does not print its
# table, only the resulting propagation-delay range 1.23--14.2 ms).
NSFNET_EDGES_KM: list[tuple[int, int, float]] = [
    (1, 2, 1100), (1, 3, 1600), (1, 8, 2800),
    (2, 3, 600), (2, 4, 1000),
    (3, 6, 2000),
    (4, 5, 600), (4, 11, 2400),
    (5, 6, 1100), (5, 7, 800),
    (6, 10, 1200), (6, 13, 2000),
    (7, 8, 700),
    (8, 9, 700),
    (9, 10, 900), (9, 12, 500), (9, 13, 500),
    (11, 12, 800), (11, 14, 800),
    (12, 14, 600),
    (13, 14, 300),
]
FIBER_SPEED_KM_S = 2.0419e5  # c / 1.468 (speed of light in optical fiber)


def propagation_delay_s(dist_km: float) -> float:
    return dist_km / FIBER_SPEED_KM_S


def nsfnet(
    source: str = "v4",
    gpu_mem_gb: float = 2.0,
    cpu_mem_gb: float = 8.0,
    bandwidth_bps: float = GBPS,
) -> PhysicalNetwork:
    """NSFNET with the paper's node setup: `source` is the sole CPU node (8 GB),
    all others GPU nodes (2 GB); every link 1 Gb/s both directions."""
    net = PhysicalNetwork()
    for i in range(1, 15):
        name = f"v{i}"
        if name == source:
            net.add_node(NodeSpec(name, CPU_XEON_6226R, cpu_mem_gb * GB, cpu_mem_gb * GB))
        else:
            net.add_node(NodeSpec(name, GPU_RTX_A6000, gpu_mem_gb * GB, gpu_mem_gb * GB))
    for u, v, km in NSFNET_EDGES_KM:
        d = propagation_delay_s(km)
        net.add_bidirectional(f"v{u}", f"v{v}", LinkSpec(bandwidth_bps, bandwidth_bps, d, d))
    return net


def random_network(
    n_nodes: int,
    p: float = 0.2,
    seed: int = 0,
    source: str | None = None,
    bandwidth_bps: float = GBPS,
) -> PhysicalNetwork:
    """Random graphs for the scalability study (paper Sec. VI-D): each node pair is
    linked with probability p; a ring backbone guarantees connectivity; delays are
    drawn from the paper's NSFNET propagation-delay range."""
    rng = random.Random(seed)
    net = PhysicalNetwork()
    names = [f"v{i}" for i in range(1, n_nodes + 1)]
    source = source or names[0]
    for name in names:
        if name == source:
            net.add_node(NodeSpec(name, CPU_XEON_6226R, 8 * GB, 8 * GB))
        else:
            net.add_node(NodeSpec(name, GPU_RTX_A6000, 2 * GB, 2 * GB))
    # Connectivity ring, normalized to (min, max) like the random edges below:
    # the wraparound pair {v1, vN} must be stored as (0, n-1), not (n-1, 0),
    # or a random draw of (0, n-1) would re-add the same undirected link —
    # silently overwriting it, double-counting the edge in sorted(edges), and
    # shifting the seeded delay stream.
    edges = {tuple(sorted((i, (i + 1) % n_nodes))) for i in range(n_nodes)
             if n_nodes > 1}
    for i in range(n_nodes):
        for j in range(i + 1, n_nodes):
            if rng.random() < p:
                edges.add((i, j))
    for i, j in sorted(edges):
        d = rng.uniform(1.23e-3, 14.2e-3)
        net.add_bidirectional(names[i], names[j], LinkSpec(bandwidth_bps, bandwidth_bps, d, d))
    return net


def candidate_sets(K: int, seed: int, nodes: list[str],
                   source: str, dest: str, per_stage: int = 2) -> list[list[str]]:
    """Paper Sec. VI-A2 candidate policy: first/last stage pinned to s/d; each
    intermediate sub-model gets `per_stage` randomly, distinctly selected
    candidate nodes."""
    rng = random.Random(seed * 1000 + K)
    mids = [n for n in nodes if n not in (source, dest)]
    n_needed = per_stage * (K - 2)
    if n_needed > len(mids):
        raise ValueError(
            f"candidate_sets: K={K} with per_stage={per_stage} needs "
            f"{n_needed} distinct intermediate nodes but only {len(mids)} "
            f"are available (|nodes|={len(nodes)} minus source/destination); "
            f"lower K or per_stage, or use a larger topology")
    picked = rng.sample(mids, n_needed) if K > 2 else []
    cands = [[source]]
    for k in range(K - 2):
        cands.append(picked[per_stage * k : per_stage * (k + 1)])
    cands.append([dest])
    return cands


# ---------------------------------------------------------------- TPU adaptation
V5E_HBM_GB = 16.0
ICI_LINK_BPS = 50e9 * 8  # ~50 GB/s per ICI link
DCN_LINK_BPS = 25e9 * 8  # inter-pod data-center network
ICI_HOP_DELAY_S = 1e-6
DCN_HOP_DELAY_S = 10e-6


def tpu_pod_topology(
    n_groups: int = 16,
    chips_per_group: int = 16,
    n_pods: int = 1,
    mfu: float = 0.5,
) -> PhysicalNetwork:
    """TPU-native planner graph (DESIGN.md Sec. 2.2): each node is a stage group of
    `chips_per_group` v5e chips; groups within a pod form an ICI ring; pods are
    joined by DCN links between their first groups.  HBM of the group is the
    planner's memory capacity (constraint (15))."""
    net = PhysicalNetwork()
    cm = tpu_group_compute_model(chips_per_group, mfu=mfu)
    hbm = chips_per_group * V5E_HBM_GB * GB
    for p in range(n_pods):
        for g in range(n_groups):
            net.add_node(NodeSpec(f"p{p}g{g}", cm, hbm, hbm))
    for p in range(n_pods):
        for g in range(n_groups):
            u, v = f"p{p}g{g}", f"p{p}g{(g + 1) % n_groups}"
            net.add_bidirectional(u, v, LinkSpec(ICI_LINK_BPS, ICI_LINK_BPS,
                                                 ICI_HOP_DELAY_S, ICI_HOP_DELAY_S))
    for p in range(n_pods - 1):
        net.add_bidirectional(f"p{p}g0", f"p{p + 1}g0",
                              LinkSpec(DCN_LINK_BPS, DCN_LINK_BPS,
                                       DCN_HOP_DELAY_S, DCN_HOP_DELAY_S))
    return net
