"""Round-trip pipelined latency model for split *learning* (docs/training.md).

The fused evaluator (plan.py) models training as a per-stage FW+BW sum inside
the inference latency shape: good enough for the sequential schedule (where
only the per-stage totals matter) but wrong for pipelining, because the
backward pass is a *second wave* that traverses the placed chain in reverse —
gradients are their own smashed flow (``delta^BW`` sizes over the links'
backward channels), and the pipeline has two bottlenecks, one per direction.

This module is the round-trip model for ``mode=TR, schedule=pipe, M > 1``
(GPipe-style F-then-B, matching the tick semantics of ``msl/pipeline.py``):

* Every resource is *two* pipeline stages: a hosting node runs a forward pass
  (rho^FW flops) and later a backward pass (rho^BW flops); every physical link
  of subpath k carries ``b * delta^FW`` downstream on its forward channel and
  ``b * delta^BW`` upstream on its backward channel.
* A microbatch's round trip costs its share ``t/M`` of every stage in both
  directions, plus every link's propagation once per direction (the fill), and
  the tail subpath's forward propagation (psi_K = 0, as in Eq. 16).
* Steady state is dominated by the *sum* of the two per-direction bottlenecks:
  after warm-up the chain completes one microbatch round trip every
  ``tau_fw + tau_bw`` seconds (the bottleneck node must run one forward and
  one backward pass per microbatch; the bottleneck link ships one activation
  and one gradient), so the drain term is ``(M-1) * (tau_fw + tau_bw) / M``.

    T_rt = fill_rt + (M-1)/M * (tau_fw + tau_bw)
    fill_rt = sum(all per-direction stage times)/M + all propagation

Sanity anchors (tests/test_trainpipe.py): a uniform K-stage chain with
per-stage forward time f and backward time b reproduces the GPipe schedule
length (M + K - 1) * (f + b); T_rt is <= the sequential TR latency for every
plan (tau_fw <= sum of forward stages, tau_bw <= sum of backward stages); and
the fill equals the fused pipelined fill bit-for-bit-compatible in value, so
the round-trip model only *adds* the second bottleneck to the drain.

``seq``+TR and every IF path never reach this module — the dispatch in
``PlanEvaluator.evaluate`` routes here only for TR+pipe with M > 1, keeping
those anchors bit-for-bit unchanged.
"""
from __future__ import annotations

from .costmodel import BW, FW, TR
from .network import transmission_time_s


def segment_comp_dir_s(ev, node: str, lo: int, hi: int, direction: str) -> float:
    """Single-direction Eq. (17) compute time of sub-model [lo, hi] at node.

    Cached in the evaluator's EvalCache comp table under 8-tuple keys
    ``(node, lo, hi, direction, b, mode, schedule, M)`` — length-disjoint from
    the fused 7-tuple entries, so fused and per-direction values never alias
    even inside a shared cache.
    """
    key = (node, lo, hi, direction, *ev._ck)
    cache = ev.cache
    hit = cache.comp.get(key)
    if hit is not None:
        cache.hits += 1
        return hit
    cache.misses += 1
    cm = ev.net.nodes[node].compute
    t = cm.comp_time_s(ev.request.batch_size,
                       ev.profile.seg_flops(lo, hi, direction))
    cache.comp[key] = t
    return t


def round_trip_stage_times(ev, plan) -> tuple[list[float], list[float]]:
    """(forward, backward) full-batch occupancy of every pipeline resource:
    the K hosting nodes' per-direction compute, then each physical link of
    each inter-stage subpath (activation transfer on the forward channel,
    gradient transfer on the backward channel).  ``max`` of each list is the
    per-direction bottleneck (tau_fw, tau_bw)."""
    fw_times: list[float] = []
    bw_times: list[float] = []
    b = ev.request.batch_size
    for (lo, hi), node in zip(plan.segments, plan.placement):
        fw_times.append(segment_comp_dir_s(ev, node, lo, hi, FW))
        bw_times.append(segment_comp_dir_s(ev, node, lo, hi, BW))
    for k, path in enumerate(plan.paths):
        cut = plan.segments[k][1]
        fw_bytes = b * ev.profile.cut_bytes(cut, FW)
        bw_bytes = b * ev.profile.cut_bytes(cut, BW)
        for u, v in zip(path, path[1:]):
            link = ev.net.links[(u, v)]
            fw_times.append(transmission_time_s(fw_bytes, link.bw_fw))
            bw_times.append(transmission_time_s(bw_bytes, link.bw_bw))
    return fw_times, bw_times


def round_trip_taus(ev, plan) -> tuple[float, float]:
    """(tau_fw, tau_bw): the slowest forward and slowest backward stage."""
    fw_times, bw_times = round_trip_stage_times(ev, plan)
    return max(fw_times), max(bw_times)


def round_trip_bottleneck_s(ev, plan) -> float:
    """Steady-state round-trip period tau_fw + tau_bw: one microbatch
    completes per period once the pipeline is warm, so the serve layer's
    sustainable-rate clamp for a training chain is 1 / this."""
    tau_fw, tau_bw = round_trip_taus(ev, plan)
    return tau_fw + tau_bw


def evaluate_round_trip(ev, plan, n_microbatches: int):
    """Round-trip pipelined latency T_rt = fill_rt + (M-1)/M*(tau_fw+tau_bw).

    The forward wave charges each host's FW compute and each subpath link's
    activation transfer (t/M fill shares, full forward propagation, running
    tau_fw max); the backward wave charges BW compute and gradient transfers
    over the same links' backward channels (the reverse traversal visits the
    same link set, so fill sums iterate subpaths in forward order — the
    decomposition is order-independent).  The psi_K = 0 tail charges forward
    propagation only, exactly like the sequential evaluator.

    The jitted twin (``jax_solvers._fast_evaluate``) mirrors this accumulation
    order operation-for-operation — bit parity, not closeness.
    """
    from .plan import LatencyBreakdown  # deferred: plan.py imports this module

    assert ev.request.mode == TR
    M = n_microbatches
    out = LatencyBreakdown()
    b = ev.request.batch_size
    tau_fw = tau_bw = 0.0
    # forward wave: activations flow source -> destination
    for (lo, hi), node in zip(plan.segments, plan.placement):
        t = segment_comp_dir_s(ev, node, lo, hi, FW)
        out.computation_s += t / M
        tau_fw = max(tau_fw, t)
    for k, path in enumerate(plan.paths):
        fw_bytes = b * ev.profile.cut_bytes(plan.segments[k][1], FW)
        for u, v in zip(path, path[1:]):
            link = ev.net.links[(u, v)]
            t = transmission_time_s(fw_bytes, link.bw_fw)
            out.transmission_s += t / M
            out.propagation_s += link.delay_fw
            tau_fw = max(tau_fw, t)
    if plan.tail_path:  # psi_K = 0: forward propagation only
        _, prop = ev.net.path_cost_breakdown(plan.tail_path, 0.0, None)
        out.propagation_s += prop
    # backward wave: gradients flow destination -> source over the reverse
    # subpaths, charged on the links' backward channels (R^BW convention)
    for (lo, hi), node in zip(plan.segments, plan.placement):
        t = segment_comp_dir_s(ev, node, lo, hi, BW)
        out.computation_s += t / M
        tau_bw = max(tau_bw, t)
    for k, path in enumerate(plan.paths):
        bw_bytes = b * ev.profile.cut_bytes(plan.segments[k][1], BW)
        for u, v in zip(path, path[1:]):
            link = ev.net.links[(u, v)]
            t = transmission_time_s(bw_bytes, link.bw_bw)
            out.transmission_s += t / M
            out.propagation_s += link.delay_bw
            tau_bw = max(tau_bw, t)
    out.bubble_s = (M - 1) * (tau_fw + tau_bw) / M
    return out
