from .pipeline import BatchSpec, Prefetcher, SyntheticLM, shard_batch

__all__ = ["BatchSpec", "SyntheticLM", "Prefetcher", "shard_batch"]
