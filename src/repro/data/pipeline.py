"""Deterministic synthetic data pipeline: sharded host batches + prefetch.

Token streams are generated per (shard, step) from a counter-based hash so any
host can materialize exactly its slice — restart/elastic-safe (no file offsets
to replay, checkpoint only stores the step).  A background thread prefetches.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np


def _philox_tokens(seed: int, step: int, shape: tuple[int, ...], vocab: int,
                   salt: int = 0) -> np.ndarray:
    rng = np.random.Generator(np.random.Philox(key=seed, counter=[0, 0, salt, step]))
    return rng.integers(0, vocab, size=shape, dtype=np.int32)


@dataclass
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab: int
    memory_len: int = 0
    d_model: int = 0


class SyntheticLM:
    """Markov-ish synthetic LM stream: targets are a deterministic function of
    tokens so a training loop can actually reduce loss (used by examples)."""

    def __init__(self, spec: BatchSpec, seed: int = 0):
        self.spec, self.seed = spec, seed

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        s = self.spec
        toks = _philox_tokens(self.seed, step, (s.global_batch, s.seq_len + 1),
                              s.vocab)
        # learnable structure: every 4th token repeats the previous one
        toks[:, 1::4] = toks[:, 0:-1:4]
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if s.memory_len:
            rng = np.random.Generator(
                np.random.Philox(key=self.seed, counter=[1, 0, 0, step]))
            batch["memory"] = rng.standard_normal(
                (s.global_batch, s.memory_len, s.d_model), dtype=np.float32)
        return batch


class Prefetcher:
    def __init__(self, stream: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()


def shard_batch(batch: dict, sharding) -> dict:
    """Place host numpy batch onto the mesh (batch dim sharded)."""
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
