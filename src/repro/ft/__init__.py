from .manager import ElasticPlanController, FTEvent, StepTimeCalibrator

__all__ = ["ElasticPlanController", "FTEvent", "StepTimeCalibrator"]
