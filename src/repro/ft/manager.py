"""Fault tolerance & elasticity: checkpoint/restart, node-failure re-planning,
straggler mitigation — the paper's planner as the recovery mechanism.

On a node failure the controller (1) drops the node from the planner topology,
(2) re-solves splitting/placement/chaining with BCD (tens of ms — Fig. 10's
headline), (3) restores the last checkpoint and re-jits the step for the new
plan.  Straggler mitigation follows the paper's kappa_i calibration: per-node
step times are re-fit by OLS (kappa(b, phi) = (alpha b + beta) phi, Sec. VI-A2)
and the planner re-runs when the refreshed model predicts a better chain.

At 1000+ nodes the same machinery applies per pod-group: the planner graph is
the pod-level topology (DESIGN.md Sec. 2.2), so re-planning cost is O(groups),
not O(chips), and checkpoint restore is the only O(params) step.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import numpy as np

from ..core import ComputeModel, PhysicalNetwork, ProblemInstance, solve
from ..core.costmodel import ModelProfile
from ..core.plan import ServiceChainRequest


@dataclass
class StepTimeCalibrator:
    """Online OLS re-fit of kappa_i from measured (b, phi, seconds) samples."""

    samples: dict[str, list[tuple[float, float, float]]] = field(
        default_factory=dict)

    def record(self, node: str, batch: int, flops: float, seconds: float):
        self.samples.setdefault(node, []).append((batch, flops, seconds))

    def fit(self, node: str) -> ComputeModel | None:
        """OLS over t = (alpha*b + beta) * phi  =>  t/phi = alpha*b + beta."""
        pts = self.samples.get(node, [])
        if len(pts) < 2:
            return None
        b = np.array([p[0] for p in pts])
        y = np.array([p[2] / max(p[1], 1.0) for p in pts])
        alpha, beta = np.polyfit(b, y, 1)
        # ComputeModel constants are in ms per FLOP (paper Table II convention)
        return ComputeModel(name=f"fitted-{node}",
                            pieces=((math.inf, alpha * 1e3, beta * 1e3),))


@dataclass
class FTEvent:
    step: int
    kind: str  # failure | straggler | replan | restore
    detail: str


class ElasticPlanController:
    """Holds the current plan; re-plans on failures/stragglers."""

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 request: ServiceChainRequest, K: int,
                 candidates: list[list[str]]):
        self.net = net
        self.profile = profile
        self.request = request
        self.K = K
        self.candidates = [list(c) for c in candidates]
        self.calibrator = StepTimeCalibrator()
        self.events: list[FTEvent] = []
        self.result = self._solve()
        if not self.result.feasible:
            raise ValueError("initial plan infeasible")

    def _solve(self):
        return solve(ProblemInstance(self.net, self.profile, self.request,
                                     self.K, tuple(tuple(c) for c in
                                                   self.candidates)),
                     solver="bcd")

    @property
    def plan(self):
        return self.result.plan

    def fail_node(self, node: str, step: int = -1):
        """Drop a failed node everywhere and re-plan (elastic scaling down)."""
        self.candidates = [[n for n in c if n != node] or c
                           for c in self.candidates]
        for c in self.candidates:
            if not c:
                raise ValueError("no candidates left for a stage")
        self.events.append(FTEvent(step, "failure", node))
        return self._replan(step, f"after losing {node}")

    def observe_step(self, step: int, node: str, batch: int, flops: float,
                     seconds: float, slowdown_threshold: float = 1.5):
        """Record a measured per-node step time; re-fit + re-plan if the node
        is now `slowdown_threshold`x slower than its model predicts."""
        self.calibrator.record(node, batch, flops, seconds)
        predicted = self.net.nodes[node].compute.comp_time_s(batch, flops)
        if predicted > 0 and seconds > slowdown_threshold * predicted:
            fitted = self.calibrator.fit(node)
            if fitted is not None:
                spec = self.net.nodes[node]
                self.net.nodes[node] = type(spec)(
                    spec.name, fitted, spec.mem_capacity, spec.disk_capacity)
                # in-place node swap bypasses add_node: drop derived caches
                # (routing frontiers are compute-independent, but the content
                # key — the planner's instance identity — is not)
                self.net.clear_routing_cache()
                self.events.append(FTEvent(step, "straggler",
                                           f"{node} {seconds/predicted:.1f}x"))
                return self._replan(step, f"straggler {node}")
        return None

    def _replan(self, step: int, why: str):
        t0 = time.perf_counter()
        res = self._solve()
        if not res.feasible:
            raise ValueError(f"re-plan infeasible ({why})")
        changed = res.plan.placement != self.result.plan.placement or \
            res.plan.segments != self.result.plan.segments
        self.result = res
        self.events.append(FTEvent(
            step, "replan",
            f"{why}: {res.plan.placement} segs={res.plan.segments} "
            f"in {(time.perf_counter()-t0)*1e3:.1f}ms changed={changed}"))
        return res.plan
