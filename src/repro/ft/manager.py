"""Fault tolerance & elasticity: checkpoint/restart, node-failure re-planning,
straggler mitigation — the paper's planner as the recovery mechanism.

On a node failure the controller routes through the serve stack's failure
machinery (docs/failures.md): the node is marked down in a
:class:`~repro.serve.ResidualState` (capacity exactly zero, incident links
gone), the hosted chain is detected through the residual reverse index,
released, and re-planned against the *degraded* fabric with BCD (tens of
ms — Fig. 10's headline); the caller then restores the last checkpoint and
re-jits the step for the new plan.  No candidate stripping is needed — a
down node is unreachable in the degraded network, so the solver avoids it
by construction, and a later `recover` can bring it back.

Straggler mitigation follows the paper's kappa_i calibration: per-node step
times are re-fit by OLS (kappa(b, phi) = (alpha b + beta) phi, Sec. VI-A2)
and the planner re-runs when the refreshed model predicts a better chain.
A compute-model swap changes the planner's instance identity (content
hashes), so the straggler path rebuilds the admission core from scratch and
re-applies any standing failures.

At 1000+ nodes the same machinery applies per pod-group: the planner graph is
the pod-level topology (DESIGN.md Sec. 2.2), so re-planning cost is O(groups),
not O(chips), and checkpoint restore is the only O(params) step.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import numpy as np

from ..core import ComputeModel, Plan, PhysicalNetwork
from ..core.costmodel import ModelProfile
from ..core.plan import ServiceChainRequest
from ..serve import AdmissionCore, FailureEvent, ServePlanner, ServeRequest


@dataclass
class StepTimeCalibrator:
    """Online OLS re-fit of kappa_i from measured (b, phi, seconds) samples."""

    samples: dict[str, list[tuple[float, float, float]]] = field(
        default_factory=dict)

    def record(self, node: str, batch: int, flops: float, seconds: float):
        self.samples.setdefault(node, []).append((batch, flops, seconds))

    def fit(self, node: str) -> ComputeModel | None:
        """OLS over t = (alpha*b + beta) * phi  =>  t/phi = alpha*b + beta."""
        pts = self.samples.get(node, [])
        if len(pts) < 2:
            return None
        b = np.array([p[0] for p in pts])
        y = np.array([p[2] / max(p[1], 1.0) for p in pts])
        alpha, beta = np.polyfit(b, y, 1)
        # ComputeModel constants are in ms per FLOP (paper Table II convention)
        return ComputeModel(name=f"fitted-{node}",
                            pieces=((math.inf, alpha * 1e3, beta * 1e3),))


@dataclass
class FTEvent:
    step: int
    kind: str  # failure | straggler | replan | restore
    detail: str


@dataclass
class _PlanResult:
    """The controller's current plan + its predicted latency (the shape the
    demo and callers consumed from the legacy SolveResult)."""

    plan: Plan
    latency_s: float
    feasible: bool = True


class ElasticPlanController:
    """Holds the current plan; re-plans on failures/stragglers.

    Internally this is a one-chain :class:`~repro.serve.AdmissionCore`: the
    training chain is admitted onto the fabric's residual state, node
    failures are :class:`~repro.serve.FailureEvent` marks whose victim
    migration *is* the re-plan, and `recover_node` restores capacity.
    """

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 request: ServiceChainRequest, K: int,
                 candidates: list[list[str]]):
        self.net = net
        self.profile = profile
        self.request = request
        self.K = K
        self.candidates = [list(c) for c in candidates]
        self.calibrator = StepTimeCalibrator()
        self.events: list[FTEvent] = []
        self.down_nodes: list[str] = []  # standing failures, survive rebuilds
        self._core: AdmissionCore | None = None
        rec = self._rebuild_core()
        if rec is None:
            raise ValueError("initial plan infeasible")
        self.result = _PlanResult(rec.plan, rec.latency_s)

    def _serve_request(self) -> ServeRequest:
        r = self.request
        return ServeRequest(
            request_id=0, source=r.source, destination=r.destination,
            batch_size=r.batch_size, mode=r.mode, K=self.K,
            candidates=tuple(tuple(c) for c in self.candidates),
            model_id=r.model_id, schedule=r.schedule,
            n_microbatches=r.n_microbatches)

    def _rebuild_core(self):
        """Fresh planner + admission core over the *current* ``self.net``
        (compute models included), with standing node failures re-applied
        before the chain is admitted.  Returns the accepted record or None."""
        planner = ServePlanner(self.net, self.profile, solver="bcd")
        serve_req = self._serve_request()
        presolved, keys, _ = planner.presolve([serve_req])
        core = AdmissionCore(planner, presolved, keys)
        for node in self.down_nodes:
            core.state.fail_node(node)
        self._core = core
        return core.try_admit(serve_req)

    @property
    def plan(self):
        return self.result.plan

    def fail_node(self, node: str, step: int = -1):
        """Mark `node` down and live-migrate the chain off it (elastic
        scaling down).  The degraded fabric — not a stripped candidate
        list — is what makes the solver avoid the dead node."""
        if node not in self.net.nodes:
            raise ValueError(f"unknown node {node!r}")
        self.events.append(FTEvent(step, "failure", node))
        self.down_nodes.append(node)
        t0 = time.perf_counter()
        victims = self._core.apply_failure(
            FailureEvent(t_s=float(max(step, 0)), kind="node_down",
                         node=node))
        if not victims:
            # the dead node hosted nothing: the current plan survives
            self.events.append(FTEvent(
                step, "replan", f"after losing {node}: plan unchanged"))
            return self.plan
        rec = victims[0]
        if rec.failed_s is not None:  # no feasible placement remains
            raise ValueError(f"re-plan infeasible (after losing {node})")
        return self._adopt(rec, step, f"after losing {node}", t0)

    def recover_node(self, node: str, step: int = -1):
        """Bring a previously failed node back (capacity restored); the
        current plan is kept — the next failure/straggler re-plan may use
        the node again."""
        if node not in self.down_nodes:
            raise ValueError(f"{node!r} is not down")
        self.down_nodes.remove(node)
        self._core.apply_failure(
            FailureEvent(t_s=float(max(step, 0)), kind="recover", node=node))
        self.events.append(FTEvent(step, "restore", node))
        return self.plan

    def observe_step(self, step: int, node: str, batch: int, flops: float,
                     seconds: float, slowdown_threshold: float = 1.5):
        """Record a measured per-node step time; re-fit + re-plan if the node
        is now `slowdown_threshold`x slower than its model predicts."""
        self.calibrator.record(node, batch, flops, seconds)
        predicted = self.net.nodes[node].compute.comp_time_s(batch, flops)
        if predicted > 0 and seconds > slowdown_threshold * predicted:
            fitted = self.calibrator.fit(node)
            if fitted is not None:
                spec = self.net.nodes[node]
                self.net.nodes[node] = type(spec)(
                    spec.name, fitted, spec.mem_capacity, spec.disk_capacity)
                # in-place node swap bypasses add_node: drop derived caches
                # (routing frontiers are compute-independent, but the content
                # key — the planner's instance identity — is not)
                self.net.clear_routing_cache()
                self.events.append(FTEvent(step, "straggler",
                                           f"{node} {seconds/predicted:.1f}x"))
                t0 = time.perf_counter()
                rec = self._rebuild_core()
                if rec is None:
                    raise ValueError(f"re-plan infeasible (straggler {node})")
                return self._adopt(rec, step, f"straggler {node}", t0)
        return None

    def _adopt(self, rec, step: int, why: str, t0: float):
        changed = rec.plan.placement != self.result.plan.placement or \
            rec.plan.segments != self.result.plan.segments
        self.result = _PlanResult(rec.plan, rec.latency_s)
        self.events.append(FTEvent(
            step, "replan",
            f"{why}: {rec.plan.placement} segs={rec.plan.segments} "
            f"in {(time.perf_counter()-t0)*1e3:.1f}ms changed={changed}"))
        return rec.plan
