"""Pallas TPU kernels for the substrate's compute hot spots (the paper itself
has no kernel-level contribution — see DESIGN.md Sec. 2.3): flash attention,
per-expert grouped matmul, RG-LRU recurrence, Mamba-2 SSD intra-chunk.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
ops.py (jit'd wrappers), ref.py (pure-jnp oracles).  Validated in interpret
mode on CPU; Mosaic lowering on real TPUs.
"""
from . import ops, ref
from .flash_attention import flash_attention
from .minplus import minplus_matmul
from .moe_gmm import expert_matmul
from .rglru import rglru_scan
from .ssd import ssd_intra_chunk

__all__ = ["ops", "ref", "flash_attention", "expert_matmul", "minplus_matmul",
           "rglru_scan", "ssd_intra_chunk"]
