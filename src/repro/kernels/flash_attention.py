"""Flash attention Pallas TPU kernel (GQA, causal, sliding-window, softcap).

TPU adaptation of the memory-bound attention hot spot: the (Bq, Bk) score tile
lives in VMEM, the running max / normalizer / accumulator persist in VMEM
scratch across the sequential kv-block grid dimension, and only the final
normalized output tile is written back to HBM.  MXU-aligned tiles: Bq, Bk
multiples of 128 lanes; fp32 accumulation regardless of input dtype.

Grid: (B, Hq, nq, nk) with ("parallel","parallel","parallel","arbitrary")
semantics — nk is the sequential reduction dimension.  GQA: the kv BlockSpec
index-maps query head h to kv head h // (Hq // Hkv), so kv tiles are fetched
once per kv head group.

Validated in interpret mode against ref.reference_attention (tests/test_kernels).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, nk: int,
                  seq_q: int, seq_kv: int, causal: bool, window: int | None,
                  softcap: float | None):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (Bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_idx < seq_q) & (k_idx < seq_kv)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= q_idx - k_idx < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    v = v_ref[0, 0].astype(jnp.float32)
    l_scr[...] = corr * l_prev + jnp.sum(p, axis=1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_scr[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    block_q = min(block_q, max(Sq, 1))
    block_k = min(block_k, max(Skv, 1))
    nq = -(-Sq // block_q)
    nk = -(-Skv // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Skv
    qt = jnp.moveaxis(q, 2, 1)  # (B, Hq, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k, nk=nk,
        seq_q=Sq, seq_kv=Skv, causal=causal, window=window, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)
