"""Tropical (min-plus) matmul Pallas kernel for frontier composition.

The DFTS tour relaxation (core/dfts.py) and its batched JAX port
(core/jax_solvers.py) compose per-stage frontier matrices in the tropical
semiring: ``val[m, n] = min_k a[m, k] + b[k, n]`` with the *first* minimizing
``k`` returned as a predecessor index (ties resolve to the lowest index, the
np/jnp ``argmin`` convention the NumPy oracle relies on for bit-parity).

Per batch element the kernel keeps the whole (padded) tile in VMEM and scans
the contraction axis with a strict-``<`` running min/argmin, so the result is
independent of accumulation order (IEEE min is associative/commutative for
the +inf-padded, NaN-free cost matrices the solvers produce).  +inf is the
semiring zero: padded rows/columns are absorbing and can never win a min
against a finite entry, which is what makes shape padding safe.

Validated in interpret mode on CPU (the CI path); Mosaic lowering on TPU.
The jnp oracle is :func:`repro.kernels.ref.reference_minplus`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile floors for TPU layout: second-to-last dim multiples of 8, last dim
# multiples of 128.  Frontier matrices are tiny (S <= ~16 candidates), so a
# single padded block per batch element is the whole problem.
_BM = 8
_BK = 128
_BN = 128


def _minplus_kernel(a_ref, b_ref, val_ref, idx_ref):
    a = a_ref[0]  # (M, K)
    b = b_ref[0]  # (K, N)
    m, k = a.shape
    n = b.shape[1]

    def body(j, carry):
        val, idx = carry
        cand = a[:, j][:, None] + b[j, :][None, :]  # (M, N)
        better = cand < val  # strict: first minimum wins (argmin convention)
        return (jnp.where(better, cand, val),
                jnp.where(better, j, idx))

    val0 = jnp.full((m, n), jnp.inf, dtype=val_ref.dtype)
    idx0 = jnp.zeros((m, n), dtype=jnp.int32)
    val, idx = jax.lax.fori_loop(0, k, body, (val0, idx0))
    val_ref[0] = val
    idx_ref[0] = idx


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_matmul(a, b, *, interpret: bool | None = None):
    """Batched tropical matmul: a (..., M, K) ∘ b (..., K, N).

    Returns ``(val, idx)`` with ``val[..., m, n] = min_k a[..., m, k] +
    b[..., k, n]`` and ``idx`` the first minimizing ``k`` (int32; 0 when the
    whole column is +inf, matching ``jnp.argmin``).  Inputs are padded with
    +inf to TPU tile multiples and the padding is sliced back off, so any
    shapes (including non-tile-multiples) are accepted.
    """
    if a.ndim != b.ndim or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"batch dims must match, got {a.shape} vs {b.shape}")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"contraction dims must match, got {a.shape} vs "
                         f"{b.shape}")
    batch = a.shape[:-2]
    M, K = a.shape[-2:]
    N = b.shape[-1]
    a3 = _pad_to(_pad_to(a.reshape((-1, M, K)), 1, _BM), 2, _BK)
    b3 = _pad_to(_pad_to(b.reshape((-1, K, N)), 1, _BK), 2, _BN)
    B, Mp, Kp = a3.shape
    Np = b3.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    val, idx = pl.pallas_call(
        _minplus_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Mp, Kp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Kp, Np), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Mp, Np), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Mp, Np), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Mp, Np), a3.dtype),
            jax.ShapeDtypeStruct((B, Mp, Np), jnp.int32),
        ],
        interpret=interpret,
    )(a3, b3)
    val = val[:, :M, :N].reshape(batch + (M, N))
    idx = idx[:, :M, :N].reshape(batch + (M, N))
    return val, idx
