"""Per-expert (grouped) matmul Pallas TPU kernel for capacity-based MoE.

Computes out[e] = act(x[e] @ w[e]) for every expert tile without materializing
the (E, C, F) intermediate in fp32 HBM: grid (E, C/Bc, F/Bf, D/Bd) with the D
dimension sequential, fp32 accumulation in VMEM scratch, activation fused into
the final write-back.  MXU alignment: Bc/Bf/Bd multiples of 128 (padded).

This is the TPU-native replacement for the three `gecd,edf->gecf` einsums in
models/layers.moe_ffn; the dispatch/combine one-hots stay XLA einsums (they are
bandwidth-, not compute-, bound and GSPMD already shards them over EP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nd: int, activation: str):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)  # (Bc, Bd)
    w = w_ref[0].astype(jnp.float32)  # (Bd, Bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _finish():
        acc = acc_scr[...]
        if activation == "silu":
            acc = acc * jax.nn.sigmoid(acc)
        elif activation == "gelu":
            acc = jax.nn.gelu(acc, approximate=True)
        o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "block_c", "block_f",
                                             "block_d", "interpret"))
def expert_matmul(x, w, *, activation: str = "none", block_c: int = 128,
                  block_f: int = 128, block_d: int = 512,
                  interpret: bool | None = None):
    """x: (E, C, D), w: (E, D, F) -> act(x @ w): (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    nc, nf, nd = -(-C // block_c), -(-F // block_f), -(-D // block_d)
    xp = jnp.pad(x, ((0, 0), (0, nc * block_c - C), (0, nd * block_d - D)))
    wp = jnp.pad(w, ((0, 0), (0, nd * block_d - D), (0, nf * block_f - F)))
    kernel = functools.partial(_gmm_kernel, nd=nd, activation=activation)
    out = pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, nc * block_c, nf * block_f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :C, :F]
