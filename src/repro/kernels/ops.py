"""jit'd public wrappers around the Pallas kernels (interpret=True on CPU, real
Mosaic lowering on TPU), including the composed SSD forward that pairs the
intra-chunk kernel with the jnp inter-chunk recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .moe_gmm import expert_matmul
from .rglru import rglru_scan
from .ssd import ssd_intra_chunk


def ssd_forward(xh, dtv, A, Bm, Cm, h0=None, chunk: int = 256,
                interpret: bool | None = None):
    """Full SSD layer forward via the Pallas intra-chunk kernel.

    xh: (B, S, H, P); dtv: (B, S, H) (softplus'd); A: (H,) positive rates;
    Bm, Cm: (B, S, N).  Matches models.layers._ssd_chunked (the oracle).
    Returns (y (B, S, H, P) fp32, h_last (B, H, P, N) fp32).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, "sequence must divide the chunk size"
    xk = jnp.moveaxis(xh.reshape(Bsz, nc, Q, H, P), 3, 2)  # (B, nc, H, Q, P)
    dtk = jnp.moveaxis(dtv.reshape(Bsz, nc, Q, H), 3, 2)  # (B, nc, H, Q)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    y_intra, chunk_states, in_decay = ssd_intra_chunk(
        xk, Bc, Cc, dtk, A, interpret=interpret)

    # inter-chunk recurrence (tiny, sequential): h_{c} = decay_c * h_{c-1} + S_c
    chunk_decay = in_decay[..., -1]  # (B, nc, H)
    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(h, inp):
        cs, cd = inp  # (B,H,N,P), (B,H)
        h_new = h * cd[:, :, None, None] + jnp.moveaxis(cs, 2, 3)
        return h_new, h

    h_last, h_prevs = jax.lax.scan(
        step, h_init, (jnp.moveaxis(chunk_states, 1, 0),
                       jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B, nc, H, P, N) state BEFORE chunk
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bchqp", Cc.astype(jnp.float32),
                         in_decay, h_prevs)
    y = jnp.moveaxis(y_intra + y_inter, 2, 3).reshape(Bsz, S, H, P)
    return y, h_last


__all__ = ["flash_attention", "expert_matmul", "rglru_scan", "ssd_intra_chunk",
           "ssd_forward"]
