"""Pure-jnp oracles for every Pallas kernel (the ground truth the shape/dtype
sweeps in tests/test_kernels.py assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """Naive attention, same semantics as kernels.flash_attention."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    q32 = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q32, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def reference_expert_matmul(x, w, *, activation="none"):
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    if activation == "silu":
        out = out * jax.nn.sigmoid(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    return out.astype(x.dtype)


def reference_rglru_scan(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan (the model's own path)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def reference_minplus(a, b):
    """Tropical matmul oracle, same semantics as kernels.minplus_matmul:
    val[..., m, n] = min_k a[..., m, k] + b[..., k, n]; idx = first argmin k
    (int32; 0 for all-+inf columns, the jnp.argmin convention)."""
    cand = a[..., :, :, None] + b[..., None, :, :]  # (..., M, K, N)
    return cand.min(axis=-2), cand.argmin(axis=-2).astype(jnp.int32)


def reference_ssd_intra_chunk(x, Bm, Cm, dt, A):
    """Chunk-local SSD terms; mirrors models.layers._ssd_chunked's intra part.

    x: (B, nc, H, Q, P); Bm/Cm: (B, nc, Q, N); dt: (B, nc, H, Q); A: (H,)>0.
    """
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    dA = dt32 * (-A)[None, None, :, None]  # (B, nc, H, Q)
    cum = jnp.cumsum(dA, axis=-1)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cm.astype(jnp.float32),
                        Bm.astype(jnp.float32))
    Q = x.shape[3]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    delta = cum[..., :, None] - cum[..., None, :]  # (B,nc,H,Q,K)
    decay = jnp.exp(jnp.where(causal, delta, -jnp.inf))
    w = scores[:, :, None] * decay
    w = w * dt32[:, :, :, None, :]
    y = jnp.einsum("bchqk,bchkp->bchqp", w, x32)
    end_decay = jnp.exp(cum[..., -1:] - cum) * dt32  # (B, nc, H, Q)
    hc = jnp.einsum("bchq,bcqn,bchqp->bchnp", end_decay,
                    Bm.astype(jnp.float32), x32)
    return y, hc, jnp.exp(cum)
