"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over the sequence, per (batch, channel-block).  The
recurrence is memory-bound: the pure-XLA associative scan materializes
O(log S) intermediate (B, S, W) buffers in HBM; here each (Bs, Bw) tile is
streamed through VMEM once, with the running state h (1, Bw) persisted in VMEM
scratch across the sequential S-block grid dimension.

Within a tile the recurrence over Bs steps uses an in-register fori_loop —
sequential on the VPU by nature (documented trade-off: real Griffin kernels use
the same structure; the channel dimension provides the 128-lane parallelism).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0]  # (Bs, Bw) fp32
    b = b_ref[0]

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    h0 = h_scr[0]
    h_last, out = jax.lax.fori_loop(0, block_s, step,
                                    (h0, jnp.zeros_like(a)))
    h_scr[0] = h_last
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan(a, b, *, block_s: int = 256, block_w: int = 512,
               interpret: bool | None = None):
    """a, b: (B, S, W) fp32 -> h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    ns, nw = -(-S // block_s), -(-W // block_w)
    pad_s, pad_w = ns * block_s - S, nw * block_w - W
    ap = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
    bp = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=(B, nw, ns),  # S sequential innermost: h carries across s-blocks
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda b_, w, s: (b_, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, ns * block_s, nw * block_w),
                                       a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:, :S, :W]
