"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

Per (batch, chunk, head) tile it computes, entirely in VMEM:
  * within-chunk decay weights  L[q,k] = exp(cum_q - cum_k) (causal),
  * the "attention" form  Y_intra = ((C B^T) ∘ L ∘ dt_k) @ X           (Q, P)
  * the chunk state contribution  H_c = (B ∘ exp(cum_end - cum) ∘ dt)^T X (N, P)
  * the incoming-state decay vector exp(cum)                              (Q,)

The O(Q^2) score tile never touches HBM (the pure-XLA path materializes
(B, nc, Q, Q, H) decay tensors — the dominant HBM term for SSM archs).  The
inter-chunk recurrence (nc steps, O(B H P N) per step) stays a jnp scan in
ops.ssd_forward — it is tiny and sequential.

Layout: x (B, nc, H, Q, P); B/C (B, nc, Q, N); dt (B, nc, H, Q); A (H,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, hc_ref, dec_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0]  # scalar decay rate (positive)

    q = x.shape[0]
    dA = dt * (-a)  # per-step log decay
    cum = jnp.cumsum(dA)  # (Q,)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask the exponent (non-causal deltas are positive -> exp overflow)
    decay = jnp.exp(jnp.where(li >= lj, cum[:, None] - cum[None, :], -jnp.inf))
    w = scores * decay * dt[None, :]
    y_ref[0, 0, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    end_decay = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    hc = jax.lax.dot_general(Bm * end_decay[:, None], x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    hc_ref[0, 0, 0] = hc
    dec_ref[0, 0, 0] = jnp.exp(cum)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, Bm, Cm, dt, A, *, interpret: bool | None = None):
    """x: (B, nc, H, Q, P); Bm/Cm: (B, nc, Q, N); dt: (B, nc, H, Q); A: (H,).

    Returns (y_intra (B,nc,H,Q,P) fp32, chunk_states (B,nc,H,N,P) fp32,
             in_decay (B,nc,H,Q) fp32)."""
    Bsz, nc, H, Q, P = x.shape
    N = Bm.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    out = pl.pallas_call(
        _ssd_kernel,
        grid=(Bsz, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1,), lambda b, c, h: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nc, H, Q), jnp.float32),
        ],
        scratch_shapes=[],
        interpret=interpret,
    )(x, Bm, Cm, dt, A)
    return out
