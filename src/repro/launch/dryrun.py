import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  512 placeholder host devices let jax.make_mesh build
# the production meshes: 16x16 (one pod of 256 v5e chips) and 2x16x16 (2 pods).

# Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh) cell,
# print ``memory_analysis()`` (proves the cell fits 16 GB/chip HBM) and
# ``cost_analysis()`` (FLOPs/bytes for §Roofline), parse the collective
# schedule from the optimized HLO, and write one JSON artifact per cell.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--force] [--quick]
#   PYTHONPATH=src python -m repro.launch.dryrun --cell ARCH SHAPE MESH

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"
HBM_PER_CHIP = 16 * 1024**3  # v5e
MESHES = ("single", "multi")


def cell_path(arch: str, shape: str, mesh: str) -> Path:
    return ARTIFACTS / f"{arch}__{shape}__{mesh}.json"


def run_cell(arch: str, shape_name: str, mesh_name: str) -> dict:
    import jax

    from ..configs import SHAPES, get_config, shape_applicable
    from ..models.profiles import active_params, total_params
    from ..models.sharding import make_rules, mesh_rules
    from ..roofline.analysis import Roofline
    from ..roofline.hlo_cost import analyze_hlo
    from .mesh import make_production_mesh
    from .specs import input_specs

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.size
    # fsdp (batch over every axis) only pays when the batch covers the mesh;
    # below that it duplicates non-weight compute on idle axes and bloats
    # small-batch cells (measured: qwen2 train multi 2.5 -> 25.9 GB).  §Perf.
    strategy = (cfg.sharding_strategy
                if shape.global_batch >= n_dev else "2d")
    rules = make_rules(mesh, strategy)
    t0 = time.perf_counter()
    spec = input_specs(cfg, shape, rules)
    with mesh_rules(rules):
        jitted = jax.jit(spec["fn"], out_shardings=spec["out_shardings"],
                         donate_argnums=spec["donate"])
        lowered = jitted.lower(*spec["args"])
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch}|{shape_name}|{mesh_name}] memory_analysis:", mem)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict], >=0.6 dict
        cost = cost[0] if cost else {}
    builtin_flops = float(cost.get("flops", 0.0))
    builtin_bytes = float(cost.get("bytes accessed", 0.0))
    print(f"[{arch}|{shape_name}|{mesh_name}] cost_analysis (builtin, "
          f"while-bodies-once): flops={builtin_flops:.3e} bytes={builtin_bytes:.3e}")
    # Trip-count-aware analysis over the optimized HLO: XLA's HloCostAnalysis
    # counts while bodies once, undercounting a 48-layer scan 48x and hiding
    # the collectives inside it — see roofline/hlo_cost.py.
    hlo = compiled.as_text()
    mc = analyze_hlo(hlo, n_dev)
    flops = mc.flops
    hbm_bytes = mc.bytes
    coll = {"bytes_per_device": mc.coll_bytes, "counts": mc.coll_counts,
            "total_bytes_per_device": mc.total_coll_bytes,
            "unknown_trip_counts": mc.unknown_trip_counts}
    print(f"[{arch}|{shape_name}|{mesh_name}] trip-aware: flops={flops:.3e} "
          f"bytes={hbm_bytes:.3e} coll={mc.total_coll_bytes:.3e}")

    n_active = active_params(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    model_flops = factor * n_active * tokens

    rf = Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=n_dev,
                  flops_per_device=flops, hbm_bytes_per_device=hbm_bytes,
                  coll_bytes_per_device=coll["total_bytes_per_device"],
                  model_flops_global=model_flops)
    per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
               + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
        "devices": n_dev,
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev,
            "fits_16gb": bool(per_dev <= HBM_PER_CHIP),
        },
        "cost": {"flops_per_device": flops, "hbm_bytes_per_device": hbm_bytes,
                 "builtin_flops": builtin_flops, "builtin_bytes": builtin_bytes},
        "collectives": coll,
        "params": {"total": total_params(cfg), "active": n_active},
        "tokens": tokens,
        "roofline": rf.to_dict(),
        "hlo_bytes": len(hlo),
    }
    return result


def enumerate_cells(quick: bool = False):
    from ..configs import ARCHS, SHAPES

    archs = sorted(ARCHS)
    shapes = list(SHAPES)
    if quick:
        archs, shapes = archs[:2], ["train_4k"]
    for arch in archs:
        for shape in shapes:
            for mesh in MESHES:
                yield arch, shape, mesh


def run_all(force: bool = False, quick: bool = False,
            timeout_s: float = 2400.0) -> int:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    cells = list(enumerate_cells(quick))
    for i, (arch, shape, mesh) in enumerate(cells):
        out = cell_path(arch, shape, mesh)
        if out.exists() and not force:
            prev = json.loads(out.read_text())
            print(f"[{i+1}/{len(cells)}] {arch} {shape} {mesh}: cached "
                  f"({prev.get('status')})")
            failures += prev.get("status") == "error"
            continue
        t0 = time.time()
        env = dict(os.environ)
        src = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--cell", arch,
             shape, mesh],
            capture_output=True, text=True, timeout=timeout_s, env=env)
        status = "ok" if proc.returncode == 0 else "error"
        if proc.returncode != 0:
            failures += 1
            out.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "stderr": proc.stderr[-4000:], "stdout": proc.stdout[-2000:],
            }, indent=2))
        info = json.loads(out.read_text())
        print(f"[{i+1}/{len(cells)}] {arch} {shape} {mesh}: "
              f"{info.get('status')} in {time.time()-t0:.0f}s "
              + (f"compile={info.get('t_compile_s', 0):.0f}s "
                 f"fits={info.get('memory', {}).get('fits_16gb')}"
                 if info.get("status") == "ok" else ""))
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--timeout", type=float, default=2400.0)
    args = ap.parse_args()
    if args.cell:
        arch, shape, mesh = args.cell
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        try:
            result = run_cell(arch, shape, mesh)
        except Exception:
            cell_path(arch, shape, mesh).write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "stderr": traceback.format_exc()[-4000:]}, indent=2))
            raise
        cell_path(arch, shape, mesh).write_text(json.dumps(result, indent=2))
        print(json.dumps({k: v for k, v in result.items() if k != "hlo"},
                         indent=2, default=str))
    else:
        sys.exit(1 if run_all(args.force, args.quick, args.timeout) else 0)


if __name__ == "__main__":
    main()
