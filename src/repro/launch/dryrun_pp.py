import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# MSL pipeline dry-run (paper technique on the production mesh): the BCD
# planner picks K + per-stage group ranges on the pod-level topology; the
# pipelined train step is lowered + compiled on a ('stage','data') mesh carved
# from the 512 placeholder devices; roofline terms from the partitioned HLO.
#
# Usage: PYTHONPATH=src python -m repro.launch.dryrun_pp ARCH OUT.json [K]

import json
import sys
import time


def main() -> None:
    arch = sys.argv[1]
    out_path = sys.argv[2]
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import TRAIN_4K, get_config
    from ..models import transformer as T
    from ..models.profiles import active_params
    from ..msl import make_pipeline_mesh, make_pipeline_train_step, plan_pipeline
    from ..optim import make_optimizer
    from ..roofline.analysis import Roofline
    from ..roofline.hlo_cost import analyze_hlo

    cfg = get_config(arch)
    # Feasible (K, data, M) combos on 512 chips with global batch 256: the
    # microbatch must tile the data axis, so mb = 512/K and M = 256*K/512.
    # The planner scores each K by its chain latency; we adjust by the GPipe
    # bubble factor (M+K-1)/M — a beyond-paper throughput correction — and
    # pick the argmin.
    B = TRAIN_4K.global_batch
    ks = [int(sys.argv[3])] if len(sys.argv) > 3 else [4, 8]
    best = None
    for K in ks:
        M = max(1, B * K // 512)
        plan_k = plan_pipeline(cfg, seq_len=TRAIN_4K.seq_len,
                               microbatch=512 // K, candidate_K=(K,))
        eff = plan_k.predicted_latency_s * (M + K - 1) / M
        print(f"K={K}: chain={plan_k.predicted_latency_s*1e3:.1f}ms "
              f"bubble-adj={eff*1e3:.1f}ms segments={plan_k.segments}")
        if best is None or eff < best[0]:
            best = (eff, plan_k, M)
    _, plan, n_micro = best
    # Homogeneous stage groups + a uniform residual delta make the chain
    # objective flat across contiguous partitions: the DP's first-found tie
    # (e.g. [(1,13),(14,14),...]) is latency-equivalent to the balanced split
    # but inflates Gmax padding ~5x.  Rebalance to the even split.
    from ..core import even_split

    plan.segments = even_split(plan.n_groups, plan.K)
    n_data = 512 // plan.K
    mesh = make_pipeline_mesh(plan.K, n_data)
    opt = make_optimizer(cfg.optimizer)
    step = make_pipeline_train_step(cfg, mesh, plan, n_micro, opt)

    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    param_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    R = cfg.n_layers // len(cfg.pattern)

    def shard_of(leaf):
        """Stacked block params: layer dim over 'stage' when divisible (the
        planner's segments are contiguous so the restack gather is
        near-local) + next divisible dim over 'data' (ZeRO).  Otherwise ZeRO
        over the first 'data'-divisible dim — full replication of fp32 Adam
        state measured at 2.1 TB/device on gemma2 without this."""
        shape = list(leaf.shape)
        spec = [None] * len(shape)
        start = 0
        if shape and shape[0] == R and R % plan.K == 0:
            spec[0] = "stage"
            start = 1
        for i in range(start, len(shape)):
            if shape[i] % n_data == 0 and shape[i] >= n_data:
                spec[i] = "data"
                break
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, P(*spec)))

    params = jax.tree.map(shard_of, param_shapes)
    opt_state = jax.tree.map(shard_of, jax.eval_shape(opt.init, params))
    bs = NamedSharding(mesh, P("data"))
    batch = {
        "tokens": jax.ShapeDtypeStruct((TRAIN_4K.global_batch, TRAIN_4K.seq_len),
                                       jnp.int32, sharding=bs),
        "targets": jax.ShapeDtypeStruct((TRAIN_4K.global_batch, TRAIN_4K.seq_len),
                                        jnp.int32, sharding=bs),
    }
    t0 = time.perf_counter()
    lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt_state, batch)
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    print("memory_analysis:", mem)
    mc = analyze_hlo(compiled.as_text(), mesh.size)
    n_active = active_params(cfg)
    model_flops = 6.0 * n_active * TRAIN_4K.global_batch * TRAIN_4K.seq_len
    rf = Roofline(arch=arch, shape="train_4k", mesh=f"pp{plan.K}x{n_data}",
                  chips=mesh.size, flops_per_device=mc.flops,
                  hbm_bytes_per_device=mc.bytes,
                  coll_bytes_per_device=mc.total_coll_bytes,
                  model_flops_global=model_flops)
    per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
               + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "status": "ok", "arch": arch, "shape": "train_4k",
        "mesh": f"pp{plan.K}x{n_data}", "t_compile_s": t_compile,
        "plan": {"K": plan.K, "segments": plan.segments,
                 "placement": plan.placement,
                 "predicted_latency_s": plan.predicted_latency_s,
                 "breakdown": plan.breakdown},
        "memory": {"per_device_bytes": per_dev,
                   "fits_16gb": bool(per_dev <= 16 * 1024**3)},
        "collectives": {"bytes_per_device": mc.coll_bytes,
                        "counts": mc.coll_counts},
        "roofline": rf.to_dict(),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result["roofline"], indent=2))


if __name__ == "__main__":
    main()
