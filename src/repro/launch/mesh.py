"""Production meshes.  Defined as functions (never module-level constants) so
importing this module does not touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 v5e chips as ('data','model') = (16,16).
    Multi-pod: 2 pods x 256 chips as ('pod','data','model') = (2,16,16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


# v5e hardware constants (roofline denominators; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
