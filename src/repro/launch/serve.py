"""Serving launcher: --arch <id>, batched generation over synthetic prompts.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 8
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs import get_config
    from ..models import transformer as T
    from ..serving import ServingEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 32)))
               .astype(np.int32) for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"{args.requests} requests -> {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
