"""Sharding assembly for the dry-run and launchers: parameter, optimizer-state,
batch, and cache shardings derived from the logical rules in models/sharding.py.
Everything operates on ShapeDtypeStructs (eval_shape) — no allocation."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.sharding import MeshRules, param_shardings


def replicated(rules: MeshRules) -> NamedSharding:
    return NamedSharding(rules.mesh, P())


def batch_sharding(rules: MeshRules, ndim: int, global_batch: int) -> NamedSharding:
    """Shard dim0 (batch) over the DP axes (prefix fallback when the batch
    does not divide the full DP group, e.g. decode's 128 over 256 chips)."""
    spec = rules.resolve(("batch",) + (None,) * (ndim - 1),
                         (global_batch,) + (1,) * (ndim - 1))
    return NamedSharding(rules.mesh, spec)


def opt_state_shardings(opt_state_shapes, params_shapes, rules: MeshRules):
    """AdamW m/v mirror the param shardings; Adafactor vr/vc drop the reduced
    dim from the param spec; scalars replicate."""
    pshard = param_shardings(params_shapes, rules)

    def like_params(sub):
        return jax.tree.map(lambda p, s: s, sub, pshard)

    out = {}
    for key, sub in opt_state_shapes.items():
        if key in ("m", "v"):
            out[key] = like_params(sub)
        elif key == "f":
            def factored(param_sh, fsub):
                spec = list(param_sh.spec) if param_sh.spec else []
                def pad(spec_, nd):
                    spec_ = list(spec_)[-nd:] if nd else []
                    return [None] * (nd - len(spec_)) + spec_
                res = {}
                for name, leaf in fsub.items():
                    nd = len(leaf.shape)
                    if name == "vr":  # param shape minus last dim
                        res[name] = NamedSharding(rules.mesh, P(*pad(spec[:-1], nd)))
                    elif name == "vc":  # minus second-to-last dim
                        res[name] = NamedSharding(
                            rules.mesh, P(*pad(spec[:-2] + spec[-1:], nd)))
                    else:  # "v": same as param
                        res[name] = NamedSharding(rules.mesh,
                                                  P(*pad(spec, nd)))
                return res

            out[key] = jax.tree.map(
                factored, pshard, sub,
                is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
        else:  # step counters etc.
            out[key] = jax.tree.map(lambda _: replicated(rules), sub)
    return out


def cache_shardings(cache_shapes, rules: MeshRules, global_batch: int):
    """Heuristic per-leaf cache sharding: the first dim equal to the global
    batch -> DP axes (prefix fallback — an unsharded 32k KV cache is 100+ GB
    per device on the 100-layer archs); the last trailing dim divisible by the
    TP size (and not already consumed by the batch axes) -> TP."""

    def leaf(s):
        logical = [None] * len(s.shape)
        for i, d in enumerate(s.shape):
            if d == global_batch and global_batch > 1:
                logical[i] = "batch"
                break
        for i in range(len(s.shape) - 1, -1, -1):
            if logical[i] is None and s.shape[i] >= rules.axes_size(rules.tp):
                logical[i] = "tp"
                break
        return NamedSharding(rules.mesh,
                             rules.resolve(tuple(logical), tuple(s.shape)))

    return jax.tree.map(leaf, cache_shapes)


def to_structs(shapes, shardings):
    """Attach shardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
