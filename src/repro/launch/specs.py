"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
device allocation) for every model input of every (arch x shape) cell, plus the
step-function builders the dry-run lowers."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as T
from ..models.sharding import MeshRules, param_shardings
from ..optim import make_optimizer
from ..serving.engine import decode_step, prefill
from ..train.steps import make_train_step
from . import shardings as SH


def params_structs(cfg: ModelConfig, rules: MeshRules):
    shapes = jax.eval_shape(partial(T.init_params, cfg=cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return SH.to_structs(shapes, param_shardings(shapes, rules))


def _batch_structs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules,
                   seq_len: int | None = None):
    B = shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    bs2 = SH.batch_sharding(rules, 2, B)
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bs2),
        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bs2),
    }
    if cfg.memory_len:
        out["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.memory_len, cfg.d_model), jnp.float32,
            sharding=SH.batch_sharding(rules, 3, B))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules) -> dict:
    """All arguments of the step function this cell lowers, as sharded
    ShapeDtypeStructs.  Returns {"fn": step_fn, "args": tuple, "out_shardings"}.
    """
    params = params_structs(cfg, rules)
    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_shapes = jax.eval_shape(opt.init, params)
        opt_structs = SH.to_structs(
            opt_shapes, SH.opt_state_shardings(opt_shapes, params, rules))
        batch = _batch_structs(cfg, shape, rules)
        step = make_train_step(cfg, opt)
        out_sh = (jax.tree.map(lambda s: s.sharding, params),
                  jax.tree.map(lambda s: s.sharding, opt_structs),
                  None)
        return {"fn": step, "args": (params, opt_structs, batch),
                "out_shardings": out_sh, "donate": (0, 1)}

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len

        def prefill_step(params, batch):
            return prefill(params, cfg, batch["tokens"], cache_len=S,
                           memory=batch.get("memory"))

        batch = _batch_structs(cfg, shape, rules)
        batch.pop("targets")
        cache_shapes = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S))
        cache_sh = SH.cache_shardings(cache_shapes, rules, B)
        extras = {}
        if cfg.memory_len:
            extras["enc_memory"] = SH.batch_sharding(rules, 3, B)
        logits_sh = SH.batch_sharding(rules, 3, B)
        out_sh = (logits_sh, {"stack": cache_sh, **extras})
        return {"fn": prefill_step, "args": (params, batch),
                "out_shardings": out_sh, "donate": ()}

    # decode: one new token against a cache of seq_len
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    cache_sh = SH.cache_shardings(cache_shapes, rules, B)
    cache = {"stack": SH.to_structs(cache_shapes, cache_sh)}
    out_cache_sh = {"stack": cache_sh}
    if cfg.memory_len:
        mem_sh = SH.batch_sharding(rules, 3, B)
        cache["enc_memory"] = jax.ShapeDtypeStruct(
            (B, cfg.memory_len, cfg.d_model), jnp.dtype(cfg.compute_dtype),
            sharding=mem_sh)
        out_cache_sh["enc_memory"] = mem_sh
    bs2 = SH.batch_sharding(rules, 2, B)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bs2)
    positions = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bs2)

    def serve_step(params, cache, tokens, positions):
        logits, new_cache = decode_step(params, cfg, cache, tokens, positions)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    out_sh = (SH.batch_sharding(rules, 1, B), out_cache_sh)
    return {"fn": serve_step, "args": (params, cache, tokens, positions),
            "out_shardings": out_sh, "donate": (1,)}
