"""Training launcher: --arch <id> on the local device mesh, with planner-driven
pipeline mode, checkpointing, elastic re-planning hooks, and the synthetic data
pipeline.  On this CPU container it trains reduced configs end-to-end; on a real
TPU slice the same entrypoint scales to the production meshes (mesh shape is
taken from the available device count).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50 \
      [--mode dp|msl-pp] [--reduced] [--ckpt-dir DIR] [--resume]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mode", choices=("dp", "msl-pp"), default="dp")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--n-micro", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..ckpt import CheckpointManager
    from ..configs import get_config
    from ..data import BatchSpec, Prefetcher, SyntheticLM
    from ..models import transformer as T
    from ..optim import make_optimizer
    from ..train import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg.optimizer, lr=args.lr, warmup=5, total=args.steps)
    opt_state = opt.init(params)

    if args.mode == "msl-pp":
        from ..msl import make_pipeline_mesh, make_pipeline_train_step
        from ..msl.planner import PipelinePlan

        n_dev = jax.device_count()
        K = 2 if n_dev >= 4 else 1
        if K < 2:
            raise SystemExit("msl-pp needs >= 4 devices "
                             "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        R = cfg.n_layers // len(cfg.pattern)
        plan = PipelinePlan(K=2, segments=[(1, R // 2), (R // 2 + 1, R)],
                            placement=["s0", "s1"], n_groups=R,
                            predicted_latency_s=0.0, breakdown={})
        mesh = make_pipeline_mesh(2, n_dev // 2)
        step_fn = jax.jit(make_pipeline_train_step(cfg, mesh, plan,
                                                   args.n_micro, opt))
    else:
        step_fn = jax.jit(make_train_step(cfg, opt))

    ckpt = CheckpointManager(args.ckpt_dir or f"/tmp/repro_{args.arch}_ckpt")
    start = 0
    if args.resume:
        s, state = ckpt.restore()
        if s is not None:
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            start = s + 1
            print(f"[resume] from step {s}")

    spec = BatchSpec(args.batch, args.seq, cfg.vocab_size,
                     memory_len=cfg.memory_len, d_model=cfg.d_model)
    prefetch = Prefetcher(SyntheticLM(spec, seed=0), start_step=start)
    t0 = time.time()
    try:
        for step in range(start, args.steps):
            _, host_batch = prefetch.next()
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                dt = (time.time() - t0) / max(1, step - start + 1)
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"{dt*1e3:.0f} ms/step")
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          blocking=False)
    finally:
        prefetch.close()
    ckpt.save(args.steps - 1, {"params": params, "opt": opt_state})
    print(f"done: {args.steps - start} steps; checkpoint at step "
          f"{ckpt.latest_step()}")


if __name__ == "__main__":
    main()
