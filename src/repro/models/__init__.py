from . import layers, sharding, transformer
from .layers import Ctx
from .transformer import forward, init_cache, init_params, logits_last

__all__ = ["layers", "transformer", "sharding", "Ctx", "forward",
           "init_params", "init_cache", "logits_last"]
