"""Pure-JAX building blocks for every assigned architecture.

Conventions:
  * params are plain dict pytrees; stored in cfg.param_dtype, cast to
    cfg.compute_dtype at use.
  * activations x: (B, S, D); positions: (B, S) int32.
  * attention is *blocked* over query chunks (lax.scan) so compiled memory stays
    bounded at 32k+ sequence lengths — this pure-jnp path is also the oracle for
    the Pallas flash-attention kernel.
  * every block returns (y, new_cache); cache=None outside decode/prefill.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding import active_rules, constrain, constrain_first

Params = dict
Cache = Any

# Attention-internal sharding (whole-spec fallbacks, consistent across the
# score chain so no dot forces a gather):
#   plan A (heads divide TP):  q/k/v/o head-sharded, scores head-sharded;
#   plan B (e.g. 40 heads x 16 TP): q/scores/o sharded on the query-chunk dim,
#   k/v replicated (batch-sharded only) — both dots stay local.
_KV_SPECS = [("batch", None, "tp", None), ("batch", None, None, None)]
_Q5_SPECS = [("batch", None, None, "tp", None),  # (B, nc, qc, H, hd): heads
             ("batch", None, "tp", None, None)]  # qc
_SCORE_SPECS = [("batch", "tp", None, None),  # (B, H, qc, S): heads
                ("batch", None, "tp", None)]  # qc
_O_SPECS = [("batch", None, "tp", None),  # (B, qc, H, hd): heads
            ("batch", "tp", None, None)]  # qc


@partial(jax.tree_util.register_dataclass,
         data_fields=("positions", "memory"),
         meta_fields=("mode", "cache_len", "causal"))
@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through blocks (a pytree: arrays are leaves,
    mode flags are static metadata — so Ctx can cross jit/checkpoint/shard_map
    boundaries)."""

    mode: str  # "train" | "prefill" | "decode"
    positions: jnp.ndarray  # (B, S) int32 absolute positions
    memory: jnp.ndarray | None = None  # (B, M, D) modality / encoder memory
    cache_len: int = 0  # allocated cache length (decode)
    causal: bool = True

    @property
    def decoding(self) -> bool:
        return self.mode == "decode"


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rmsnorm(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


# =============================================================== attention ====
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, max(1, cfg.n_kv_heads)
    ks = jax.random.split(key, 8)
    dt = pdt(cfg)
    p = {
        "wq": _dense_init(ks[0], (D, Hq * hd), dt),
        "wk": _dense_init(ks[1], (D, Hkv * hd), dt),
        "wv": _dense_init(ks[2], (D, Hkv * hd), dt),
        "wo": _dense_init(ks[3], (Hq * hd, D), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    if cross:
        p["xgate"] = jnp.zeros((), dt)  # llama-vision gated cross-attention
    return p


def _project_qkv(p, cfg: ModelConfig, xq, xkv, q_positions, kv_positions,
                 apply_rope: bool = True):
    B, Sq, D = xq.shape
    hd = cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, max(1, cfg.n_kv_heads)
    dt = cdt(cfg)

    def proj(x, w, b_name, H):
        y = x @ p[w].astype(dt)
        if b_name in p:
            y = y + p[b_name].astype(dt)
        return y.reshape(x.shape[0], x.shape[1], H, hd)

    q = proj(xq, "wq", "bq", Hq)
    k = proj(xkv, "wk", "bk", Hkv)
    v = proj(xkv, "wv", "bv", Hkv)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if apply_rope:
        q = rope(q, q_positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _expand_gqa(k, Hq):
    """Repeat kv heads to Hq so the head dim shards over TP even when
    Hkv < |tp| (the repeated tensor is head-sharded; replicating small-Hkv
    tensors instead blocks GSPMD and replicates the O(S^2) scores — §Perf)."""
    Hkv = k.shape[2]
    if Hkv == Hq:
        return k
    return jnp.repeat(k, Hq // Hkv, axis=2)


def _padded_heads(Hq: int, batch: int) -> int:
    """Pad the head count to the TP multiple when heads WILL be TP-sharded: 56
    arctic heads over 16 TP ranks otherwise fall back to REPLICATED k/v and
    scores (~16x attention memory; +14% padded-head FLOPs is the cheap side of
    that trade — §Perf hillclimb #2).  Whether heads shard depends on whether
    the batch consumed the TP axis for THIS tensor (fsdp strategy at full
    batch: yes; prefill/decode prefix-fallback batches: no) — so the decision
    resolves the actual spec instead of inspecting the rules statically."""
    rules = active_rules()
    if rules is None:
        return Hq
    tp = rules.axes_size(rules.tp)
    Hp = -(-Hq // tp) * tp
    spec = rules.resolve(("batch", None, "tp", None), (batch, 1, Hp, 1))
    return Hp if spec[2] is not None else Hq


def _pad_heads(x, Hp: int):
    H = x.shape[2]
    if H == Hp:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))


def blocked_attention(cfg: ModelConfig, q, k, v, q_positions, kv_positions,
                      causal=True, window=None):
    """Memory-bounded attention: scan over query chunks, full K/V per chunk.

    q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd).  GQA via kv-head repetition
    (head-sharded over TP).  Masking: causal (q_pos >= kv_pos), optional
    sliding window, and kv padding (kv_positions < 0 marks unwritten slots).
    """
    B, Sq, Hq, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    qc = min(cfg.q_chunk, Sq)
    n_chunks = -(-Sq // qc)
    pad = n_chunks * qc - Sq
    if pad:  # ragged tail: pad queries (their pos=-1 rows are discarded below)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=-1)
    Hp = _padded_heads(Hq, B)
    k = constrain_first(_pad_heads(_expand_gqa(k, Hq), Hp), _KV_SPECS)
    v = constrain_first(_pad_heads(_expand_gqa(v, Hq), Hp), _KV_SPECS)
    qs = constrain_first(
        _pad_heads(q, Hp).reshape(B, n_chunks, qc, Hp, hd), _Q5_SPECS)
    qpos = q_positions.reshape(B, n_chunks, qc)
    kv_valid = kv_positions >= 0  # (B, Sk)

    def one_chunk(carry, inp):
        qi, qp = inp  # (B, qc, Hq, hd), (B, qc)
        s = jnp.einsum("bqhe,bshe->bhqs", qi, k,
                       preferred_element_type=jnp.float32) * scale
        s = constrain_first(s, _SCORE_SPECS)
        if cfg.attn_softcap:
            s = softcap(s, cfg.attn_softcap)
        mask = kv_valid[:, None, None, :]
        if causal:
            mask = mask & (qp[:, None, :, None]
                           >= kv_positions[:, None, None, :])
        if window is not None:
            mask = mask & (qp[:, None, :, None]
                           - kv_positions[:, None, None, :] < window)
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhqs,bshe->bqhe", w, v)
        return carry, constrain_first(o, _O_SPECS)

    # checkpoint per chunk: otherwise the scan's backward linearization stacks
    # every chunk's (qc, Skv) score tile — an O(S^2) HBM buffer per layer that
    # dominated the memory roofline term (§Perf, hillclimb #1)
    one_chunk = jax.checkpoint(one_chunk,
                               policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(one_chunk, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qpos, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1)[:, :, :, :Hq]  # drop padded heads
    out = out.reshape(B, n_chunks * qc, Hq, hd)
    return out[:, :Sq]


def _decode_attention(cfg, q, k, v, q_positions, kv_positions, window=None):
    """Single-token decode: q (B, 1, Hq, hd) against the full cache.

    Decode keeps the GROUPED (Hkv, G) formulation: repeating KV heads here
    amplifies the step's dominant cost — streaming the KV cache from HBM — by
    Hq/Hkv (measured 0.1-0.5x regressions on the decode_32k cells when the
    train-path expansion was reused; §Perf)."""
    B, _, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qi = q.reshape(B, 1, Hkv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    mask = (kv_positions >= 0) & (kv_positions <= q_positions[:, :1])
    if window is not None:
        mask = mask & (q_positions[:, :1] - kv_positions < window)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v).reshape(B, 1, Hq, hd)


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype=None):
    hd, Hkv = cfg.resolved_head_dim, max(1, cfg.n_kv_heads)
    dtype = dtype or cdt(cfg)
    return {
        "k": jnp.zeros((batch, length, Hkv, hd), dtype),
        "v": jnp.zeros((batch, length, Hkv, hd), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),  # -1 = unwritten
    }


def _cache_write(cache, k_new, v_new, positions, ring_window=None):
    """Write new K/V at ring-buffer slots (position mod cache length)."""
    length = cache["k"].shape[1]
    slots = positions % length  # (B, S)
    bidx = jnp.arange(k_new.shape[0])[:, None]
    k = cache["k"].at[bidx, slots].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slots].set(v_new.astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, slots].set(positions)
    return {"k": k, "v": v, "pos": pos}


def attention_block(p, cfg: ModelConfig, x, ctx: Ctx, cache,
                    window=None, cross=False):
    """Self- or cross-attention sublayer (no residual/norm — caller wraps)."""
    if cross:
        dt = cdt(cfg)
        hd, Hq = cfg.resolved_head_dim, cfg.n_heads
        q = (x @ p["wq"].astype(dt))
        if "bq" in p:
            q = q + p["bq"].astype(dt)
        q = q.reshape(x.shape[0], x.shape[1], Hq, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if cache is not None and ctx.decoding:
            # cross K/V were projected once at prefill; recomputing them per
            # decode step cost ~100x the decoder's own FLOPs (§Perf)
            k, v = cache["k"], cache["v"]
            new_cache = cache
        else:
            mem = ctx.memory
            Hkv = max(1, cfg.n_kv_heads)
            k = (mem @ p["wk"].astype(dt)).reshape(mem.shape[0], -1, Hkv, hd)
            v = (mem @ p["wv"].astype(dt)).reshape(mem.shape[0], -1, Hkv, hd)
            if "bk" in p:
                k = k + p["bk"].astype(dt).reshape(Hkv, hd)
                v = v + p["bv"].astype(dt).reshape(Hkv, hd)
            if cfg.qk_norm:
                k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
            new_cache = ({"k": k.astype(dt), "v": v.astype(dt)}
                         if cache is not None else cache)
        M = k.shape[1]
        mpos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (k.shape[0], M))
        out = blocked_attention(cfg, q, k, v, ctx.positions, mpos, causal=False)
    elif cache is not None:
        q, k_new, v_new = _project_qkv(p, cfg, x, x, ctx.positions, ctx.positions)
        if ctx.decoding:
            new_cache = _cache_write(cache, k_new, v_new, ctx.positions)
            out = _decode_attention(cfg, q, new_cache["k"], new_cache["v"],
                                    ctx.positions, new_cache["pos"], window)
        else:
            # prefill (from an empty cache): attend over this call's K/V
            # directly; persist only the last `length` tokens (ring buffers
            # would otherwise see unordered duplicate-slot writes).
            W = cache["k"].shape[1]
            S = k_new.shape[1]
            tail = min(W, S)
            new_cache = _cache_write(cache, k_new[:, -tail:], v_new[:, -tail:],
                                     ctx.positions[:, -tail:])
            out = blocked_attention(cfg, q, k_new, v_new,
                                    ctx.positions, ctx.positions, True, window)
    else:  # training: no cache
        q, k, v = _project_qkv(p, cfg, x, x, ctx.positions, ctx.positions)
        out = blocked_attention(cfg, q, k, v, ctx.positions, ctx.positions,
                                ctx.causal, window)
        new_cache = None
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1) @ p["wo"].astype(cdt(cfg))
    if cross and "xgate" in p:
        out = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(out.dtype) * out
    return out, new_cache


# ====================================================================== MLP ====
def init_mlp(key, cfg: ModelConfig, d_ff=None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = pdt(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (D, F), dt),
            "w_up": _dense_init(ks[1], (D, F), dt),
            "w_down": _dense_init(ks[2], (F, D), dt),
        }
    return {  # plain gelu MLP (starcoder2 / whisper)
        "w_up": _dense_init(ks[0], (D, F), dt),
        "b_up": jnp.zeros((F,), dt),
        "w_down": _dense_init(ks[1], (F, D), dt),
        "b_down": jnp.zeros((D,), dt),
    }


def mlp(p, cfg: ModelConfig, x):
    dt = cdt(cfg)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_variant == "swiglu" else partial(
            jax.nn.gelu, approximate=True)
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt),
                    approximate=True)
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ====================================================================== MoE ====
def init_moe(key, cfg: ModelConfig) -> Params:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = pdt(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (D, E), jnp.float32),  # router in fp32
        "w_gate": _dense_init(ks[1], (E, D, F), dt),
        "w_up": _dense_init(ks[2], (E, D, F), dt),
        "w_down": _dense_init(ks[3], (E, F, D), dt),
    }


MOE_GROUP = 1024  # tokens per dispatch group (bounds the one-hot dispatch tensor)


def moe_ffn(p, cfg: ModelConfig, x):
    """GShard-style capacity-based top-k dispatch (EP-shardable einsums).

    Tokens are processed in groups of MOE_GROUP so the (T, E, C) dispatch
    one-hot stays O(T^2 k / E) *per group* instead of per batch.  Load-balance
    auxiliary loss is returned via `moe_ffn.aux` on the fly (summed by caller).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    g = min(MOE_GROUP, T)
    n_groups = max(1, T // g)
    toks = x.reshape(n_groups, g, D)
    C = max(1, int(g * k / E * cfg.capacity_factor))

    logits = jnp.einsum("gtd,de->gte", toks.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (G, g, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via cumulative counts across the k slots
    mask = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (G, g, k, E)
    pos_in_slot = jnp.cumsum(mask, axis=1) - mask  # tokens before me, same slot
    offset = jnp.cumsum(mask.sum(axis=1, keepdims=True), axis=2) - mask.sum(
        axis=1, keepdims=True)  # earlier slots' totals
    pos = pos_in_slot + offset  # (G, g, k, E)
    keep = (pos < C) & (mask > 0)
    # dispatch/combine tensors (G, g, E, C); accumulate per slot so the
    # (g, k, E, C) intermediate is never materialized
    disp = jnp.zeros((n_groups, g, E, C), cdt(cfg))
    comb = jnp.zeros((n_groups, g, E, C), jnp.float32)
    for j in range(k):
        oh = jax.nn.one_hot(pos[:, :, j], C, dtype=jnp.float32)  # (G, g, E, C)
        oh = oh * keep[:, :, j, :, None]
        disp = disp + oh.astype(cdt(cfg))
        comb = comb + oh * gate_vals[:, :, j][:, :, None, None]
    # EP layout: token groups on the DP axes, experts on 'model'; the
    # dispatch/combine einsums become the all-to-alls of expert parallelism
    disp = constrain(disp, ("batch", None, "expert", None))
    comb = constrain(comb, ("batch", None, "expert", None))
    expert_in = constrain(jnp.einsum("gtec,gtd->gecd", disp, toks),
                          ("batch", "expert", None, None))
    act = jax.nn.silu if cfg.mlp_variant != "gelu" else jax.nn.gelu
    dt = cdt(cfg)
    h = act(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(dt))
    h = constrain(h, ("batch", "expert", None, None))
    expert_out = constrain(jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt)),
                           ("batch", "expert", None, None))
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(dt), expert_out)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f = mask[:, :, 0, :].astype(jnp.float32).mean(axis=1)  # top-1 routing frac
    P = probs.mean(axis=1)
    aux = E * jnp.mean(jnp.sum(f * P, axis=-1))
    return out.reshape(B, S, D), aux


# =================================================================== RG-LRU ====
def init_rglru(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    W = cfg.rnn_width or D
    dt = pdt(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_x": _dense_init(ks[0], (D, W), dt),  # recurrent branch input
        "w_gate_branch": _dense_init(ks[1], (D, W), dt),  # gelu gate branch
        "conv_w": _dense_init(ks[2], (cfg.conv_width, W), dt, scale=0.3),
        "w_input_gate": _dense_init(ks[3], (W, W), dt),
        "w_rec_gate": _dense_init(ks[4], (W, W), dt),
        "lam": jnp.linspace(0.9, 0.999, W).astype(jnp.float32),  # Lambda init
        "w_out": _dense_init(ks[5], (W, D), dt),
    }


def _causal_depthwise_conv(x, w, state=None):
    """x: (B, S, W) causal depthwise conv, kernel (cw, W).

    state: (B, cw-1, W) trailing inputs from the previous call (decode).
    Returns (y, new_state)."""
    cw = w.shape[0]
    hist = state if state is not None else jnp.zeros(
        (x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(cw))
    return y, xp[:, -(cw - 1):]


def rglru_scan(a, bx):
    """h_t = a_t * h_{t-1} + bx_t via associative scan over S."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return bb


def rglru_block(p, cfg: ModelConfig, x, ctx: Ctx, cache):
    """Griffin recurrent block: (conv -> RG-LRU) ⊙ gelu-gate -> out proj."""
    dt = cdt(cfg)
    B, S, _ = x.shape
    u = x @ p["w_x"].astype(dt)  # (B, S, W)
    gate_branch = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt))
    conv_state = cache.get("conv") if cache else None
    u, new_conv = _causal_depthwise_conv(u, p["conv_w"].astype(dt), conv_state)

    i_gate = jax.nn.sigmoid(u @ p["w_input_gate"].astype(dt)).astype(jnp.float32)
    r_gate = jax.nn.sigmoid(u @ p["w_rec_gate"].astype(dt)).astype(jnp.float32)
    log_a = -8.0 * r_gate * jax.nn.softplus(p["lam"])  # RG-LRU gated decay
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i_gate * u.astype(jnp.float32))
    if ctx.decoding and cache is not None:
        h_prev = cache["h"]  # (B, 1, W) fp32
        h = a * h_prev + bx
        y32 = h
        new_cache = {"h": h, "conv": new_conv}
    else:
        if cache is not None and "h" in cache:  # prefill continuing from state
            bx = bx.at[:, 0].add(a[:, 0] * cache["h"][:, 0])
        y32 = rglru_scan(a, bx)
        new_cache = ({"h": y32[:, -1:], "conv": new_conv}
                     if cache is not None else None)
    y = (y32.astype(dt) * gate_branch) @ p["w_out"].astype(dt)
    return y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int):
    W = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, 1, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), cdt(cfg)),
    }


# ================================================================ Mamba-2 SSD ==
def init_ssd(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    H = Di // cfg.ssm_head_dim
    N = cfg.ssm_state
    dt = pdt(cfg)
    ks = jax.random.split(key, 4)
    conv_dim = Di + 2 * N
    return {
        # projects to [z (Di), x (Di), B (N), C (N), dt (H)]
        "w_in": _dense_init(ks[0], (D, 2 * Di + 2 * N + H), dt),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, conv_dim), dt, scale=0.3),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((Di,), dt),
        "w_out": _dense_init(ks[2], (Di, D), dt),
    }


def _ssd_chunked(xh, dtv, A, Bm, Cm, h0=None, chunk=256):
    """Chunked SSD scan (Mamba-2 state-space duality, arXiv:2405.21060 Alg. 1).

    xh: (B, S, H, P); dtv: (B, S, H) softplus'd; A: (H,) >0 decay rate;
    Bm, Cm: (B, S, N).  Returns (y (B,S,H,P), h_last (B,H,P,N)).  fp32 math.
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, "sequence must be divisible by ssm_chunk"
    xc = xh.reshape(Bsz, nc, Q, H, P)
    dtc = dtv.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A[None, None, None, :]  # (B, nc, Q, H): -log decay per step
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    # intra-chunk (diagonal blocks): causal "attention" with decay weights.
    # Mask the EXPONENT, not the exp: non-causal entries have positive
    # cum_q - cum_k that overflows exp in fp32, and 0 * d(inf) = NaN in the
    # backward pass (exposed by pipeline bubble ticks).
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # shared across heads
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,K,H)
    decay = jnp.exp(jnp.where(causal, delta, -jnp.inf))
    w = scores[..., None] * decay
    w = w * dtc[:, :, None, :, :]  # dt_k factor (B,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w, xc)

    # chunk states: h_c = sum_k exp(cum_end - cum_k) dt_k B_k x_k
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)  # (B, nc, Q, H)
    state_w = end_decay * dtc  # (B, nc, Q, H)
    chunk_states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", state_w, Bc, xc)

    # inter-chunk scan over nc
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, nc, H) total decay of chunk
    h_init = (h0 if h0 is not None
              else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(h, inp):
        cs, cd = inp  # (B,H,P,N), (B,H)
        h_new = h * cd[:, :, None, None] + cs
        return h_new, h

    (h_last, h_prevs) = jax.lax.scan(
        step, h_init, (jnp.moveaxis(chunk_states, 1, 0),
                       jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B, nc, H, P, N): state BEFORE chunk
    in_decay = jnp.exp(cum)  # decay from chunk start to position
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, in_decay, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_last


def ssd_block(p, cfg: ModelConfig, x, ctx: Ctx, cache):
    dt_ = cdt(cfg)
    B, S, D = x.shape
    Di = cfg.ssm_expand * D
    H = Di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    zxbcdt = x @ p["w_in"].astype(dt_)
    z, xs, Bm, Cm, dtv = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = cache.get("conv") if cache else None
    conv_out, new_conv = _causal_depthwise_conv(conv_in, p["conv_w"].astype(dt_),
                                                conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)
    xh = xs.reshape(B, S, H, P).astype(jnp.float32)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = jnp.exp(p["A_log"])  # (H,) positive rates
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    if ctx.decoding and cache is not None:
        h0 = cache["h"]  # (B, H, P, N)
        dA = jnp.exp(-dtv[:, 0] * A[None, :])  # (B, H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtv[:, 0], Bm32[:, 0], xh[:, 0])
        h = h0 * dA[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm32[:, 0], h)[:, None]
        new_cache = {"h": h, "conv": new_conv}
    else:
        h0 = cache["h"] if (cache is not None and "h" in cache) else None
        # NOTE: A enters negated inside `_ssd_chunked` via dA = dt*A with decay
        # exp(-(cum_t - cum_s)); we pass positive rates and negate there.
        y, h_last = _ssd_chunked(xh, dtv, -A, Bm32, Cm32, h0, cfg.ssm_chunk)
        new_cache = {"h": h_last, "conv": new_conv} if cache is not None else None
    y = y + p["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, Di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"].astype(dt_), new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int):
    Di = cfg.ssm_expand * cfg.d_model
    H = Di // cfg.ssm_head_dim
    conv_dim = Di + 2 * cfg.ssm_state
    return {
        "h": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), cdt(cfg)),
    }
