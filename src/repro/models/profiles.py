"""Bridge between the model substrate and the paper's planner: every arch
becomes a `ModelProfile` (per-layer rho^FW/BW, delta^FW/BW, r^mem/disk) the
splitting/placement/chaining optimizer can cut — the TPU-side analogue of the
paper's Table I.

FLOPs are analytic per *sample* (batch=1) at a given sequence length, matmuls
counted as 2*MACs; rho^BW = 2 * rho^FW (the paper's convention).  delta at every
cut is the residual-stream activation (S * d_model * 2 bytes bf16); the whisper
encoder->decoder cut additionally ships the encoder output (cross-attn memory).
r^mem covers parameters (param_dtype bytes) times `state_multiplier` (optimizer
states: 1 for inference, ~9 for fp32 AdamW over bf16 compute, ~2.1 adafactor).
"""
from __future__ import annotations


from ..configs.base import ModelConfig
from ..core.costmodel import LayerProfile, ModelProfile

BF16 = 2


def _param_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4


def state_multiplier(cfg: ModelConfig) -> float:
    """bytes of (params + grads + optimizer state) per param byte."""
    if cfg.optimizer == "adafactor":
        return 2.1  # w + g (+ tiny factored stats)
    # fp32 master + m + v + bf16 grads on fp32 params
    return 3.5


def _attn_flops(cfg: ModelConfig, S: int, S_kv: int | None = None,
                causal: bool = True, window: int | None = None) -> float:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, max(1, cfg.n_kv_heads)
    S_kv = S_kv if S_kv is not None else S
    proj = 2 * S * D * (Hq * hd + 2 * Hkv * hd) + 2 * S * Hq * hd * D
    eff_kv = min(S_kv, window) if window else S_kv
    pair = S * eff_kv * (0.5 if (causal and S > 1 and not window) else 1.0)
    attn = 2 * 2 * pair * Hq * hd  # scores + values
    return proj + attn


def _mlp_flops(cfg: ModelConfig, S: int, d_ff: int | None = None) -> float:
    F = d_ff if d_ff is not None else cfg.d_ff
    n_mats = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    return 2 * n_mats * S * cfg.d_model * F


def _attn_params(cfg: ModelConfig) -> int:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, max(1, cfg.n_kv_heads)
    return D * (Hq * hd + 2 * Hkv * hd) + Hq * hd * D


def _mlp_params(cfg: ModelConfig, d_ff: int | None = None) -> int:
    F = d_ff if d_ff is not None else cfg.d_ff
    n_mats = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    return n_mats * cfg.d_model * F


def _block_cost(cfg: ModelConfig, kind: str, S: int, mode: str,
                cache_len: int) -> tuple[float, int]:
    """(fw_flops per sample, params) of one block."""
    D = cfg.d_model
    S_kv = cache_len if mode == "decode" else None
    fl, pr = 0.0, 0
    if kind in ("attn", "local_attn", "moe", "moe_dense"):
        window = cfg.window if kind == "local_attn" else None
        fl += _attn_flops(cfg, S, S_kv, True, window)
        pr += _attn_params(cfg)
        if kind in ("moe", "moe_dense"):
            fl += 2 * S * D * cfg.n_experts  # router
            fl += cfg.moe_top_k * _mlp_flops(cfg, S, cfg.moe_d_ff)
            pr += D * cfg.n_experts + cfg.n_experts * 3 * D * cfg.moe_d_ff
            if kind == "moe_dense":
                fl += _mlp_flops(cfg, S)
                pr += _mlp_params(cfg)
        else:
            fl += _mlp_flops(cfg, S)
            pr += _mlp_params(cfg)
    elif kind == "xattn":
        M = cfg.memory_len
        fl += 2 * S * D * cfg.n_heads * cfg.resolved_head_dim * 2  # q, o proj
        fl += 2 * M * D * 2 * max(1, cfg.n_kv_heads) * cfg.resolved_head_dim
        fl += 2 * 2 * S * M * cfg.n_heads * cfg.resolved_head_dim
        fl += _mlp_flops(cfg, S)
        pr += _attn_params(cfg) + _mlp_params(cfg)
    elif kind == "dec_block":
        M = cfg.memory_len
        fl += _attn_flops(cfg, S, S_kv)
        fl += 2 * 2 * S * M * cfg.n_heads * cfg.resolved_head_dim
        fl += 2 * S * D * cfg.n_heads * cfg.resolved_head_dim * 2
        fl += _mlp_flops(cfg, S)
        pr += 2 * _attn_params(cfg) + _mlp_params(cfg)
    elif kind == "rglru":
        W = cfg.rnn_width or D
        fl += 2 * S * D * W * 2  # two input branches
        fl += 2 * S * W * W * 2  # input/recurrence gates
        fl += 2 * S * W * cfg.conv_width + 10 * S * W  # conv + scan
        fl += 2 * S * W * D  # out proj
        fl += _mlp_flops(cfg, S)
        pr += 2 * D * W + 2 * W * W + cfg.conv_width * W + W * D + _mlp_params(cfg)
    elif kind == "ssd":
        Di = cfg.ssm_expand * D
        N = cfg.ssm_state
        H = Di // cfg.ssm_head_dim
        Q = min(cfg.ssm_chunk, S)
        fl += 2 * S * D * (2 * Di + 2 * N + H)  # in proj
        fl += 2 * S * Q * N  # intra-chunk scores (head-shared)
        fl += 2 * 2 * S * Q * Di  # intra-chunk weighted values (+decay apply)
        fl += 2 * 2 * S * N * Di  # chunk states + inter-chunk outputs
        fl += 2 * S * Di * D  # out proj
        pr += D * (2 * Di + 2 * N + H) + cfg.conv_width * (Di + 2 * N) + Di * D + 3 * H + Di
    else:
        raise ValueError(kind)
    pr += 2 * D  # norms
    return fl, pr


def model_profile(cfg: ModelConfig, seq_len: int, mode: str = "train",
                  cache_len: int = 0, training_state: bool | None = None,
                  ) -> ModelProfile:
    """Planner view: L = 1 (embed) + n_layers (+ enc_layers) + 1 (head)."""
    pb = _param_bytes(cfg)
    mult = (state_multiplier(cfg)
            if (training_state if training_state is not None else mode == "train")
            else 1.0)
    D, V = cfg.d_model, cfg.vocab_size
    S = 1 if mode == "decode" else seq_len
    resid = S * D * BF16
    layers: list[LayerProfile] = []

    def add(name, fw, act_bytes, params):
        layers.append(LayerProfile(name, fw, 2.0 * fw, act_bytes, act_bytes,
                                   params * pb * mult, params * pb))

    add("embed", 2 * S * D, resid, V * D)
    if cfg.enc_layers:  # whisper encoder before the decoder chain
        M = cfg.memory_len
        for i in range(cfg.enc_layers):
            fl = _attn_flops(cfg, M, M, causal=False) + _mlp_flops(cfg, M)
            # every cut after an encoder layer ships the (B, M, D) memory plus
            # the raw decoder tokens' embeddings
            add(f"enc{i}", fl, M * D * BF16 + resid,
                _attn_params(cfg) + _mlp_params(cfg) + 2 * D)
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        fl, pr = _block_cost(cfg, kind, S, mode, cache_len)
        act = resid
        if cfg.enc_layers:  # decoder cuts also ship the cross-attn memory
            act += cfg.memory_len * D * BF16
        elif any(k in ("xattn",) for k in kinds[i + 1:]):
            act += cfg.memory_len * D * BF16  # vision memory still needed ahead
        add(f"{kind}{i}", fl, act, pr)
    head_params = 0 if cfg.tie_embeddings else D * V
    add("head", 2 * S * D * V, 0.0, head_params + D)
    return ModelProfile(cfg.name, layers)


def total_params(cfg: ModelConfig) -> int:
    prof = model_profile(cfg, seq_len=1, mode="decode", training_state=False)
    return int(sum(l.mem_bytes for l in prof.layers) / _param_bytes(cfg))


def active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE top-k counting) — for MODEL_FLOPS=6*N*D."""
    n = total_params(cfg)
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n -= cfg.n_layers * (cfg.n_experts - cfg.moe_top_k) * per_expert
    return int(n)
