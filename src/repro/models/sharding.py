"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Logical axes:
  batch   -> data-parallel mesh axes (('pod','data') multi-pod, ('data',) single)
  fsdp    -> weight/optimizer-state sharding axes (ZeRO-3 via GSPMD)
  tp      -> tensor-parallel axis ('model')
  seq     -> sequence-parallel axis for the residual stream between blocks
  expert  -> expert-parallel axis for MoE weights/activations

`constrain(x, logical_spec)` is a no-op unless a `MeshRules` context is active
(so model code runs unmodified on a bare CPU).  Dims that do not divide evenly
by their mesh axes fall back to replication (GSPMD would pad; we prefer explicit
replication for predictable memory analysis).
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch: tuple[str, ...] = ("data",)
    fsdp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)
    seq: tuple[str, ...] = ("model",)
    expert: tuple[str, ...] = ("model",)

    def axes_size(self, axes: tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    def resolve(self, logical: tuple, shape: tuple[int, ...] | None = None) -> P:
        """logical entries: None | 'batch' | 'fsdp' | 'tp' | 'seq' | 'expert'.

        'batch' degrades gracefully to axis-tuple prefixes (e.g. batch 128 on a
        ('data','model') = 256-way DP group shards over ('data',) = 16)."""
        parts = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            if name is None:
                parts.append(None)
                continue
            # a mesh axis may appear at most once per spec: under the fsdp
            # strategy 'batch' already consumes 'model', so tp/seq constraints
            # on the same tensor degrade to replication of that dim
            axes = tuple(a for a in getattr(self, name) if a not in used)
            if shape is not None:
                while axes and shape[i] % self.axes_size(axes) != 0:
                    axes = axes[:-1] if name == "batch" else ()
            if not axes:
                parts.append(None)
            else:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, logical: tuple, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(logical, shape))


_ACTIVE: contextvars.ContextVar[MeshRules | None] = contextvars.ContextVar(
    "mesh_rules", default=None)


@contextlib.contextmanager
def mesh_rules(rules: MeshRules | None):
    tok = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active_rules() -> MeshRules | None:
    return _ACTIVE.get()


def constrain(x, logical: tuple):
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = rules.resolve(logical, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def constrain_first(x, candidates: list[tuple]):
    """Apply the first candidate spec whose sharded dims ALL divide evenly
    (whole-spec fallback — per-dim fallback would silently replicate, e.g.
    40 heads over 16 TP ranks replicated the O(S^2) attention scores)."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    for logical in candidates:
        ok = True
        for i, name in enumerate(logical):
            if name is None:
                continue
            if x.shape[i] % rules.axes_size(getattr(rules, name)) != 0:
                ok = False
                break
        if ok:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(rules.mesh, rules.resolve(logical, x.shape)))
    return x


# ------------------------------------------------------------ parameter rules
# base logical spec per leaf name; applied to the *trailing* dims (stacked
# leading group dims get None).
_PARAM_RULES: dict[str, tuple] = {
    "embed": ("tp", "fsdp"),
    "head": ("fsdp", "tp"),
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "q_norm": (None,), "k_norm": (None,), "xgate": (),
    "router": ("fsdp", None),
    "b_up": ("tp",), "b_down": (None,),
    "w_x": ("fsdp", "tp"), "w_gate_branch": ("fsdp", "tp"),
    "conv_w": (None, None),
    "w_input_gate": ("tp", None), "w_rec_gate": ("tp", None),
    "lam": (None,), "w_out": ("tp", "fsdp"),
    "w_in": ("fsdp", "tp"),
    "A_log": (None,), "D_skip": (None,), "dt_bias": (None,), "norm": (None,),
    "ln1": (None,), "ln2": (None,), "ln3": (None,), "final_norm": (None,),
}


def _leaf_logical(name: str, ndim: int, is_moe: bool) -> tuple:
    if name in ("w_gate", "w_up"):
        base = ("expert", "fsdp", None) if is_moe else ("fsdp", "tp")
    elif name == "w_down":
        base = ("expert", None, "fsdp") if is_moe else ("tp", "fsdp")
    elif name in _PARAM_RULES:
        base = _PARAM_RULES[name]
    else:
        base = ()
    pad = ndim - len(base)
    return (None,) * max(0, pad) + tuple(base[-ndim:] if ndim < len(base) else base)


def param_logical_tree(params) -> object:
    """Pytree of logical specs mirroring `params` (works on ShapeDtypeStructs)."""

    def walk(path, leaf):
        names = [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]
        name = next((n for n in reversed(names) if n and not n.isdigit()), "")
        return _leaf_logical(name, len(leaf.shape), "moe" in names)

    return jax.tree_util.tree_map_with_path(walk, params)


def param_shardings(params, rules: MeshRules):
    logical = param_logical_tree(params)
    return jax.tree.map(
        lambda leaf, spec: rules.sharding(spec, tuple(leaf.shape)),
        params, logical,
    )


def make_rules(mesh: Mesh, strategy: str = "fsdp") -> MeshRules:
    """Rules for this repo's meshes ('data','model') / ('pod','data','model').

    fsdp (dense archs): activations batch-sharded over ('data','model') — 4096
    tokens/chip at train_4k instead of 65536 — weights ZeRO-3 2-D sharded and
    gathered per layer by GSPMD; 'pod' adds another ZeRO/DP dimension.

    2d (MoE archs): batch over DP axes only; TP + EP on 'model' (experts must
    stay sharded — gathering 13 B params/layer of arctic experts is a non-
    starter).  Sequence parallelism keeps the residual carries small."""
    names = mesh.axis_names
    multi = "pod" in names
    if strategy == "fsdp":
        if multi:
            # batch prefix-drops from the right: global_batch 256 on 512 chips
            # shards over ('pod','data') = 32 — no pod-replicated compute.
            # (The multi-pod cells whose batch is too small to cover the mesh
            # are exactly where msl-pp pipelines layers across pods instead.)
            return MeshRules(mesh, batch=("pod", "data", "model"),
                             fsdp=("pod", "data"), tp=("model",),
                             seq=("model",))
        return MeshRules(mesh, batch=("data", "model"), fsdp=("data",),
                         tp=("model",), seq=("model",))
    if multi:
        return MeshRules(mesh, batch=("pod", "data"), fsdp=("data",))
    return MeshRules(mesh, batch=("data",), fsdp=("data",))
