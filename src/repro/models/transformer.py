"""Model assembly: embedding + repeated block pattern (scan) + head.

The layer stack is organized as `full_reps` repetitions of `cfg.pattern`
executed under one `lax.scan` with params stacked over repetitions (keeps HLO
size O(pattern) instead of O(L)), plus an unrolled remainder.  Whisper-style
encoders are a second (non-causal) stack over the modality memory.

The same stack is exposed to the *planner* (repro.core) through
`costmodel_profile` in profiles.py — every architecture is a layer list the
paper's splitting/placement/chaining optimizer can cut.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .layers import Ctx
from .sharding import constrain

KINDS_WITH_KV = ("attn", "local_attn", "moe", "moe_dense", "dec_block")


# ------------------------------------------------------------------- params --
def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 6)
    dt = L.pdt(cfg)
    D = cfg.d_model
    p: dict = {"ln1": jnp.zeros((D,), dt)}
    if kind in ("attn", "local_attn"):
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = jnp.zeros((D,), dt)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind in ("moe", "moe_dense"):
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = jnp.zeros((D,), dt)
        p["moe"] = L.init_moe(ks[1], cfg)
        if kind == "moe_dense":
            p["mlp"] = L.init_mlp(ks[2], cfg)
    elif kind == "xattn":
        p["attn"] = L.init_attention(ks[0], cfg, cross=True)
        p["ln2"] = jnp.zeros((D,), dt)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "dec_block":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = jnp.zeros((D,), dt)
        p["xattn"] = L.init_attention(ks[1], cfg, cross=True)
        p["ln3"] = jnp.zeros((D,), dt)
        p["mlp"] = L.init_mlp(ks[2], cfg)
    elif kind == "rglru":
        p["rglru"] = L.init_rglru(ks[0], cfg)
        p["ln2"] = jnp.zeros((D,), dt)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "ssd":
        p["ssd"] = L.init_ssd(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def _sp_gather(h):
    """Sequence-parallel entry: gather the (normed) sublayer input."""
    return constrain(h, ("batch", None, None))


def _sp_scatter(h):
    """Sequence-parallel exit: reduce-scatter the sublayer output back to the
    sequence-sharded residual layout."""
    return constrain(h, ("batch", "seq", None))


def apply_block(p, cfg: ModelConfig, kind: str, x, ctx: Ctx, cache):
    """Pre-norm block; returns (x, new_cache, aux_loss).

    Sequence parallelism, Megatron-SP style: the residual stream (and thus the
    scan carry the backward pass saves per layer) stays sequence-sharded at all
    times; each sublayer gathers its *normed input* and reduce-scatters its
    output.  Constraining the residual itself at block entry instead makes the
    while-loop carry's fixed-point sharding replicated — full-sequence saved
    activations per layer (§Perf, hillclimb #1)."""

    def norm_in(scale_name: str):
        return _sp_gather(L.rmsnorm(x, p[scale_name], cfg.norm_eps))

    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn", "moe", "moe_dense"):
        window = cfg.window if kind == "local_attn" else None
        h, cache = L.attention_block(p["attn"], cfg, norm_in("ln1"),
                                     ctx, cache, window=window)
        x = x + _sp_scatter(h)
        hin = norm_in("ln2")
        if kind in ("moe", "moe_dense"):
            y, aux = L.moe_ffn(p["moe"], cfg, hin)
            if kind == "moe_dense":
                y = y + L.mlp(p["mlp"], cfg, hin)
        else:
            y = L.mlp(p["mlp"], cfg, hin)
        x = x + _sp_scatter(y)
    elif kind == "xattn":
        h, cache = L.attention_block(p["attn"], cfg, norm_in("ln1"),
                                     ctx, cache, cross=True)
        x = x + _sp_scatter(h)
        x = x + _sp_scatter(L.mlp(p["mlp"], cfg, norm_in("ln2")))
    elif kind == "dec_block":
        c_self = cache["self"] if cache else None
        c_cross = cache["cross"] if cache else None
        h, c_self = L.attention_block(p["attn"], cfg, norm_in("ln1"),
                                      ctx, c_self)
        x = x + _sp_scatter(h)
        h, c_cross = L.attention_block(p["xattn"], cfg, norm_in("ln2"),
                                       ctx, c_cross, cross=True)
        x = x + _sp_scatter(h)
        x = x + _sp_scatter(L.mlp(p["mlp"], cfg, norm_in("ln3")))
        cache = ({"self": c_self, "cross": c_cross} if cache is not None
                 else None)
    elif kind == "rglru":
        h, cache = L.rglru_block(p["rglru"], cfg, norm_in("ln1"), ctx, cache)
        x = x + _sp_scatter(h)
        x = x + _sp_scatter(L.mlp(p["mlp"], cfg, norm_in("ln2")))
    elif kind == "ssd":
        h, cache = L.ssd_block(p["ssd"], cfg, norm_in("ln1"), ctx, cache)
        x = x + _sp_scatter(h)
    else:
        raise ValueError(kind)
    x = constrain(x, ("batch", "seq", None))
    return x, cache, aux


def init_cross_cache(cfg: ModelConfig, batch: int):
    hd, Hkv = cfg.resolved_head_dim, max(1, cfg.n_kv_heads)
    return {
        "k": jnp.zeros((batch, cfg.memory_len, Hkv, hd), L.cdt(cfg)),
        "v": jnp.zeros((batch, cfg.memory_len, Hkv, hd), L.cdt(cfg)),
    }


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, length: int):
    if kind in ("attn", "moe", "moe_dense"):
        return L.init_kv_cache(cfg, batch, length)
    if kind == "dec_block":
        return {"self": L.init_kv_cache(cfg, batch, length),
                "cross": init_cross_cache(cfg, batch)}
    if kind == "local_attn":
        return L.init_kv_cache(cfg, batch, min(length, cfg.window or length))
    if kind == "rglru":
        return L.init_rglru_cache(cfg, batch)
    if kind == "ssd":
        return L.init_ssd_cache(cfg, batch)
    if kind == "xattn":
        return init_cross_cache(cfg, batch)  # cross K/V projected at prefill
    return {}


@dataclasses.dataclass(frozen=True)
class StackLayout:
    pattern: tuple[str, ...]
    full_reps: int
    remainder: tuple[str, ...]

    @staticmethod
    def of(n_layers: int, pattern: tuple[str, ...]) -> "StackLayout":
        plen = len(pattern)
        return StackLayout(pattern, n_layers // plen,
                           tuple(pattern[: n_layers % plen]))


def init_stack(key, cfg: ModelConfig, n_layers: int, pattern: tuple[str, ...]):
    lay = StackLayout.of(n_layers, pattern)
    ks = iter(jax.random.split(key, n_layers + 1))
    groups = []
    for kind in lay.pattern:
        stacked = [init_block(next(ks), cfg, kind) for _ in range(lay.full_reps)]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
                      if lay.full_reps else None)
    rem = [init_block(next(ks), cfg, kind) for kind in lay.remainder]
    return {"groups": groups, "rem": rem}


def init_stack_cache(cfg: ModelConfig, n_layers: int, pattern, batch, length):
    lay = StackLayout.of(n_layers, pattern)
    groups = []
    for kind in lay.pattern:
        cs = [init_block_cache(cfg, kind, batch, length)
              for _ in range(lay.full_reps)]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
                      if lay.full_reps else None)
    rem = [init_block_cache(cfg, kind, batch, length) for kind in lay.remainder]
    return {"groups": groups, "rem": rem}


def apply_stack(p, cfg: ModelConfig, n_layers: int, pattern, x, ctx: Ctx, cache):
    """Scan over pattern repetitions; unrolled remainder.  Returns
    (x, new_cache, aux_sum)."""
    lay = StackLayout.of(n_layers, pattern)
    aux_total = jnp.zeros((), jnp.float32)

    if lay.full_reps:
        # NOTE(§Perf, refuted hypothesis): nesting a per-block jax.checkpoint
        # inside the group body did NOT reduce peak memory (79 -> 77.9 GB on
        # llama-90b/train_4k) and cost +15% recompute FLOPs — the peak is held
        # by matmul-dtype-legalization copies, not multi-block liveness.
        def body(carry, xs):
            h, aux_acc = carry
            params_t, cache_t = xs
            new_caches = []
            for i, kind in enumerate(lay.pattern):
                h, c, aux = apply_block(params_t[i], cfg, kind, h, ctx,
                                        cache_t[i] if cache is not None else None)
                new_caches.append(c if c is not None else {})
            return (h, aux_acc + aux), tuple(new_caches)

        if cfg.remat and ctx.mode == "train":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        cache_groups = (tuple(cache["groups"]) if cache is not None
                        else tuple({} for _ in lay.pattern))
        (x, aux_total), new_groups = jax.lax.scan(
            body, (x, aux_total), (tuple(p["groups"]), cache_groups))
        new_groups = list(new_groups)
    else:
        new_groups = []

    new_rem = []
    for i, kind in enumerate(lay.remainder):
        x, c, aux = apply_block(p["rem"][i], cfg, kind, x, ctx,
                                cache["rem"][i] if cache is not None else None)
        aux_total = aux_total + aux
        new_rem.append(c if c is not None else {})
    new_cache = ({"groups": new_groups, "rem": new_rem}
                 if cache is not None else None)
    return x, new_cache, aux_total


# ---------------------------------------------------------------- full model --
def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    dt = L.pdt(cfg)
    V, D = cfg.vocab_size, cfg.d_model
    params = {
        "embed": (jax.random.normal(ks[0], (V, D)) * 0.02).astype(dt),
        "final_norm": jnp.zeros((D,), dt),
        "stack": init_stack(ks[1], cfg, cfg.n_layers, cfg.pattern),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(ks[2], (D, V)) / jnp.sqrt(D)).astype(dt)
    if cfg.enc_layers:
        params["encoder"] = init_stack(ks[3], cfg, cfg.enc_layers, ("attn",))
        params["enc_norm"] = jnp.zeros((D,), dt)
    return params


def embed_tokens(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(L.cdt(cfg))
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, L.cdt(cfg)))
    return x


def head_matrix(params, cfg: ModelConfig):
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


def encode_memory(params, cfg: ModelConfig, memory):
    """Whisper-style encoder over stub frame embeddings (non-causal attn)."""
    if not cfg.enc_layers:
        return memory.astype(L.cdt(cfg))  # vision stub: patch embeddings direct
    B, M, _ = memory.shape
    pos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (B, M))
    ctx = Ctx(mode="train", positions=pos, causal=False)
    x = memory.astype(L.cdt(cfg))
    x, _, _ = apply_stack(params["encoder"], cfg, cfg.enc_layers, ("attn",),
                          x, ctx, None)
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, ctx: Ctx, cache=None,
            memory=None):
    """tokens (B, S) -> (hidden (B, S, D), new_cache, aux)."""
    if memory is not None:
        ctx = dataclasses.replace(ctx, memory=encode_memory(params, cfg, memory))
    x = embed_tokens(params, cfg, tokens)
    x = constrain(x, ("batch", "seq", None))
    x, new_cache, aux = apply_stack(params["stack"], cfg, cfg.n_layers,
                                    cfg.pattern, x, ctx, cache)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux


def init_cache(cfg: ModelConfig, batch: int, length: int):
    return init_stack_cache(cfg, cfg.n_layers, cfg.pattern, batch, length)


def logits_last(params, cfg: ModelConfig, hidden):
    """Final-position logits (serving)."""
    W = head_matrix(params, cfg).astype(L.cdt(cfg))
    logits = hidden[:, -1:] @ W
    if cfg.final_softcap:
        logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits.astype(jnp.float32)
