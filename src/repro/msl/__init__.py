from .planner import PipelinePlan, group_profile, plan_pipeline
from .simulator import ChainSimulator, RoundTripResult
from .pipeline import (
    make_pipeline_mesh,
    make_pipeline_train_step,
    pipeline_forward,
    stack_for_pipeline,
)

__all__ = ["PipelinePlan", "plan_pipeline", "group_profile",
           "make_pipeline_mesh", "make_pipeline_train_step",
           "pipeline_forward", "stack_for_pipeline", "ChainSimulator",
           "RoundTripResult"]
