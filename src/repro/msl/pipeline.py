"""shard_map microbatch pipeline runtime executing a PipelinePlan.

The paper's service chain made SPMD: mesh ('stage', 'data'); stage k holds its
planner-assigned contiguous group range; smashed data (the residual stream)
moves stage k -> k+1 via `jax.lax.ppermute` — the TPU fabric plays the paper's
physical network, the ppermute schedule is the chaining.  GPipe-style schedule
with M microbatches: T = M + K - 1 ticks, fill/drain bubbles; XLA's async
collective-permute (start/done pairs) overlaps the tick-t transfer with tick-t
compute — compute/comm overlap the paper does not model (a beyond-paper
optimization, EXPERIMENTS.md §Perf).

Backward: plain jax.grad through the shard_map — AD reverses every ppermute,
yielding the paper's reverse-path gradient chaining for free.  Embedding and
the LM head run outside the pipeline region, sharded over 'data' (DESIGN.md).

Stages run one structurally identical program: every stage scans over
`Gmax = ceil(n_groups / K)` group slots; slots beyond the stage's planner
segment carry a False validity flag and pass the residual through unchanged.
"""
from __future__ import annotations

import inspect
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import layers as L
from ..models import transformer as T
from ..models.layers import Ctx
from ..train.steps import chunked_xent
from .planner import PipelinePlan

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

# The replication-check kwarg was renamed across JAX versions (0.4.x:
# `check_rep`, >= 0.6: `check_vma`); detect whichever this JAX accepts so the
# pipeline disables it on either line (and passes nothing if both are gone).
_CHECK_KWARGS = (
    {kw: False}
    for kw in ("check_vma", "check_rep")
    if kw in inspect.signature(shard_map).parameters
)
SHARD_MAP_CHECK_KWARGS: dict = next(_CHECK_KWARGS, {})


def make_pipeline_mesh(n_stages: int, n_data: int) -> Mesh:
    return jax.make_mesh((n_stages, n_data), ("stage", "data"))


# ------------------------------------------------------------ param restacking
def stack_for_pipeline(params: dict, cfg: ModelConfig, plan: PipelinePlan):
    """Model 'stack' params (R, ...) per pattern position -> (K, Gmax, ...)
    stage-major layout + validity mask (K, Gmax).  Differentiable (gather)."""
    K = plan.K
    Gmax = max(plan.groups_per_stage)
    R = plan.n_groups
    # index map: slot (k, g) -> source group index (clamped; invalid masked)
    idx = []
    for k, (lo, hi) in enumerate(plan.segments):
        row = [min(lo - 1 + g, R - 1) for g in range(Gmax)]
        idx.append(row)
    idx = jnp.asarray(idx, jnp.int32)  # (K, Gmax)

    def restack(leaf):
        return jnp.take(leaf, idx.reshape(-1), axis=0).reshape(
            (K, Gmax) + leaf.shape[1:])

    groups = tuple(jax.tree.map(restack, g) for g in params["stack"]["groups"])
    valid = jnp.asarray(
        [[g < n for g in range(Gmax)] for n in plan.groups_per_stage], bool)
    return groups, valid


# ------------------------------------------------------------ pipelined forward
def _stage_apply(stage_groups, valid, cfg: ModelConfig, x, ctx: Ctx):
    """Scan this stage's Gmax group slots over the residual stream."""

    def body(carry, xs):
        h, aux_acc = carry
        params_g, valid_g = xs
        h2 = h
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.pattern):
            h2, _, a = T.apply_block(params_g[i], cfg, kind, h2, ctx, None)
            aux = aux + a
        h = jnp.where(valid_g, h2, h)
        aux_acc = aux_acc + jnp.where(valid_g, aux, 0.0)
        return (h, aux_acc), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (stage_groups, valid))
    return h, aux


def pipelined_apply(groups_stacked, valid, h_mb, *, cfg: ModelConfig, K: int,
                    n_micro: int):
    """Runs INSIDE shard_map over ('stage', 'data').

    groups_stacked: per-pattern-position trees, leading (1, Gmax, ...) local
    (stage-sharded); h_mb: (M, mb_local, S, D) microbatched embeddings
    (replicated over 'stage').  Returns ((M, mb, S, D) outputs — valid on the
    LAST stage's shard — and the stage-local aux-loss sum)."""
    stage = jax.lax.axis_index("stage")
    M = n_micro
    n_ticks = M + K - 1
    mb, S = h_mb.shape[1], h_mb.shape[2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
    ctx = Ctx(mode="train", positions=positions)
    my_groups = tuple(jax.tree.map(lambda p: p[0], g) for g in groups_stacked)
    my_valid = valid[0]

    def tick(carry, t):
        received, outs, aux_acc = carry
        inject = h_mb[jnp.clip(t, 0, M - 1)]
        x_in = jnp.where(stage == 0, inject, received)
        # bubble skipping: stage i only has real work for ticks i <= t < i+M;
        # lax.cond (real XLA conditional — not vmapped into a select here)
        # skips the fill/drain garbage compute entirely
        active = (t >= stage) & (t - stage < M)
        y, aux = jax.lax.cond(
            active,
            lambda xi: _stage_apply(my_groups, my_valid, cfg, xi, ctx),
            lambda xi: (xi, jnp.zeros((), jnp.float32)),
            x_in)
        # the last stage collects microbatch t - (K - 1)
        oidx = jnp.clip(t - (K - 1), 0, M - 1)
        take = t >= K - 1
        outs = jax.lax.dynamic_update_index_in_dim(
            outs, jnp.where(take, y, outs[oidx]), oidx, 0)
        # ship smashed data along the chain (ring permute; the wrap-around
        # edge K-1 -> 0 is ignored by stage 0's inject select)
        nxt = jax.lax.ppermute(y, "stage",
                               [(i, (i + 1) % K) for i in range(K)])
        return (nxt, outs, aux_acc + aux), None

    (_, outs, aux), _ = jax.lax.scan(
        tick, (jnp.zeros_like(h_mb[0]), jnp.zeros_like(h_mb),
               jnp.zeros((), jnp.float32)),
        jnp.arange(n_ticks))
    return outs, aux[None]


def pipeline_forward(params, batch, cfg: ModelConfig, mesh: Mesh,
                     plan: PipelinePlan, n_micro: int):
    """Embed -> pipelined blocks -> final hidden states (B, S, D) + aux."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    assert B % n_micro == 0
    mb = B // n_micro
    x = T.embed_tokens(params, cfg, tokens)
    h_mb = x.reshape(n_micro, mb, S, -1)
    groups_stacked, valid = stack_for_pipeline(params, cfg, plan)
    fn = shard_map(
        partial(pipelined_apply, cfg=cfg, K=plan.K, n_micro=n_micro),
        mesh=mesh,
        in_specs=(tuple(jax.tree.map(lambda _: P("stage"), g)
                        for g in groups_stacked), P("stage"),
                  P(None, "data")),
        out_specs=(P("stage", "data"), P("stage")),
        **SHARD_MAP_CHECK_KWARGS,
    )
    outs, aux = fn(groups_stacked, valid, h_mb)
    # out dim0 is stage-major (K * M); the last stage's block holds the model
    # output microbatches
    h_last = outs[-n_micro:]
    hidden = h_last.reshape(B, S, -1)
    hidden = L.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
    # aux averaged over ticks (bubble ticks process pass-through garbage; the
    # valid-slot masking keeps their contribution bounded)
    return hidden, jnp.sum(aux) / (n_micro + plan.K - 1)


def make_pipeline_train_step(cfg: ModelConfig, mesh: Mesh, plan: PipelinePlan,
                             n_micro: int, opt):
    def loss_fn(params, batch):
        hidden, aux = pipeline_forward(params, batch, cfg, mesh, plan, n_micro)
        head_w = T.head_matrix(params, cfg).astype(hidden.dtype)
        nll = chunked_xent(hidden, head_w, batch["targets"], cfg)
        return nll + 0.01 * aux, nll

    def train_step(params, opt_state, batch):
        (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "nll": nll}

    return train_step
