import os

if "XLA_FLAGS" not in os.environ:  # 4 host devices for the (2,2) test mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

# Pipeline-vs-sequential equivalence check (run as a module so the device-count
# flag is set before jax initializes; tests invoke it via subprocess).
import sys


def main(arch: str = "qwen3-14b") -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import ARCHS
    from ..models import transformer as T
    from ..models.layers import Ctx
    from ..optim import make_optimizer
    from .planner import PipelinePlan
    from .pipeline import make_pipeline_mesh, make_pipeline_train_step, \
        pipeline_forward

    cfg = ARCHS[arch].reduced()
    assert cfg.n_layers % len(cfg.pattern) == 0
    R = cfg.n_layers // len(cfg.pattern)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, M = 4, 16, 2
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    mesh = make_pipeline_mesh(2, 2)
    # planner segments over R groups with K=2 (balanced by construction here;
    # the real planner path is exercised in tests/test_msl_planner.py)
    plan = PipelinePlan(K=2, segments=[(1, R // 2), (R // 2 + 1, R)],
                        placement=["p0g0", "p0g1"], n_groups=R,
                        predicted_latency_s=0.0, breakdown={})

    hidden_pp, aux = jax.jit(
        lambda p, b: pipeline_forward(p, b, cfg, mesh, plan, M))(params, batch)

    # sequential reference: same blocks, no pipeline
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    hidden_ref, _, _ = T.forward(params, cfg, batch["tokens"],
                                 Ctx(mode="train", positions=pos))
    err = float(jnp.max(jnp.abs(hidden_pp.astype(jnp.float32)
                                - hidden_ref.astype(jnp.float32))))
    print(f"pipeline-vs-sequential max_err={err:.6f}")
    assert err < 5e-2, err  # bf16 residual accumulation tolerance

    # one pipelined train step end-to-end (grads through ppermute)
    opt = make_optimizer(cfg.optimizer, total=10)
    step = jax.jit(make_pipeline_train_step(cfg, mesh, plan, M, opt))
    p2, s2, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    print(f"pipelined train step loss={loss:.4f}")
    assert np.isfinite(loss)
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0.0
    print("PIPELINE CHECK OK")


if __name__ == "__main__":
    main(*sys.argv[1:])
