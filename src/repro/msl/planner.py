"""MSL planner: the paper's splitting/placement/chaining optimizer applied to
TPU pipeline parallelism (DESIGN.md Sec. 2.2).

Pipeline units are pattern *groups* (one repetition of cfg.pattern) so every
stage runs a structurally identical program (SPMD).  The planner consumes the
group-level cost profile (rho/delta/r per group), a `tpu_pod_topology` graph
whose nodes are candidate stage groups, and returns the latency-minimizing
(K, segments, placement) via the paper's BCD (or the exact DP oracle).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ModelConfig
from ..core import (
    TR,
    LayerProfile,
    ModelProfile,
    ProblemInstance,
    ServiceChainRequest,
    solve,
    tpu_pod_topology,
)
from ..models.profiles import model_profile


def group_profile(cfg: ModelConfig, seq_len: int, mode: str = "train",
                  cache_len: int = 0) -> ModelProfile:
    """Merge per-block rows of model_profile into pattern-group rows
    (embed/head/encoder rows are excluded — they run outside the pipeline)."""
    prof = model_profile(cfg, seq_len, mode, cache_len)
    rows = prof.layers[1 + cfg.enc_layers : -1]  # block rows only
    plen = len(cfg.pattern)
    groups: list[LayerProfile] = []
    for i in range(0, len(rows), plen):
        chunk = rows[i : i + plen]
        groups.append(LayerProfile(
            name=f"group{i // plen}",
            flops_fw=sum(r.flops_fw for r in chunk),
            flops_bw=sum(r.flops_bw for r in chunk),
            act_bytes=chunk[-1].act_bytes,
            grad_bytes=chunk[-1].grad_bytes,
            mem_bytes=sum(r.mem_bytes for r in chunk),
            disk_bytes=sum(r.disk_bytes for r in chunk),
        ))
    return ModelProfile(cfg.name + "-groups", groups)


@dataclass
class PipelinePlan:
    K: int
    segments: list[tuple[int, int]]  # 1-indexed inclusive GROUP ranges
    placement: list[str]
    n_groups: int
    predicted_latency_s: float
    breakdown: dict

    @property
    def groups_per_stage(self) -> list[int]:
        return [hi - lo + 1 for lo, hi in self.segments]


def plan_pipeline(cfg: ModelConfig, *, seq_len: int, microbatch: int,
                  candidate_K: tuple[int, ...] = (2, 4, 8),
                  n_groups_mesh: int = 8, chips_per_group: int = 64,
                  mode: str = TR, solver: str = "bcd") -> PipelinePlan:
    """Choose K and the per-stage group ranges minimizing the paper objective
    on the pod-level topology.  `microbatch` plays the paper's batch-size b
    role (smashed data = microbatch x activation bytes)."""
    prof = group_profile(cfg, seq_len, "train" if mode == TR else "prefill")
    net = tpu_pod_topology(n_groups=n_groups_mesh,
                           chips_per_group=chips_per_group)
    nodes = sorted(net.nodes)
    best: PipelinePlan | None = None
    for K in candidate_K:
        if K > prof.L or K > len(nodes):
            continue
        cands = [[nodes[0]]] + [nodes[1:-1] or nodes for _ in range(K - 2)] \
            + [[nodes[-1]]]
        if K == 1:
            continue
        req = ServiceChainRequest(cfg.name, nodes[0], nodes[-1], microbatch,
                                  mode)
        res = solve(ProblemInstance(net, prof, req, K,
                                    tuple(tuple(c) for c in cands)),
                    solver=solver)
        if not res.feasible:
            continue
        plan = PipelinePlan(
            K=K, segments=res.plan.segments, placement=res.plan.placement,
            n_groups=prof.L, predicted_latency_s=res.latency_s,
            breakdown={
                "computation_s": res.latency.computation_s,
                "transmission_s": res.latency.transmission_s,
                "propagation_s": res.latency.propagation_s,
            })
        if best is None or plan.predicted_latency_s < best.predicted_latency_s:
            best = plan
    if best is None:
        raise ValueError(f"no feasible pipeline plan for {cfg.name}")
    return best
