"""Host-level MSL/MSI executor over a planner Plan: runs REAL sub-model JAX
computations per chain stage and charges the plan's network delays — the
end-to-end validation that the planner's latency decomposition (Eq. 16)
corresponds to an actual executable chain.

Each stage's sub-model is the contiguous group range the plan assigns; smashed
data is the actual residual-stream array handed from stage to stage (the
paper's Fig. 1 forward walk).  Measured compute times per node feed the
StepTimeCalibrator (ft/manager.py), closing the paper's OLS calibration loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import FW, BW, PlanEvaluator, ServiceChainRequest
from ..core.plan import Plan
from ..models import transformer as T
from ..models.layers import Ctx


@dataclass
class StageTrace:
    stage: int
    node: str
    groups: tuple[int, int]
    compute_s_measured: float
    compute_s_predicted: float
    transfer_s_charged: float
    smashed_bytes: float


@dataclass
class ChainResult:
    hidden: jnp.ndarray
    traces: list[StageTrace] = field(default_factory=list)

    @property
    def total_charged_s(self) -> float:
        return sum(t.compute_s_predicted + t.transfer_s_charged
                   for t in self.traces)

    @property
    def total_measured_compute_s(self) -> float:
        return sum(t.compute_s_measured for t in self.traces)


class ChainSimulator:
    """Executes a splitting/placement plan stage by stage on the local device,
    charging per-hop network delays from the plan's evaluator."""

    def __init__(self, cfg: ModelConfig, params, net, profile,
                 request: ServiceChainRequest):
        self.cfg = cfg
        self.params = params
        self.ev = PlanEvaluator(net, profile, request)
        self.request = request
        self._stage_fns: dict[tuple[int, int], object] = {}

    def _stage_fn(self, lo: int, hi: int):
        """jit'd executor for group range [lo, hi] (1-indexed inclusive)."""
        key = (lo, hi)
        if key not in self._stage_fns:
            cfg = self.cfg
            plen = len(cfg.pattern)

            def run(stack_params, x):
                B, S = x.shape[0], x.shape[1]
                pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
                ctx = Ctx(mode="prefill", positions=pos)
                h = x
                for g in range(lo - 1, hi):
                    for i, kind in enumerate(cfg.pattern):
                        p_g = jax.tree.map(lambda l: l[g],
                                           stack_params["groups"][i])
                        h, _, _ = T.apply_block(p_g, cfg, kind, h, ctx, None)
                return h

            self._stage_fns[key] = jax.jit(run)
        return self._stage_fns[key]

    def forward(self, tokens) -> ChainResult:
        """Walk the chain: embed at the source, per-stage blocks at each hop."""
        plan: Plan = self.plan
        x = T.embed_tokens(self.params, self.cfg, tokens)
        result = ChainResult(hidden=x)
        for k, ((lo, hi), node) in enumerate(zip(plan.segments, plan.placement)):
            fn = self._stage_fn(lo, hi)
            t0 = time.perf_counter()
            x = jax.block_until_ready(fn(self.params["stack"], x))
            measured = time.perf_counter() - t0
            predicted = self.ev.segment_comp_s(node, lo, hi)
            trans = prop = 0.0
            smashed = 0.0
            if k < plan.K - 1:
                trans, prop = self.ev.cut_transfer_s(plan.paths[k],
                                                     plan.segments[k][1])
                smashed = float(x.size * x.dtype.itemsize)
            result.traces.append(StageTrace(
                stage=k, node=node, groups=(lo, hi),
                compute_s_measured=measured, compute_s_predicted=predicted,
                transfer_s_charged=trans + prop, smashed_bytes=smashed))
        result.hidden = x
        return result

    def run_plan(self, plan: Plan, tokens) -> ChainResult:
        self.plan = plan
        return self.forward(tokens)
