"""Host-level MSL/MSI executor over a planner Plan: runs REAL sub-model JAX
computations per chain stage and charges the plan's network delays — the
end-to-end validation that the planner's latency decomposition (Eq. 16)
corresponds to an actual executable chain.

Each stage's sub-model is the contiguous group range the plan assigns; smashed
data is the actual residual-stream array handed from stage to stage (the
paper's Fig. 1 forward walk).  Measured compute times per node feed the
StepTimeCalibrator (ft/manager.py), closing the paper's OLS calibration loop.

Training chains get a full round trip (:meth:`ChainSimulator.round_trip`):
the forward walk captures per-stage VJP pullbacks, then a REAL backward wave
replays them in reverse chain order, handing the gradient cotangent back over
each subpath's backward channel (``delta^BW`` sizes, ``bw_bw``/``delay_bw``).
:meth:`ChainSimulator.executed_round_trip_s` replays the same per-resource
charged times through a discrete-event GPipe F-then-B microbatch schedule —
an independent reconstruction that validates ``trainpipe.evaluate_round_trip``
(docs/training.md) against an executed chain rather than against itself.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import FW, BW, PlanEvaluator, ServiceChainRequest
from ..core.network import transmission_time_s
from ..core.plan import Plan
from ..core.trainpipe import segment_comp_dir_s
from ..models import transformer as T
from ..models.layers import Ctx


@dataclass
class StageTrace:
    stage: int
    node: str
    groups: tuple[int, int]
    compute_s_measured: float
    compute_s_predicted: float
    transfer_s_charged: float
    smashed_bytes: float
    direction: str = FW


@dataclass
class ChainResult:
    hidden: jnp.ndarray
    traces: list[StageTrace] = field(default_factory=list)

    @property
    def total_charged_s(self) -> float:
        return sum(t.compute_s_predicted + t.transfer_s_charged
                   for t in self.traces)

    @property
    def total_measured_compute_s(self) -> float:
        return sum(t.compute_s_measured for t in self.traces)


@dataclass
class RoundTripResult:
    """Executed forward + backward chain walk: the forward traces in chain
    order followed by the backward traces in reverse chain order, plus the
    gradient handed back to the chain's source (the paper's reverse-path
    smashed flow)."""

    hidden: jnp.ndarray
    grad_in: jnp.ndarray
    traces: list[StageTrace] = field(default_factory=list)

    def charged_s(self, direction: str | None = None) -> float:
        """Sum of charged (predicted compute + transfer) time, optionally
        restricted to one direction — the executed chain's decomposition."""
        return sum(t.compute_s_predicted + t.transfer_s_charged
                   for t in self.traces
                   if direction is None or t.direction == direction)

    @property
    def total_measured_compute_s(self) -> float:
        return sum(t.compute_s_measured for t in self.traces)


class ChainSimulator:
    """Executes a splitting/placement plan stage by stage on the local device,
    charging per-hop network delays from the plan's evaluator."""

    def __init__(self, cfg: ModelConfig, params, net, profile,
                 request: ServiceChainRequest):
        self.cfg = cfg
        self.params = params
        self.ev = PlanEvaluator(net, profile, request)
        self.request = request
        self._stage_fns: dict[tuple[int, int], object] = {}

    def _stage_fn(self, lo: int, hi: int):
        """jit'd executor for group range [lo, hi] (1-indexed inclusive)."""
        key = (lo, hi)
        if key not in self._stage_fns:
            cfg = self.cfg
            plen = len(cfg.pattern)

            def run(stack_params, x):
                B, S = x.shape[0], x.shape[1]
                pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
                ctx = Ctx(mode="prefill", positions=pos)
                h = x
                for g in range(lo - 1, hi):
                    for i, kind in enumerate(cfg.pattern):
                        p_g = jax.tree.map(lambda l: l[g],
                                           stack_params["groups"][i])
                        h, _, _ = T.apply_block(p_g, cfg, kind, h, ctx, None)
                return h

            self._stage_fns[key] = jax.jit(run)
        return self._stage_fns[key]

    def forward(self, tokens) -> ChainResult:
        """Walk the chain: embed at the source, per-stage blocks at each hop."""
        plan: Plan = self.plan
        x = T.embed_tokens(self.params, self.cfg, tokens)
        result = ChainResult(hidden=x)
        for k, ((lo, hi), node) in enumerate(zip(plan.segments, plan.placement)):
            fn = self._stage_fn(lo, hi)
            t0 = time.perf_counter()
            x = jax.block_until_ready(fn(self.params["stack"], x))
            measured = time.perf_counter() - t0
            predicted = self.ev.segment_comp_s(node, lo, hi)
            trans = prop = 0.0
            smashed = 0.0
            if k < plan.K - 1:
                trans, prop = self.ev.cut_transfer_s(plan.paths[k],
                                                     plan.segments[k][1])
                smashed = float(x.size * x.dtype.itemsize)
            result.traces.append(StageTrace(
                stage=k, node=node, groups=(lo, hi),
                compute_s_measured=measured, compute_s_predicted=predicted,
                transfer_s_charged=trans + prop, smashed_bytes=smashed))
        result.hidden = x
        return result

    def run_plan(self, plan: Plan, tokens) -> ChainResult:
        self.plan = plan
        return self.forward(tokens)

    # ------------------------------------------------------------- round trip
    def _dir_transfer_s(self, path, cut_after: int,
                        direction: str) -> tuple[float, float]:
        """(transmission, propagation) of the cut's smashed data in ONE
        direction.  Backward gradients are charged on the same directed links'
        backward channels (the R^BW convention of Eq. 7 / serve residuals)."""
        nbytes = (self.ev.request.batch_size
                  * self.ev.profile.cut_bytes(cut_after, direction))
        trans = prop = 0.0
        for u, v in zip(path, path[1:]):
            link = self.ev.net.links[(u, v)]
            trans += transmission_time_s(nbytes, link.rate(direction))
            prop += link.delay(direction)
        return trans, prop

    def round_trip(self, plan: Plan, tokens) -> RoundTripResult:
        """Execute the full training round trip on the placed chain.

        The forward walk runs each stage under ``jax.vjp``, keeping the
        pullback; the backward wave then replays the pullbacks in reverse
        chain order, handing the REAL gradient cotangent stage k -> k-1 over
        subpath k-1's backward channel.  Each trace charges the single
        direction's predicted compute (``trainpipe.segment_comp_dir_s``) and
        transfer, so ``charged_s(FW) + charged_s(BW)`` is the executed
        chain's decomposition of the sequential round trip.
        """
        self.plan = plan
        x = T.embed_tokens(self.params, self.cfg, tokens)
        result = RoundTripResult(hidden=x, grad_in=jnp.zeros_like(x))
        pullbacks = []
        for k, ((lo, hi), node) in enumerate(zip(plan.segments,
                                                 plan.placement)):
            fn = self._stage_fn(lo, hi)
            t0 = time.perf_counter()
            x, pull = jax.vjp(lambda h: fn(self.params["stack"], h), x)
            x = jax.block_until_ready(x)
            measured = time.perf_counter() - t0
            pullbacks.append(pull)
            trans = prop = smashed = 0.0
            if k < plan.K - 1:
                trans, prop = self._dir_transfer_s(plan.paths[k],
                                                   plan.segments[k][1], FW)
                smashed = float(x.size * x.dtype.itemsize)
            result.traces.append(StageTrace(
                stage=k, node=node, groups=(lo, hi),
                compute_s_measured=measured,
                compute_s_predicted=segment_comp_dir_s(self.ev, node, lo, hi,
                                                       FW),
                transfer_s_charged=trans + prop, smashed_bytes=smashed,
                direction=FW))
        result.hidden = x
        g = jnp.ones_like(x)  # cotangent seed at the chain destination
        for k in range(plan.K - 1, -1, -1):
            (lo, hi), node = plan.segments[k], plan.placement[k]
            t0 = time.perf_counter()
            (g,) = pullbacks[k](g)
            g = jax.block_until_ready(g)
            measured = time.perf_counter() - t0
            trans = prop = smashed = 0.0
            if k > 0:  # gradient ships back over subpath k-1
                trans, prop = self._dir_transfer_s(plan.paths[k - 1],
                                                   plan.segments[k - 1][1], BW)
                smashed = float(g.size * g.dtype.itemsize)
            result.traces.append(StageTrace(
                stage=k, node=node, groups=(lo, hi),
                compute_s_measured=measured,
                compute_s_predicted=segment_comp_dir_s(self.ev, node, lo, hi,
                                                       BW),
                transfer_s_charged=trans + prop, smashed_bytes=smashed,
                direction=BW))
        result.grad_in = g
        return result

    def executed_round_trip_s(self, plan: Plan, n_microbatches: int) -> float:
        """Discrete-event GPipe F-then-B replay of the charged chain — see
        the module-level :func:`executed_round_trip_s` (needs only the plan
        evaluator, so tests can replay NSFNET plans without a jax model)."""
        return executed_round_trip_s(self.ev, plan, n_microbatches)


def executed_round_trip_s(ev, plan: Plan, n_microbatches: int) -> float:
    """Discrete-event GPipe F-then-B replay of the charged chain.

    Every pipeline resource (hosting node per direction, physical link
    channel per direction) serves microbatches FIFO at its full-batch
    time / M; propagation delays microbatches without occupying the
    resource; the backward phase releases only when the forward phase has
    fully drained (the F-then-B barrier of ``msl/pipeline.py``).  The
    makespan is an independently-computed executed latency that
    ``trainpipe.evaluate_round_trip``'s closed form must match (the
    classic flow-shop identity sum + (M-1)*bottleneck, per direction) —
    tests assert agreement to 1e-9 relative.
    """
    M = n_microbatches
    b = ev.request.batch_size

    res_fw: list[tuple[float, float]] = []  # (full-batch service, prop)
    for k, ((lo, hi), node) in enumerate(zip(plan.segments,
                                             plan.placement)):
        res_fw.append((segment_comp_dir_s(ev, node, lo, hi, FW), 0.0))
        if k < plan.K - 1:
            fw_bytes = b * ev.profile.cut_bytes(plan.segments[k][1], FW)
            for u, v in zip(plan.paths[k], plan.paths[k][1:]):
                link = ev.net.links[(u, v)]
                res_fw.append((transmission_time_s(fw_bytes, link.bw_fw),
                               link.delay_fw))
    res_bw: list[tuple[float, float]] = []
    for k in range(plan.K - 1, -1, -1):
        (lo, hi), node = plan.segments[k], plan.placement[k]
        res_bw.append((segment_comp_dir_s(ev, node, lo, hi, BW), 0.0))
        if k > 0:
            path = plan.paths[k - 1]
            bw_bytes = b * ev.profile.cut_bytes(plan.segments[k - 1][1],
                                                BW)
            for u, v in reversed(list(zip(path, path[1:]))):
                link = ev.net.links[(u, v)]
                res_bw.append((transmission_time_s(bw_bytes, link.bw_bw),
                               link.delay_bw))
    tail_prop = 0.0
    if plan.tail_path:  # psi_K = 0: forward propagation only
        _, tail_prop = ev.net.path_cost_breakdown(plan.tail_path, 0.0,
                                                  None)

    def phase(resources: list[tuple[float, float]], release: float) -> float:
        avail = [release] * len(resources)
        done = release
        for _ in range(M):
            t = release
            for i, (service, prop) in enumerate(resources):
                start = max(t, avail[i])
                avail[i] = start + service / M
                t = avail[i] + prop
            done = t
        return done

    barrier = phase(res_fw, 0.0)  # all M forwards drained at the last node
    return phase(res_bw, barrier) + tail_prop
