from .compress import (
    compress_with_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
    topk_densify,
    topk_sparsify,
)
from .optimizers import (
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "make_optimizer", "cosine_schedule",
    "clip_by_global_norm", "quantize_int8", "dequantize_int8",
    "compress_with_feedback", "init_error_feedback", "topk_sparsify",
    "topk_densify",
]
