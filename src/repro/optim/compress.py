"""Gradient compression for data-parallel synchronization.

Distributed-optimization utilities for the large-scale runtime:
  * int8 blockwise quantization with error feedback (EF-SGD style) — ~4x
    reduction of DP all-reduce bytes at negligible quality cost;
  * top-k sparsification with error feedback.

These are used by the explicit shard_map DP-sync path (`repro.msl.pipeline`)
where we control the collective; under plain GSPMD the backward all-reduce is
implicit and uncompressed (recorded as such in the roofline's collective term).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 2048


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization.  Returns (q, scales)."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(grad, error):
    """int8 compress `grad + error`; returns (q, scale, new_error)."""
    g = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale, grad.shape, jnp.float32)
    return q, scale, g - deq


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_sparsify(x: jnp.ndarray, frac: float = 0.01):
    """Keep the largest-|.| `frac` of entries; returns (values, indices)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values, idx, shape, dtype):
    n = 1
    for d in shape:
        n *= d
    out = jnp.zeros((n,), jnp.float32).at[idx].set(values)
    return out.reshape(shape).astype(dtype)
