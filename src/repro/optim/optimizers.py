"""Optimizers (pure JAX, pytree-based): AdamW and Adafactor.

AdamW keeps fp32 m/v (+ params may themselves be the fp32 masters).  Adafactor
keeps factored second moments (rows/cols) for >=2-D leaves — the only way 480B
params fit 16 GB/chip HBM alongside bf16 weights (see configs/arctic_480b.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple[Any, Any]]
    name: str


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _lr_scale=None):
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat, tree = jax.tree_util.tree_flatten(params)
        gflat = tree.flatten_up_to(grads)
        mflat = tree.flatten_up_to(state["m"])
        vflat = tree.flatten_up_to(state["v"])
        outs = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
        new_params = tree.unflatten([o[0] for o in outs])
        new_m = tree.unflatten([o[1] for o in outs])
        new_v = tree.unflatten([o[2] for o in outs])
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update, "adamw")


def adafactor(lr_fn, decay=0.99, eps=1e-30, clip_threshold=1.0) -> Optimizer:
    """Factored second moments: for an (..., R, C) leaf keep row/col means."""

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, _lr_scale=None):
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
                upd_ = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                upd_ = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (Shazeer & Stern): RMS(update) <= clip_threshold
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-12)
            upd_ = upd_ / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), new_s

        flat, tree = jax.tree_util.tree_flatten(params)
        gflat = tree.flatten_up_to(grads)
        sflat = tree.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        new_params = tree.unflatten([o[0] for o in outs])
        new_f = tree.unflatten([o[1] for o in outs])
        return new_params, {"f": new_f, "step": step}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000) -> Optimizer:
    sched = cosine_schedule(lr, warmup, total)
    if name == "adamw":
        return adamw(sched)
    if name == "adafactor":
        return adafactor(sched)
    raise ValueError(name)
