from .analysis import Roofline, collective_stats

__all__ = ["Roofline", "collective_stats"]
