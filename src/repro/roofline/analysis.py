"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute term    = HLO_FLOPs_global  / (chips * 197 TFLOP/s bf16)
  memory term     = HLO_bytes_global  / (chips * 819 GB/s HBM)
  collective term = collective_bytes_global / (chips * 50 GB/s ICI)

`cost_analysis()` of the SPMD-partitioned module is *per device*; global =
per-device x chips, so the terms above equal per-device work over per-chip
rates.  Collective bytes are parsed from the optimized HLO: per-op effective
per-device traffic (ring all-reduce 2(n-1)/n x shard bytes, all-gather (n-1)/n x
full bytes, reduce-scatter (n-1)/n x full bytes, all-to-all (n-1)/n, permute 1x).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%|ROOT\s+%?)?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    """Per-device effective bytes per collective type + op counts."""
    bytes_by = {k: 0.0 for k in
                ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute")}
    count_by = {k: 0 for k in bytes_by}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        out_type, op = m.group(1), m.group(2)
        out_bytes = _shape_bytes(out_type)
        n = max(2, _group_size(line, n_devices))
        ring = (n - 1) / n
        if op == "all-reduce":
            vol = 2.0 * ring * out_bytes  # reduce-scatter + all-gather phases
        elif op == "all-gather":
            vol = ring * out_bytes  # output is the gathered (full) buffer
        elif op == "reduce-scatter":
            vol = ring * out_bytes * n  # output is the shard
        elif op == "all-to-all":
            vol = ring * out_bytes
        else:  # collective-permute
            vol = out_bytes
        bytes_by[op] += vol
        count_by[op] += 1
    return {"bytes_per_device": bytes_by, "counts": count_by,
            "total_bytes_per_device": sum(bytes_by.values())}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_global: float
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/masking/dispatch waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak that useful model FLOPs would achieve if
        the step ran at the bound implied by the dominant term."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        achieved = self.model_flops_global / t_bound
        return achieved / (self.chips * self.peak_flops)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d
