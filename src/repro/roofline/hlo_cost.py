"""Trip-count-aware cost analysis over optimized (SPMD-partitioned) HLO text.

XLA's built-in ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
counts every ``while`` body ONCE — a jax.lax.scan over 48 layers is undercounted
48x, and collectives inside the loop are invisible to a flat text scan.  For the
roofline to mean anything, loop bodies must be multiplied by their trip counts.

This analyzer:
  * splits the module into computations;
  * reads scalar integer constants to recover `while` trip counts from the
    canonical jax scan condition ``compare(iv, constant(N)), direction=LT``;
  * counts FLOPs for ``dot``/``convolution`` (2 x prod(out) x contraction) and
    1/elt for elementwise math ops (transcendentals x1 — close enough at matmul
    scale);
  * approximates HBM bytes as (operand + output bytes) of top-level ops in
    *real* computations (entry / while bodies / branches); computations called
    from ``fusion`` ops contribute FLOPs only (their internals live in
    registers/VMEM);
  * accumulates per-collective effective per-device traffic (ring terms), also
    multiplied through loops.

All numbers are per device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .analysis import DTYPE_BYTES

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_CONST_RE = re.compile(r"%([\w.\-]+) = [su]\d+\[\] constant\((\d+)\)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s*"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "floor",
    "select", "compare", "and", "or", "xor", "clamp", "expm1", "log1p",
    "logistic", "cosine", "sine", "atan2", "remainder",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> tuple[float, float]:
    elems = bytes_ = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * DTYPE_BYTES.get(dt, 0)
    return elems, bytes_


@dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    # deferred sub-calls: (multiplier, computation name, is_fusion)
    calls: list = field(default_factory=list)
    # conditional branch groups: exactly one branch executes -> count the max
    cond_groups: list = field(default_factory=list)


@dataclass
class ModuleCost:
    flops: float
    bytes: float
    coll_bytes: dict
    coll_counts: dict
    unknown_trip_counts: int

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloCostModel:
    def __init__(self, hlo_text: str, n_devices: int):
        self.n_devices = n_devices
        self.constants: dict[str, int] = {
            m.group(1): int(m.group(2)) for m in _CONST_RE.finditer(hlo_text)}
        self.comps: dict[str, list[str]] = {}
        self.headers: dict[str, str] = {}
        self.entry: str | None = None
        cur, buf = None, []
        for line in hlo_text.splitlines():
            hdr = _COMP_HDR.match(line.strip())
            if hdr and line.rstrip().endswith("{"):
                cur, buf = hdr.group(1), []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                self.comps[cur] = buf
                self.headers[cur] = line
            elif cur is not None:
                if line.strip() == "}":
                    cur = None
                else:
                    buf.append(line)
        self.unknown_trips = 0
        self._raw: dict[str, CompStats] = {}
        self._memo: dict[tuple[str, bool], tuple] = {}

    # ------------------------------------------------------------------ parse
    def _trip_count(self, cond_name: str) -> int:
        """jax scan condition: compare(iv, bound) with the bound a scalar
        constant referenced somewhere in the cond computation (possibly as a
        fusion operand).  Take the max scalar constant seen — the loop bound is
        the largest one in the tiny cond computation."""
        best = -1
        for line in self.comps.get(cond_name, []):
            for name in _OPERAND_RE.findall(line):
                if name in self.constants:
                    n = self.constants[name]
                    if "direction=LE" in line:
                        n += 1
                    best = max(best, n)
        return best

    def _dot_flops(self, out_type: str, rest: str, line: str,
                   types: dict[str, str]) -> float:
        out_elems, _ = _shape_elems_bytes(out_type)
        # contraction size: product of lhs dims listed in lhs_contracting_dims
        mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        operands = _OPERAND_RE.findall(rest.split(")", 1)[0])
        lhs_type = types.get(operands[0]) if operands else None
        if not lhs_type or not mdims:
            return 2.0 * out_elems  # fallback (shouldn't happen)
        mshape = _SHAPE_RE.findall(lhs_type)
        if not mshape:
            return 2.0 * out_elems
        lhs_dims = [int(x) for x in mshape[0][1].split(",") if x]
        contract = 1
        for idx in mdims.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, out_type: str, line: str) -> float:
        out_elems, _ = _shape_elems_bytes(out_type)
        m = re.search(r"window=\{size=([\dx]+)", line)
        k = 1
        if m:
            for d in m.group(1).split("x"):
                k *= int(d)
        mshape = _SHAPE_RE.findall(line.split("convolution(")[-1])
        cin = mshape[0][1].split(",") if mshape else ["1"]
        feat = int(cin[-1]) if cin and cin[-1] else 1  # NHWC guess
        return 2.0 * out_elems * k * feat

    def _parse_header_params(self, name: str) -> dict[str, str]:
        hdr = self.headers.get(name, "")
        body = hdr[hdr.find("(") + 1:]
        types: dict[str, str] = {}
        for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|[\w\[\],{}]+)", body):
            types[pm.group(1)] = pm.group(2)
        return types

    def _raw_stats(self, name: str) -> CompStats:
        if name in self._raw:
            return self._raw[name]
        st = CompStats()
        types: dict[str, str] = self._parse_header_params(name)
        for line in self.comps.get(name, []):
            m = _OP_RE.match(line)
            if not m:
                continue
            oname, otype, op, rest = m.groups()
            types[oname] = otype
            _, obytes = _shape_elems_bytes(otype)
            oelems, _ = _shape_elems_bytes(otype)
            if op == "dot":
                st.flops += self._dot_flops(otype, rest, line, types)
                st.bytes += obytes + self._operand_bytes(rest, types)
            elif op == "convolution":
                st.flops += self._conv_flops(otype, line)
                st.bytes += obytes + self._operand_bytes(rest, types)
            elif op in ELEMENTWISE:
                st.flops += oelems
                st.bytes += obytes + self._operand_bytes(rest, types)
            elif op in ("fusion", "call"):
                c = _CALLS_RE.search(line) or re.search(r"to_apply=%?([\w.\-]+)",
                                                        line)
                if c:
                    st.calls.append((1.0, c.group(1), op == "fusion"))
                if 'dynamic_update_slice' in line or "dynamic-update-slice" in line:
                    # fused scan-accumulator update: aliased in place; charge
                    # the non-accumulator operands + the slice written
                    st.bytes += 2.0 * self._dus_slice_bytes(rest, types, obytes)
                else:
                    st.bytes += obytes + self._operand_bytes(rest, types)
            elif op == "while":
                b, c = _BODY_RE.search(line), _COND_RE.search(line)
                if b:
                    trips = self._trip_count(c.group(1)) if c else -1
                    if trips < 0:
                        self.unknown_trips += 1
                        trips = 1
                    st.calls.append((float(trips), b.group(1), False))
            elif op == "conditional":
                br = _BRANCHES_RE.search(line)
                if br:
                    st.cond_groups.append(_OPERAND_RE.findall(br.group(1)))
            elif any(op.startswith(cl) for cl in COLLECTIVES):
                base = next(cl for cl in COLLECTIVES if op.startswith(cl))
                n = self._group_size(line)
                ring = (n - 1) / n
                if base == "all-reduce":
                    vol = 2.0 * ring * obytes
                elif base == "all-gather":
                    vol = ring * obytes
                elif base == "reduce-scatter":
                    vol = ring * obytes * n
                elif base == "all-to-all":
                    vol = ring * obytes
                else:
                    vol = obytes
                st.coll_bytes[base] = st.coll_bytes.get(base, 0.0) + vol
                st.coll_counts[base] = st.coll_counts.get(base, 0) + 1
                st.bytes += obytes
            elif op == "dynamic-update-slice":
                # XLA aliases the accumulator in place: true traffic is the
                # updated slice (read+write), not the whole buffer.
                st.bytes += 2.0 * self._dus_slice_bytes(rest, types, obytes)
            elif op in ("copy", "transpose", "reshape", "broadcast", "reduce",
                        "dynamic-slice", "slice",
                        "concatenate", "gather", "scatter", "pad", "iota",
                        "convert", "bitcast-convert", "reverse", "sort",
                        "cumsum"):
                if op != "reshape":  # reshapes are free (layout-preserving)
                    st.bytes += obytes + self._operand_bytes(rest, types)
                if op == "reduce":
                    st.flops += self._operand_elems(rest, types)
        self._raw[name] = st
        return st

    def _dus_slice_bytes(self, rest: str, types: dict[str, str],
                         out_bytes: float) -> float:
        """Updated-slice bytes of a (possibly fused) dynamic-update-slice: the
        largest operand is the aliased accumulator; the update slice is the
        next-largest operand."""
        sizes = sorted((
            _shape_elems_bytes(types[nm])[1]
            for nm in _OPERAND_RE.findall(rest.split(")", 1)[0])
            if nm in types), reverse=True)
        if len(sizes) >= 2:
            return sizes[1]
        return out_bytes * 0.01  # degenerate: assume a tiny slice

    def _operand_bytes(self, rest: str, types: dict[str, str]) -> float:
        total = 0.0
        for nm in _OPERAND_RE.findall(rest.split(")", 1)[0]):
            if nm in types:
                total += _shape_elems_bytes(types[nm])[1]
        return total

    def _operand_elems(self, rest: str, types: dict[str, str]) -> float:
        total = 0.0
        for nm in _OPERAND_RE.findall(rest.split(")", 1)[0]):
            if nm in types:
                total += _shape_elems_bytes(types[nm])[0]
        return total

    def _group_size(self, line: str) -> int:
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return max(2, len(m.group(1).split(",")))
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return max(2, int(m.group(2)))
        return max(2, self.n_devices)

    # ----------------------------------------------------------------- total
    def _total(self, name: str, fusion_ctx: bool) -> tuple:
        key = (name, fusion_ctx)
        if key in self._memo:
            return self._memo[key]
        st = self._raw_stats(name)
        flops = st.flops
        bytes_ = 0.0 if fusion_ctx else st.bytes
        coll_b = dict(st.coll_bytes)
        coll_c = dict(st.coll_counts)
        for mult, sub, is_fusion in st.calls:
            if sub not in self.comps:
                continue
            f, b, cb, cc = self._total(sub, fusion_ctx or is_fusion)
            flops += mult * f
            bytes_ += mult * b
            for k, v in cb.items():
                coll_b[k] = coll_b.get(k, 0.0) + mult * v
            for k, v in cc.items():
                coll_c[k] = coll_c.get(k, 0) + mult * v
        for branches in st.cond_groups:  # one branch executes: take the max
            totals = [self._total(b, fusion_ctx) for b in branches
                      if b in self.comps]
            if not totals:
                continue
            best = max(totals, key=lambda t: t[0])
            flops += best[0]
            bytes_ += best[1]
            for k, v in best[2].items():
                coll_b[k] = coll_b.get(k, 0.0) + v
            for k, v in best[3].items():
                coll_c[k] = coll_c.get(k, 0) + v
        out = (flops, bytes_, coll_b, coll_c)
        self._memo[key] = out
        return out

    def analyze(self) -> ModuleCost:
        assert self.entry, "no ENTRY computation found"
        f, b, cb, cc = self._total(self.entry, False)
        return ModuleCost(flops=f, bytes=b, coll_bytes=cb, coll_counts=cc,
                          unknown_trip_counts=self.unknown_trips)

    # ------------------------------------------------------------- attribution
    def top_ops(self, n: int = 25) -> list[tuple[float, float, str]]:
        """(bytes, flops, description) of the costliest individual op lines,
        weighted by their loop trip multiplicity — the §Perf debugging view."""
        # compute each computation's total invocation multiplier
        mult: dict[str, float] = {self.entry: 1.0}
        order = [self.entry]
        seen = {self.entry}
        while order:
            name = order.pop()
            st = self._raw_stats(name)
            for m, sub, _ in st.calls:
                if sub in self.comps:
                    mult[sub] = mult.get(sub, 0.0) + m * mult.get(name, 1.0)
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
        rows = []
        for name, lines in self.comps.items():
            k = mult.get(name, 0.0)
            if k == 0.0:
                continue
            types = self._parse_header_params(name)
            for line in lines:
                m = _OP_RE.match(line)
                if not m:
                    continue
                oname, otype, op, rest = m.groups()
                types[oname] = otype
                _, ob = _shape_elems_bytes(otype)
                oe, _ = _shape_elems_bytes(otype)
                fl = by = 0.0
                if op == "dot":
                    fl = self._dot_flops(otype, rest, line, types)
                    by = ob + self._operand_bytes(rest, types)
                elif op in ELEMENTWISE or op in (
                        "copy", "transpose", "broadcast", "reduce",
                        "dynamic-slice", "dynamic-update-slice", "slice",
                        "concatenate", "gather", "scatter", "pad", "convert",
                        "fusion", "call"):
                    by = ob + self._operand_bytes(rest, types)
                elif any(op.startswith(cl) for cl in COLLECTIVES):
                    by = ob
                if by or fl:
                    meta = ""
                    mm = re.search(r'op_name="([^"]*)"', line)
                    if mm:
                        meta = mm.group(1)[-90:]
                    rows.append((k * by, k * fl,
                                 f"x{k:.0f} {op} {otype[:60]} {meta}"))
        rows.sort(reverse=True)
        return rows[:n]


def analyze_hlo(hlo_text: str, n_devices: int) -> ModuleCost:
    return HloCostModel(hlo_text, n_devices).analyze()
