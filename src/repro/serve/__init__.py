"""Concurrent multi-request serving planner (`repro.serve`).

The paper plans one service chain R = (s, d, b, mode) in isolation; this
package admits *fleets* of chains onto one `PhysicalNetwork` with
residual-capacity accounting (link bandwidth consumed by smashed-data flows,
node memory/disk by placed sub-models), pluggable admission policies, and
capacity-aware replanning against the residual network before a request is
declared blocked.  See docs/serve.md.

CLI:  ``PYTHONPATH=src python -m repro.serve --n-requests 16 --policy fcfs``
"""
from repro.core import SOLVERS  # legacy re-export; use repro.core.solve(...)

from .planner import ServedRequest, ServeOutcome, ServePlanner, replay_verify
from .policies import POLICIES, POLICY_NAMES
from .requests import ARRIVALS, BATCH_SPREAD, ServeRequest, generate_fleet
from .residual import PlanDemand, ResidualState, effective_rate_rps, plan_demand

__all__ = [
    "ARRIVALS", "BATCH_SPREAD", "POLICIES", "POLICY_NAMES", "SOLVERS",
    "PlanDemand", "ResidualState", "ServeOutcome", "ServePlanner",
    "ServeRequest", "ServedRequest", "effective_rate_rps", "generate_fleet",
    "plan_demand", "replay_verify",
]
