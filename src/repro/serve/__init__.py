"""Concurrent multi-request serving planner (`repro.serve`).

The paper plans one service chain R = (s, d, b, mode) in isolation; this
package admits *fleets* of chains onto one `PhysicalNetwork` with
residual-capacity accounting (link bandwidth consumed by smashed-data flows,
node memory/disk by placed sub-models), pluggable admission policies, and
capacity-aware replanning against the residual network before a request is
declared blocked.  See docs/serve.md.

`ServeSim` layers an event-driven *dynamic* admission process on top:
chains arrive, hold their reservation for ``duration_s``, and depart —
releasing their exact demand back to the fabric, with an optional retry
queue for capacity-blocked requests.  See docs/sim.md.

Substrate failures (`link_down`/`node_down`/`recover` events) take committed
chains down mid-flight; victims are detected through the ResidualState
reverse index and live-migrated onto the degraded fabric, with HA standby
preplanning and a migration cost model.  See docs/failures.md.

CLI:  ``PYTHONPATH=src python -m repro.serve --n-requests 16 --policy fcfs``
      ``PYTHONPATH=src python -m repro.serve --sim --hold-model exp \\
          --duration-s 4 --arrival poisson --retry --failure-rate 0.2``
"""
from repro.core import SOLVERS  # legacy re-export; use repro.core.solve(...)

from .admission import AdmissionCore, ServedRequest
from .failures import (FAILURE_KINDS, FailureEvent, MigrationCostModel,
                       generate_failures, migration_delta, standby_network)
from .gateway import (GatewayConfig, GatewayOutcome, GatewayStats,
                      ServeGateway)
from .plancache import PlanCache
from .planner import ServeOutcome, ServePlanner, replay_verify
from .policies import POLICIES, POLICY_NAMES
from .requests import (ARRIVALS, BATCH_SPREAD, HOLD_MODELS, ServeRequest,
                       generate_fleet)
from .residual import (PlanDemand, ResidualState, effective_rate_rps,
                       plan_demand, plan_footprint)
from .sim import (FailureOutcome, ServeSim, SimOutcome, replay_verify_sim,
                  replay_verify_sim_report)

__all__ = [
    "ARRIVALS", "BATCH_SPREAD", "FAILURE_KINDS", "HOLD_MODELS", "POLICIES",
    "POLICY_NAMES", "SOLVERS", "AdmissionCore", "FailureEvent",
    "FailureOutcome", "GatewayConfig", "GatewayOutcome", "GatewayStats",
    "MigrationCostModel", "PlanCache", "PlanDemand", "ResidualState",
    "ServeGateway", "ServeOutcome", "ServePlanner", "ServeRequest",
    "ServeSim", "ServedRequest", "SimOutcome", "effective_rate_rps",
    "generate_failures", "generate_fleet", "migration_delta", "plan_demand",
    "plan_footprint", "replay_verify", "replay_verify_sim",
    "replay_verify_sim_report", "standby_network",
]
