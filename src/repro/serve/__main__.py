"""CLI for the concurrent serving planner.

    PYTHONPATH=src python -m repro.serve --n-requests 16 --policy latency-greedy
    PYTHONPATH=src python -m repro.serve --topology random \
        --topology-kwargs '{"n_nodes": 30, "p": 0.2, "seed": 7}' \
        --source v1 --destination v30 --n-requests 32 --arrival poisson
    PYTHONPATH=src python -m repro.serve --gateway --arrival poisson \
        --batch-window-s 0.5 --hold-model exp --duration-s 4 --retry

Prints a per-request admission table plus the round summary (acceptance
ratio, latency percentiles); ``--json`` additionally writes the summary and
per-request records to a file.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import solver_names, solver_supports

from .failures import generate_failures
from .gateway import GatewayConfig, ServeGateway
from .planner import ServePlanner
from .policies import POLICY_NAMES
from .requests import ARRIVALS, HOLD_MODELS, generate_fleet
from .sim import ServeSim


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description="concurrent multi-request admission")
    ap.add_argument("--topology", default="nsfnet")
    ap.add_argument("--topology-kwargs", default=None,
                    help="JSON kwargs for the topology factory")
    ap.add_argument("--profile", default="resnet101")
    ap.add_argument("--profile-kwargs", default=None)
    ap.add_argument("--source", default="v4")
    ap.add_argument("--destination", default="v13")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=2,
                    help="base batch size (spread x1/x2/x4 across the fleet)")
    ap.add_argument("--mode", default="IF", choices=("IF", "TR"))
    ap.add_argument("--train-share", type=float, default=0.0,
                    help="fraction of the fleet drawn as TR training chains "
                         "(overrides --mode per request; a dedicated seeded "
                         "stream keeps arrivals identical to the all-IF twin)")
    ap.add_argument("--K", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival", default="batch", choices=ARRIVALS)
    ap.add_argument("--rate-rps", type=float, default=1.0,
                    help="sustained chain executions/s per request (bandwidth demand)")
    ap.add_argument("--schedule", default="seq", choices=("seq", "pipe"),
                    help="execution schedule: seq (paper) or pipe "
                         "(microbatched pipeline, docs/pipeline.md)")
    ap.add_argument("--n-microbatches", type=int, default=1,
                    help="pipeline depth M for --schedule pipe")
    ap.add_argument("--policy", default="fcfs", choices=POLICY_NAMES)
    ap.add_argument("--solver", default="bcd", choices=sorted(solver_names()))
    ap.add_argument("--no-replan", action="store_true",
                    help="disable capacity-aware replanning on rejection")
    ap.add_argument("--sim", action="store_true",
                    help="event-driven dynamic admission with chain "
                         "departures (docs/sim.md) instead of one static round")
    ap.add_argument("--hold-model", default="none", choices=HOLD_MODELS,
                    help="holding-time model for --sim fleets: none = hold "
                         "forever, fixed / exp = --duration-s holds")
    ap.add_argument("--duration-s", type=float, default=None,
                    help="holding time (fixed) or mean holding time (exp)")
    ap.add_argument("--retry", action="store_true",
                    help="--sim/--gateway: queue capacity-blocked requests "
                         "and retry them when a departure frees room")
    ap.add_argument("--gateway", action="store_true",
                    help="stream the fleet through the long-running "
                         "ServeGateway (batched ticks, warm plan cache, "
                         "docs/gateway.md) instead of one static round")
    ap.add_argument("--batch-window-s", type=float, default=0.0,
                    help="--gateway: group arrivals within this window into "
                         "one presolved admission tick")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="--gateway: bounded admission queue; submissions "
                         "beyond it are rejected with reason queue-full")
    ap.add_argument("--slo-latency-s", type=float, default=None,
                    help="--gateway: reject chains whose planned latency "
                         "exceeds this SLO (before committing capacity)")
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    help="--sim/--gateway: substrate failure events per "
                         "second (docs/failures.md); 0 = no failures")
    ap.add_argument("--failure-downtime-s", type=float, default=None,
                    help="mean downtime before a failed resource recovers "
                         "(default: resources stay down)")
    ap.add_argument("--ha", action="store_true",
                    help="--sim/--gateway: pre-plan a disjoint standby for "
                         "every chain, promoted on failure")
    ap.add_argument("--json", default=None, help="write summary + records here")
    args = ap.parse_args(argv)
    if args.sim and args.gateway:
        ap.error("--sim and --gateway are mutually exclusive")
    if args.hold_model != "none" and args.duration_s is None:
        ap.error(f"--hold-model {args.hold_model} requires --duration-s")
    if args.duration_s is not None and args.hold_model == "none":
        ap.error("--duration-s requires --hold-model fixed|exp "
                 "(it would be silently ignored otherwise)")
    if ((args.hold_model != "none" or args.duration_s is not None
         or args.retry) and not (args.sim or args.gateway)):
        ap.error("--hold-model/--duration-s/--retry only apply with "
                 "--sim or --gateway")
    if ((args.batch_window_s != 0.0 or args.max_queue is not None
         or args.slo_latency_s is not None) and not args.gateway):
        ap.error("--batch-window-s/--max-queue/--slo-latency-s only apply "
                 "with --gateway")
    if ((args.failure_rate != 0.0 or args.failure_downtime_s is not None
         or args.ha) and not (args.sim or args.gateway)):
        ap.error("--failure-rate/--failure-downtime-s/--ha only apply with "
                 "--sim or --gateway")
    if args.failure_rate < 0:
        ap.error("--failure-rate must be >= 0")
    if not 0.0 <= args.train_share <= 1.0:
        ap.error("--train-share must be in [0, 1]")
    # No batch_size: the fleet's batch spread means some requests may pipeline
    # deeper than the base batch clamps, so check the unclamped depth.
    ok, reason = solver_supports(args.solver, schedule=args.schedule,
                                 n_microbatches=args.n_microbatches)
    if not ok:
        ap.error(reason)

    from repro.sweep.spec import build_profile, build_topology

    topo_kwargs = json.loads(args.topology_kwargs) if args.topology_kwargs else {}
    prof_kwargs = json.loads(args.profile_kwargs) if args.profile_kwargs else {}
    net = build_topology(args.topology, topo_kwargs)
    profile = build_profile(args.profile, prof_kwargs)

    fleet = generate_fleet(
        net, args.n_requests, args.source, args.destination, args.batch_size,
        args.mode, args.K, seed=args.seed, arrival=args.arrival,
        rate_rps=args.rate_rps, model_id=args.profile,
        schedule=args.schedule, n_microbatches=args.n_microbatches,
        hold_model=args.hold_model,
        hold_time_s=(args.duration_s if args.duration_s is not None
                     else float("inf")),
        ha=args.ha, train_share=args.train_share)
    failures = None
    if args.failure_rate > 0:
        horizon = (max(r.arrival_s for r in fleet)
                   + (args.duration_s if args.duration_s is not None else 10.0))
        failures = generate_failures(
            net, rate_per_s=args.failure_rate, horizon_s=horizon,
            seed=args.seed, mean_downtime_s=args.failure_downtime_s,
            protect=(args.source, args.destination))
    if args.sim:
        sim = ServeSim(net, profile, solver=args.solver,
                       replan=not args.no_replan, retry=args.retry)
        outcome = sim.run(fleet, policy=args.policy, failures=failures)
    elif args.gateway:
        gw = ServeGateway(
            net, profile, solver=args.solver, replan=not args.no_replan,
            policy=args.policy,
            config=GatewayConfig(batch_window_s=args.batch_window_s,
                                 max_queue=args.max_queue,
                                 slo_latency_s=args.slo_latency_s,
                                 retry=args.retry))
        outcome = gw.run_stream(fleet, failures=failures)
    else:
        planner = ServePlanner(net, profile, solver=args.solver,
                               replan=not args.no_replan)
        outcome = planner.admit(fleet, policy=args.policy)

    dynamic = args.sim or args.gateway
    extra = f" {'admit':>8} {'depart':>8} {'retry':>5}" if dynamic else ""
    print(f"{'id':>4} {'arrive':>8} {'b':>4} {'mode':>4} "
          f"{'admitted':>8} {'replan':>6} {'latency_ms':>11}{extra}  placement")
    for s in outcome.served:
        r = s.request
        lat = "-" if s.latency_s is None else f"{s.latency_s * 1e3:.2f}"
        place = "->".join(s.plan.placement) if (s.accepted and s.plan) else s.reason
        if dynamic:
            adm = "-" if s.admit_s is None else f"{s.admit_s:.3f}"
            dep = "-" if s.depart_s is None else f"{s.depart_s:.3f}"
            extra = f" {adm:>8} {dep:>8} {s.n_retries:>5}"
        print(f"{r.request_id:>4} {r.arrival_s:>8.3f} {r.batch_size:>4} "
              f"{r.mode:>4} {str(s.accepted):>8} {str(s.replanned):>6} "
              f"{lat:>11}{extra}  {place}")
    summary = outcome.summary()
    pct = {k: (f"{v * 1e3:.2f}ms" if v is not None else "-")
           for k, v in summary.items() if k.startswith("latency_p")}
    print(f"# accepted {outcome.n_accepted}/{outcome.n_requests} "
          f"(ratio {outcome.acceptance_ratio:.2f}), "
          f"{outcome.n_replanned} replanned, "
          f"p50/p95/p99 {pct['latency_p50_s']}/{pct['latency_p95_s']}/"
          f"{pct['latency_p99_s']}, {summary['wall_time_s']:.2f}s",
          file=sys.stderr)
    if dynamic:
        kind = "gateway" if args.gateway else "sim"
        print(f"# {kind}: horizon {outcome.horizon_s:.3f}s, "
              f"{outcome.n_departed} departed, "
              f"peak {outcome.peak_concurrent} concurrent, "
              f"{outcome.n_retried} admitted via retry, "
              f"blocking {outcome.blocking_probability:.2f}", file=sys.stderr)
    if failures is not None:
        fs = outcome.failure_summary()
        p95 = fs["restore_p95_s"]
        p95s = "-" if p95 is None else f"{p95:.3f}s"
        print(f"# failures: {len(failures)} events, "
              f"{fs['n_failed']} chains hit, {fs['n_restored']} restored, "
              f"{fs['n_killed']} killed, restore p95 {p95s}, "
              f"moved {fs['moved_bytes'] / 1e6:.1f} MB", file=sys.stderr)
    if args.gateway:
        gs = outcome.gateway_stats
        pc = gs.get("plan_cache", {})
        pct = gs["tick_wall_pct"]
        print(f"# gateway: {gs['n_ticks']} ticks "
              f"(window {args.batch_window_s}s), "
              f"tick p50/p95 {(pct['p50'] or 0.0) * 1e3:.2f}/"
              f"{(pct['p95'] or 0.0) * 1e3:.2f}ms, "
              f"max queue depth {gs['max_queue_depth']}, "
              f"{outcome.n_queue_rejected} queue-full, "
              f"{outcome.n_slo_rejected} slo-rejected, "
              f"plan-cache hit rate {pc.get('hit_rate', 0.0):.2f}, "
              f"{gs['admissions_per_s'] or 0.0:.1f} admissions/s",
              file=sys.stderr)
    if args.json:
        doc = {"summary": summary,
               "served": [s.to_dict() for s in outcome.served]}
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)
    return 0 if outcome.n_accepted else 1


if __name__ == "__main__":
    sys.exit(main())
