"""AdmissionCore — the one admission engine under every serve driver.

Layer 1 of the serve stack (docs/gateway.md): the snapshot-fits →
residual-replan → commit/release/retry state machine that used to live twice
(inline in :meth:`ServePlanner.admit` and again in :meth:`ServeSim.run`) is
one object here, and the three drivers are thin loops over it:

* the **static round** (`ServePlanner.admit`) feeds the whole policy-ordered
  fleet through :meth:`AdmissionCore.try_admit` with no timestamps;
* the **simulator** (`ServeSim.run`) walks its event heap, calling
  :meth:`try_admit` on arrivals, :meth:`release` on departures, and
  :meth:`drain_pending` after the departures of an instant have all drained;
* the **gateway** (`ServeGateway`) does the same per tick, with the extra
  control-plane knobs (bounded queues, SLO rejection) layered on top.

The core owns the mutable admission state — the :class:`ResidualState`, the
decision records, the retry queue and per-request retry counts, the event
timeline, and the residual-network memo shared across consecutive *failed*
attempts (any commit/release invalidates it).  All policy decisions (ordering,
when to tick, when to give up) stay in the drivers; all capacity decisions
live here, so the three drivers cannot drift apart.

``slo_latency_s`` is the gateway's SLO gate: when set, an otherwise-admissible
plan whose contended latency exceeds the budget is rejected *before* commit
(reason ``"slo"``) — the fabric is never touched, so the residual memo stays
valid.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import Plan, SolveOutcome, solve_batch

from .failures import (FailureEvent, MigrationCostModel, migration_delta,
                       standby_network)
from .requests import ServeRequest
from .residual import ResidualState

INF = float("inf")


def _plan_dict(plan: Plan) -> dict:
    return {"segments": [list(s) for s in plan.segments],
            "placement": list(plan.placement),
            "paths": [list(p) for p in plan.paths],
            "tail_path": list(plan.tail_path)}


def _plan_from_dict(d: dict) -> Plan:
    return Plan(segments=[tuple(s) for s in d["segments"]],
                placement=list(d["placement"]),
                paths=[list(p) for p in d["paths"]],
                tail_path=list(d["tail_path"]))


@dataclass
class ServedRequest:
    """Admission outcome of one request (in admission/decision order)."""

    request: ServeRequest
    accepted: bool
    replanned: bool = False
    latency_s: float | None = None
    plan: Plan | None = None
    reason: str = ""  # "" | "no-plan" | "capacity" | "slo" | "queue-full"
    status: str | None = None  # SolveOutcome.status of the winning solve
    # Event-driven fields (ServeSim / ServeGateway); None for static rounds.
    admit_s: float | None = None  # admission timestamp (>= arrival on retry)
    depart_s: float | None = None  # admit_s + duration_s when finite
    n_retries: int = 0  # failed capacity attempts before the final decision
    # Failure/migration fields (docs/failures.md).  ``plan`` always holds the
    # *current* plan; each completed migration appends an audit entry (old
    # plan, cause, timestamps, moved bytes, disruption seconds) here.
    migrations: list = field(default_factory=list)
    # set while the chain is down (released by a failure, not yet restored);
    # a record that ends with failed_s != None was killed by the failure
    failed_s: float | None = None
    # pre-planned disjoint backup for HA chains (promoted on failure)
    standby: Plan | None = None

    def to_dict(self) -> dict:
        r = self.request
        d = {
            "request_id": r.request_id,
            "source": r.source,
            "destination": r.destination,
            "batch_size": r.batch_size,
            "mode": r.mode,
            "K": r.K,
            "candidates": [list(c) for c in r.candidates],
            "arrival_s": r.arrival_s,
            "rate_rps": r.rate_rps,
            "model_id": r.model_id,
            "schedule": r.schedule,
            "n_microbatches": r.n_microbatches,
            # inf round-trips as null so the artifacts stay strict JSON
            "duration_s": None if r.duration_s == INF else r.duration_s,
            "ha": r.ha,
            "accepted": self.accepted,
            "replanned": self.replanned,
            "latency_s": self.latency_s,
            "reason": self.reason,
            "status": self.status,
            "admit_s": self.admit_s,
            "depart_s": self.depart_s,
            "n_retries": self.n_retries,
        }
        if self.plan is not None:
            d["segments"] = [list(s) for s in self.plan.segments]
            d["placement"] = list(self.plan.placement)
            d["paths"] = [list(p) for p in self.plan.paths]
            d["tail_path"] = list(self.plan.tail_path)
        if self.migrations:
            d["migrations"] = [dict(m) for m in self.migrations]
        if self.failed_s is not None:
            d["failed_s"] = self.failed_s
        if self.standby is not None:
            d["standby"] = _plan_dict(self.standby)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServedRequest":
        duration = d.get("duration_s")
        req = ServeRequest(
            request_id=d["request_id"], source=d["source"],
            destination=d["destination"], batch_size=d["batch_size"],
            mode=d["mode"], K=d["K"],
            candidates=tuple(tuple(c) for c in d["candidates"]),
            arrival_s=d["arrival_s"], rate_rps=d["rate_rps"],
            model_id=d["model_id"], schedule=d.get("schedule", "seq"),
            n_microbatches=d.get("n_microbatches", 1),
            duration_s=INF if duration is None else duration,
            ha=d.get("ha", False))
        plan = None
        if "segments" in d:
            plan = _plan_from_dict(d)
        standby = d.get("standby")
        return cls(req, d["accepted"], d["replanned"], d["latency_s"], plan,
                   d.get("reason", ""), d.get("status"), d.get("admit_s"),
                   d.get("depart_s"), d.get("n_retries", 0),
                   migrations=[dict(m) for m in d.get("migrations", [])],
                   failed_s=d.get("failed_s"),
                   standby=_plan_from_dict(standby) if standby else None)


class AdmissionCore:
    """The shared admission state machine (see module docstring).

    ``presolved`` / ``keys`` are the planner's snapshot-solve maps; the
    gateway grows them incrementally (``presolved.update(...)``) as new
    shapes stream in.  ``record_events`` turns on the timeline audit log —
    events carry the timestamp the driver passes to each call, so the static
    round (no timestamps) leaves the timeline empty.
    """

    def __init__(self, planner, presolved: dict[str, SolveOutcome],
                 keys: dict[int, str], *, retry: bool = False,
                 slo_latency_s: float | None = None,
                 record_events: bool = False,
                 cost_model: MigrationCostModel | None = None):
        self.planner = planner
        self.presolved = presolved
        self.keys = keys
        self.retry = retry
        self.slo_latency_s = slo_latency_s
        self.record_events = record_events
        self.cost_model = (cost_model if cost_model is not None
                           else MigrationCostModel())

        self.state = ResidualState(planner.net)
        self.served: list[ServedRequest] = []
        self.timeline: list[dict] = []
        self.pending: list[ServeRequest] = []  # capacity-blocked, awaiting retry
        self.retries: dict[int, int] = {}
        self.concurrent = 0
        # request_id -> live accepted record: what a failure event's victim
        # ids (from the ResidualState reverse index) resolve to
        self.live: dict[int, ServedRequest] = {}
        # victims taken down by a failure, awaiting restoration (retry mode);
        # restoration is attempted on departures/recoveries, in park order
        self.fail_parked: list[ServedRequest] = []
        # request_id -> resource name of the failure that took it down (the
        # `cause` stamped on the migration entry if restored later)
        self._down_cause: dict[int, str] = {}
        # Residual-network memo for planner.attempt, shared across the
        # *failed* attempts between two state changes (the state is unchanged
        # between them); any commit or release invalidates it.
        self.res_memo: dict = {}

    def snapshot_for(self, r: ServeRequest) -> SolveOutcome:
        return self.presolved[self.keys[r.request_id]]

    def _event(self, event: str, request_id: int, t: float | None) -> None:
        if self.record_events and t is not None:
            self.timeline.append({"t": t, "event": event,
                                  "request_id": request_id,
                                  "concurrent": self.concurrent})

    def try_admit(self, r: ServeRequest,
                  t: float | None = None) -> ServedRequest | None:
        """One admission attempt (at instant `t` when event-driven); commits
        on success and returns the accepted record — the driver schedules the
        departure from its ``depart_s``.  Returns None when the request was
        rejected-and-recorded or parked on the retry queue."""
        snapshot = self.snapshot_for(r)
        chosen, replanned, status, reason = self.planner.attempt(
            self.state, r, snapshot, res_net_cache=self.res_memo)
        if chosen is not None and self.slo_latency_s is not None:
            latency = self.planner.planned_latency_s(self.state, r, chosen)
            if latency > self.slo_latency_s:
                # nothing was committed: the residual memo stays valid
                self.served.append(ServedRequest(
                    r, False, replanned=replanned, latency_s=latency,
                    plan=chosen, reason="slo", status=status,
                    n_retries=self.retries.get(r.request_id, 0)))
                self._event("reject", r.request_id, t)
                return None
        if chosen is None:
            if reason == "capacity" and self.retry:
                self.retries[r.request_id] = \
                    self.retries.get(r.request_id, 0) + 1
                if r not in self.pending:
                    self.pending.append(r)
            else:
                self.served.append(ServedRequest(
                    r, False, plan=snapshot.plan, reason=reason,
                    status=status, n_retries=self.retries.get(r.request_id, 0)))
                self._event("reject", r.request_id, t)
            return None
        latency = self.planner.commit_latency_s(self.state, r, chosen)
        self.res_memo.clear()  # the residual state just changed
        depart = None
        if t is not None and r.duration_s != INF:
            depart = t + r.duration_s
        rec = ServedRequest(
            r, True, replanned=replanned, latency_s=latency, plan=chosen,
            status=status, admit_s=t, depart_s=depart,
            n_retries=self.retries.get(r.request_id, 0))
        if r.ha:
            rec.standby = self._plan_standby(r, chosen)
        self.served.append(rec)
        self.live[r.request_id] = rec
        self.concurrent += 1
        self._event("admit", r.request_id, t)
        return rec

    def release(self, rec: ServedRequest, t: float | None = None) -> None:
        """A departing chain returns its exact demand to the fabric."""
        self.state.release(self.planner.profile, rec.request, rec.plan)
        self.res_memo.clear()  # the residual state just changed
        self.live.pop(rec.request.request_id, None)
        self.concurrent -= 1
        self._event("depart", rec.request.request_id, t)

    def depart(self, rec: ServedRequest, t: float | None = None) -> bool:
        """Departure-event entry point, failure-aware: a chain killed (or
        still parked) by a failure holds no reservation, so its scheduled
        departure only finalizes the record.  Returns whether a release
        actually happened."""
        if rec.request.request_id not in self.live or rec.failed_s is not None:
            # down when its service window ended: stays killed
            try:
                self.fail_parked.remove(rec)
            except ValueError:
                pass
            return False
        self.release(rec, t)
        return True

    # --------------------------------------------------------------- failures
    def apply_failure(self, ev: FailureEvent,
                      t: float | None = None) -> list[ServedRequest]:
        """Single-event convenience wrapper over :meth:`apply_failures`."""
        return self.apply_failures([ev], t)

    def apply_failures(self, events: list[FailureEvent],
                       t: float | None = None) -> list[ServedRequest]:
        """Apply one *instant's* substrate events at `t` (docs/failures.md).

        All marks land first, in schedule order — ``recover`` restores a
        resource's capacity, a down event zeroes it — so same-instant
        failures are simultaneous: no victim is migrated onto a resource
        that dies in the same instant.  Victims (found through the
        ResidualState reverse index, deduped in first-event order) are then
        *all* released — the survivors' residual network is fully settled
        before any replanning — then their shapes are batch-presolved once
        against the degraded residuals via ``solve_batch`` and each victim
        is recommitted: standby promotion first (HA), then the batch seed,
        then a fresh capacity-aware attempt; a victim with no feasible new
        plan is parked for retry (``retry=True``) or killed.  Parked victims
        are re-attempted by :meth:`drain_failed` whenever capacity returns.
        Returns the victim records."""
        t_at = t if t is not None else 0.0
        causes: dict[int, str] = {}  # rid -> first failure that hit it
        for ev in events:
            if ev.kind == "recover":
                if ev.node is not None:
                    self.state.recover_node(ev.node)
                else:
                    self.state.recover_link(*ev.link)
                self._event("recover", -1, t)
                continue
            if ev.kind == "node_down":
                victim_ids = self.state.fail_node(ev.node)
            else:
                victim_ids = self.state.fail_link(*ev.link)
            self._event(ev.kind, -1, t)
            for rid in victim_ids:
                causes.setdefault(rid, ev.resource)
        self.res_memo.clear()
        victims = [self.live[rid] for rid in causes]
        for rec in victims:  # take every victim down before replanning any
            rid = rec.request.request_id
            self.state.release(self.planner.profile, rec.request, rec.plan)
            del self.live[rid]
            self.concurrent -= 1
            rec.failed_s = t_at
            self._down_cause[rid] = causes[rid]
            self._event("disrupt", rid, t)
        if victims:
            self.res_memo.clear()
            seeds = self._presolve_degraded(victims)
            for rec in victims:  # recommit in take-down order
                rid = rec.request.request_id
                plan, via = self._replacement_plan(rec, seed=seeds.get(rid))
                if plan is not None:
                    self._restore(rec, plan, t, cause=causes[rid], via=via)
                elif self.retry:
                    self.fail_parked.append(rec)
        return victims

    def _presolve_degraded(self, victims: list[ServedRequest]
                           ) -> dict[int, Plan | None]:
        """One ``solve_batch`` dispatch per mode over the degraded residual
        network for all victims of an event — the migration counterpart of
        the admission presolve."""
        by_mode: dict[str, list[ServedRequest]] = {}
        for rec in victims:
            by_mode.setdefault(rec.request.mode, []).append(rec)
        seeds: dict[int, Plan | None] = {}
        planner = self.planner
        for mode, recs in by_mode.items():
            net = self.state.materialize(mode)
            problems = [rec.request.problem(net, planner.profile)
                        for rec in recs]
            outs = solve_batch(problems, planner.solver_name,
                               cache=planner.cache.fork_fits(),
                               **planner.solver_kwargs)
            for rec, out in zip(recs, outs):
                seeds[rec.request.request_id] = out.plan
        return seeds

    def _replacement_plan(self, rec: ServedRequest,
                          seed: Plan | None = None
                          ) -> tuple[Plan | None, str]:
        """A new plan for a downed chain against the *current* residuals:
        standby promotion, the event's batch-presolve seed, then a fresh
        snapshot/replan attempt."""
        r = rec.request
        profile = self.planner.profile
        if rec.standby is not None and self.state.fits(profile, r,
                                                       rec.standby):
            return rec.standby, "standby"
        if seed is not None and self.state.fits(profile, r, seed):
            return seed, "replan"
        plan, _, _, _ = self.planner.attempt(self.state, r,
                                             self.snapshot_for(r),
                                             res_net_cache=self.res_memo)
        return plan, "replan"

    def _restore(self, rec: ServedRequest, plan: Plan, t: float | None,
                 cause: str | None = None, via: str = "replan") -> None:
        """Recommit a downed chain on `plan`, appending the migration audit
        entry (old plan, moved bytes, disruption seconds)."""
        r = rec.request
        t_at = t if t is not None else 0.0
        old_plan = rec.plan
        delta = migration_delta(self.planner.profile, r, old_plan, plan)
        if cause is None:
            cause = self._down_cause.get(r.request_id, "")
        self._down_cause.pop(r.request_id, None)
        rec.migrations.append({
            "t_down": rec.failed_s, "t_restored": t_at,
            "cause": cause, "via": via, "old_plan": _plan_dict(old_plan),
            "disruption_s": ((t_at - rec.failed_s)
                             + self.cost_model.restage_s(
                                 delta["moved_bytes"])),
            **delta,
        })
        rec.latency_s = self.planner.commit_latency_s(self.state, r, plan)
        rec.plan = plan
        rec.failed_s = None
        self.res_memo.clear()
        self.live[r.request_id] = rec
        self.concurrent += 1
        self._event("migrate", r.request_id, t)

    def _plan_standby(self, r: ServeRequest, primary: Plan) -> Plan | None:
        """HA standby preplanning at admit time: solve the chain once more on
        the disjoint fabric (primary hosts/links blocked) so a single failure
        can never take both plans down.  The backup is *not* committed — it
        reserves nothing until promoted."""
        net = standby_network(self.planner.net, r, primary)
        out = self.planner._solve(net, r, self.planner.cache.fork_fits())
        return out.plan

    def drain_failed(self, t: float | None = None) -> list[ServedRequest]:
        """Re-attempt parked victims (in park order) against the current
        residuals — called by the drivers whenever capacity returns (a
        departure or a recovery).  A victim whose service window already
        ended while down stays killed.  Restored chains keep their original
        departure schedule."""
        restored, still = [], []
        for rec in self.fail_parked:
            if (t is not None and rec.depart_s is not None
                    and rec.depart_s <= t):
                continue  # expired while down: killed
            plan, via = self._replacement_plan(rec)
            if plan is None:
                still.append(rec)
                continue
            self._restore(rec, plan, t, via=via)
            restored.append(rec)
        self.fail_parked = still
        return restored

    def drain_pending(self, t: float | None = None) -> list[ServedRequest]:
        """Re-attempt the retry queue in arrival order against the current
        residuals; returns the newly admitted records (the driver schedules
        their departures)."""
        admitted = []
        for r in sorted(self.pending, key=lambda r: (r.arrival_s,
                                                     r.request_id)):
            rec = self.try_admit(r, t)
            if rec is not None:
                self.pending.remove(r)
                admitted.append(rec)
        return admitted

    def reject_pending(self, t: float | None = None) -> None:
        """Final rejections: the event stream drained with these still queued."""
        for r in sorted(self.pending, key=lambda r: (r.arrival_s,
                                                     r.request_id)):
            snapshot = self.snapshot_for(r)
            self.served.append(ServedRequest(
                r, False, plan=snapshot.plan, reason="capacity",
                status=snapshot.status,
                n_retries=self.retries.get(r.request_id, 0)))
            self._event("reject", r.request_id, t)
        self.pending.clear()

    def conservation_ok(self) -> bool:
        return self.state.conservation_ok(self.planner.profile)
