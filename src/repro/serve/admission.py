"""AdmissionCore — the one admission engine under every serve driver.

Layer 1 of the serve stack (docs/gateway.md): the snapshot-fits →
residual-replan → commit/release/retry state machine that used to live twice
(inline in :meth:`ServePlanner.admit` and again in :meth:`ServeSim.run`) is
one object here, and the three drivers are thin loops over it:

* the **static round** (`ServePlanner.admit`) feeds the whole policy-ordered
  fleet through :meth:`AdmissionCore.try_admit` with no timestamps;
* the **simulator** (`ServeSim.run`) walks its event heap, calling
  :meth:`try_admit` on arrivals, :meth:`release` on departures, and
  :meth:`drain_pending` after the departures of an instant have all drained;
* the **gateway** (`ServeGateway`) does the same per tick, with the extra
  control-plane knobs (bounded queues, SLO rejection) layered on top.

The core owns the mutable admission state — the :class:`ResidualState`, the
decision records, the retry queue and per-request retry counts, the event
timeline, and the residual-network memo shared across consecutive *failed*
attempts (any commit/release invalidates it).  All policy decisions (ordering,
when to tick, when to give up) stay in the drivers; all capacity decisions
live here, so the three drivers cannot drift apart.

``slo_latency_s`` is the gateway's SLO gate: when set, an otherwise-admissible
plan whose contended latency exceeds the budget is rejected *before* commit
(reason ``"slo"``) — the fabric is never touched, so the residual memo stays
valid.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import Plan, SolveOutcome

from .requests import ServeRequest
from .residual import ResidualState

INF = float("inf")


@dataclass
class ServedRequest:
    """Admission outcome of one request (in admission/decision order)."""

    request: ServeRequest
    accepted: bool
    replanned: bool = False
    latency_s: float | None = None
    plan: Plan | None = None
    reason: str = ""  # "" | "no-plan" | "capacity" | "slo" | "queue-full"
    status: str | None = None  # SolveOutcome.status of the winning solve
    # Event-driven fields (ServeSim / ServeGateway); None for static rounds.
    admit_s: float | None = None  # admission timestamp (>= arrival on retry)
    depart_s: float | None = None  # admit_s + duration_s when finite
    n_retries: int = 0  # failed capacity attempts before the final decision

    def to_dict(self) -> dict:
        r = self.request
        d = {
            "request_id": r.request_id,
            "source": r.source,
            "destination": r.destination,
            "batch_size": r.batch_size,
            "mode": r.mode,
            "K": r.K,
            "candidates": [list(c) for c in r.candidates],
            "arrival_s": r.arrival_s,
            "rate_rps": r.rate_rps,
            "model_id": r.model_id,
            "schedule": r.schedule,
            "n_microbatches": r.n_microbatches,
            # inf round-trips as null so the artifacts stay strict JSON
            "duration_s": None if r.duration_s == INF else r.duration_s,
            "accepted": self.accepted,
            "replanned": self.replanned,
            "latency_s": self.latency_s,
            "reason": self.reason,
            "status": self.status,
            "admit_s": self.admit_s,
            "depart_s": self.depart_s,
            "n_retries": self.n_retries,
        }
        if self.plan is not None:
            d["segments"] = [list(s) for s in self.plan.segments]
            d["placement"] = list(self.plan.placement)
            d["paths"] = [list(p) for p in self.plan.paths]
            d["tail_path"] = list(self.plan.tail_path)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServedRequest":
        duration = d.get("duration_s")
        req = ServeRequest(
            request_id=d["request_id"], source=d["source"],
            destination=d["destination"], batch_size=d["batch_size"],
            mode=d["mode"], K=d["K"],
            candidates=tuple(tuple(c) for c in d["candidates"]),
            arrival_s=d["arrival_s"], rate_rps=d["rate_rps"],
            model_id=d["model_id"], schedule=d.get("schedule", "seq"),
            n_microbatches=d.get("n_microbatches", 1),
            duration_s=INF if duration is None else duration)
        plan = None
        if "segments" in d:
            plan = Plan(segments=[tuple(s) for s in d["segments"]],
                        placement=list(d["placement"]),
                        paths=[list(p) for p in d["paths"]],
                        tail_path=list(d["tail_path"]))
        return cls(req, d["accepted"], d["replanned"], d["latency_s"], plan,
                   d.get("reason", ""), d.get("status"), d.get("admit_s"),
                   d.get("depart_s"), d.get("n_retries", 0))


class AdmissionCore:
    """The shared admission state machine (see module docstring).

    ``presolved`` / ``keys`` are the planner's snapshot-solve maps; the
    gateway grows them incrementally (``presolved.update(...)``) as new
    shapes stream in.  ``record_events`` turns on the timeline audit log —
    events carry the timestamp the driver passes to each call, so the static
    round (no timestamps) leaves the timeline empty.
    """

    def __init__(self, planner, presolved: dict[str, SolveOutcome],
                 keys: dict[int, str], *, retry: bool = False,
                 slo_latency_s: float | None = None,
                 record_events: bool = False):
        self.planner = planner
        self.presolved = presolved
        self.keys = keys
        self.retry = retry
        self.slo_latency_s = slo_latency_s
        self.record_events = record_events

        self.state = ResidualState(planner.net)
        self.served: list[ServedRequest] = []
        self.timeline: list[dict] = []
        self.pending: list[ServeRequest] = []  # capacity-blocked, awaiting retry
        self.retries: dict[int, int] = {}
        self.concurrent = 0
        # Residual-network memo for planner.attempt, shared across the
        # *failed* attempts between two state changes (the state is unchanged
        # between them); any commit or release invalidates it.
        self.res_memo: dict = {}

    def snapshot_for(self, r: ServeRequest) -> SolveOutcome:
        return self.presolved[self.keys[r.request_id]]

    def _event(self, event: str, request_id: int, t: float | None) -> None:
        if self.record_events and t is not None:
            self.timeline.append({"t": t, "event": event,
                                  "request_id": request_id,
                                  "concurrent": self.concurrent})

    def try_admit(self, r: ServeRequest,
                  t: float | None = None) -> ServedRequest | None:
        """One admission attempt (at instant `t` when event-driven); commits
        on success and returns the accepted record — the driver schedules the
        departure from its ``depart_s``.  Returns None when the request was
        rejected-and-recorded or parked on the retry queue."""
        snapshot = self.snapshot_for(r)
        chosen, replanned, status, reason = self.planner.attempt(
            self.state, r, snapshot, res_net_cache=self.res_memo)
        if chosen is not None and self.slo_latency_s is not None:
            latency = self.planner.planned_latency_s(self.state, r, chosen)
            if latency > self.slo_latency_s:
                # nothing was committed: the residual memo stays valid
                self.served.append(ServedRequest(
                    r, False, replanned=replanned, latency_s=latency,
                    plan=chosen, reason="slo", status=status,
                    n_retries=self.retries.get(r.request_id, 0)))
                self._event("reject", r.request_id, t)
                return None
        if chosen is None:
            if reason == "capacity" and self.retry:
                self.retries[r.request_id] = \
                    self.retries.get(r.request_id, 0) + 1
                if r not in self.pending:
                    self.pending.append(r)
            else:
                self.served.append(ServedRequest(
                    r, False, plan=snapshot.plan, reason=reason,
                    status=status, n_retries=self.retries.get(r.request_id, 0)))
                self._event("reject", r.request_id, t)
            return None
        latency = self.planner.commit_latency_s(self.state, r, chosen)
        self.res_memo.clear()  # the residual state just changed
        depart = None
        if t is not None and r.duration_s != INF:
            depart = t + r.duration_s
        rec = ServedRequest(
            r, True, replanned=replanned, latency_s=latency, plan=chosen,
            status=status, admit_s=t, depart_s=depart,
            n_retries=self.retries.get(r.request_id, 0))
        self.served.append(rec)
        self.concurrent += 1
        self._event("admit", r.request_id, t)
        return rec

    def release(self, rec: ServedRequest, t: float | None = None) -> None:
        """A departing chain returns its exact demand to the fabric."""
        self.state.release(self.planner.profile, rec.request, rec.plan)
        self.res_memo.clear()  # the residual state just changed
        self.concurrent -= 1
        self._event("depart", rec.request.request_id, t)

    def drain_pending(self, t: float | None = None) -> list[ServedRequest]:
        """Re-attempt the retry queue in arrival order against the current
        residuals; returns the newly admitted records (the driver schedules
        their departures)."""
        admitted = []
        for r in sorted(self.pending, key=lambda r: (r.arrival_s,
                                                     r.request_id)):
            rec = self.try_admit(r, t)
            if rec is not None:
                self.pending.remove(r)
                admitted.append(rec)
        return admitted

    def reject_pending(self, t: float | None = None) -> None:
        """Final rejections: the event stream drained with these still queued."""
        for r in sorted(self.pending, key=lambda r: (r.arrival_s,
                                                     r.request_id)):
            snapshot = self.snapshot_for(r)
            self.served.append(ServedRequest(
                r, False, plan=snapshot.plan, reason="capacity",
                status=snapshot.status,
                n_retries=self.retries.get(r.request_id, 0)))
            self._event("reject", r.request_id, t)
        self.pending.clear()

    def conservation_ok(self) -> bool:
        return self.state.conservation_ok(self.planner.profile)
