"""Failure events, migration costs, and HA standby planning (docs/failures.md).

The paper's planner assumes a static substrate; a production MSL/MSI fabric
loses links and nodes while chains are in flight (Bhamare et al. fold exactly
this resource loss into the multi-cloud SFC problem).  This module holds the
*data* side of the failure engine:

* :class:`FailureEvent` — ``link_down`` / ``node_down`` / ``recover`` at a
  stream timestamp, the event kind ServeSim and the gateway interleave with
  arrivals and departures (departures < failures < arrivals at equal
  timestamps, so capacity freed "now" is re-checked against the degraded
  fabric "now");
* :class:`MigrationCostModel` + :func:`migration_delta` — what a migration
  *costs*: the parameter and smashed-data bytes that must move to the
  segments' new hosts, converted into restage seconds;
* :func:`standby_network` — the solve fabric for HA standby preplanning: the
  primary plan's intermediate hosts stripped of capacity and its links
  removed, so the backup solved on it is placement- and path-disjoint
  (Neutron's active/standby L3 HA routing state is the precedent);
* :func:`generate_failures` — deterministic seeded Poisson failure schedules
  for sweeps, with exponential downtimes and protected endpoints.

The *mechanism* — victim detection via the :class:`ResidualState` reverse
index, release → batched degraded-presolve → recommit/park — lives in
:meth:`AdmissionCore.apply_failure`.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core import (LinkSpec, ModelProfile, NodeSpec, PhysicalNetwork,
                        Plan)

from .requests import ServeRequest
from .residual import plan_footprint

FAILURE_KINDS = ("link_down", "node_down", "recover")


@dataclass(frozen=True)
class FailureEvent:
    """One substrate event: a link or node going down, or recovering.

    Exactly one of ``node`` / ``link`` is set.  Link failures are undirected
    (both directions lose capacity); a node failure takes every incident link
    with it.  A ``recover`` names the resource it restores.
    """

    t_s: float
    kind: str  # link_down | node_down | recover
    node: str | None = None
    link: tuple[str, str] | None = None

    def __post_init__(self) -> None:
        if self.kind not in FAILURE_KINDS:
            raise ValueError(f"kind must be one of {FAILURE_KINDS}, "
                             f"got {self.kind!r}")
        if (self.node is None) == (self.link is None):
            raise ValueError("exactly one of node/link must be set")
        if self.link is not None:
            object.__setattr__(self, "link", tuple(self.link))

    @property
    def resource(self) -> str:
        """Human-readable resource name (used in causes and reports)."""
        if self.node is not None:
            return f"node:{self.node}"
        return f"link:{self.link[0]}-{self.link[1]}"

    def to_dict(self) -> dict:
        return {"t_s": self.t_s, "kind": self.kind, "node": self.node,
                "link": list(self.link) if self.link else None}

    @classmethod
    def from_dict(cls, d: dict) -> "FailureEvent":
        link = d.get("link")
        return cls(d["t_s"], d["kind"], node=d.get("node"),
                   link=tuple(link) if link else None)


@dataclass(frozen=True)
class MigrationCostModel:
    """How long restaging a migrated chain takes beyond the outage itself.

    ``reload_bps`` — sustained rate at which moved parameter/smashed bytes
    are restaged onto the new hosts (paper Table II's disk/NIC order of
    magnitude: 1 Gbit/s default).  ``restart_s`` — fixed per-migration
    restart overhead (process spawn, re-jit, checkpoint open).  A migration's
    disruption is ``(t_restored - t_down) + restart_s + moved_bytes * 8 /
    reload_bps``.
    """

    reload_bps: float = 1e9
    restart_s: float = 0.0

    def __post_init__(self) -> None:
        if self.reload_bps <= 0:
            raise ValueError("reload_bps must be > 0")
        if self.restart_s < 0:
            raise ValueError("restart_s must be >= 0")

    def restage_s(self, moved_bytes: float) -> float:
        return self.restart_s + moved_bytes * 8.0 / self.reload_bps


def migration_delta(profile: ModelProfile, request: ServeRequest,
                    old_plan: Plan, new_plan: Plan) -> dict:
    """The bytes a migration actually moves: for every (segment, node)
    assignment of the new plan that the old plan did not already have, the
    segment's parameters plus its batch-scaled peak smashed data must be
    shipped to the new host.  Assignments the plans share are already staged
    and move nothing."""
    old = set(zip(old_plan.segments, old_plan.placement))
    param = smashed = 0.0
    for seg, node in zip(new_plan.segments, new_plan.placement):
        if (tuple(seg), node) in old or (seg, node) in old:
            continue
        lo, hi = seg
        param += profile.seg_mem_bytes(lo, hi)
        smashed += request.batch_size * profile.seg_peak_smashed(
            lo, hi, request.mode)
    return {"moved_param_bytes": param, "moved_smashed_bytes": smashed,
            "moved_bytes": param + smashed}


def standby_network(base: PhysicalNetwork, request: ServeRequest,
                    primary: Plan) -> PhysicalNetwork:
    """The fabric a disjoint standby plan is solved on: the primary's
    intermediate placement nodes keep routability but lose all hosting
    capacity, and every directed link of the primary's subpaths is removed —
    so any feasible solve yields a backup sharing no intermediate host and
    no link with the active plan (single link/node failures can never take
    both down at once).  Source and destination are pinned by the chain
    itself and stay usable."""
    links, _ = plan_footprint(primary)
    blocked = (set(primary.placement)
               - {request.source, request.destination})
    out = PhysicalNetwork()
    for name, spec in base.nodes.items():
        if name in blocked:
            out.add_node(NodeSpec(name, spec.compute, 0.0, 0.0))
        else:
            out.add_node(NodeSpec(name, spec.compute, spec.mem_capacity,
                                  spec.disk_capacity))
    for (u, v), spec in base.links.items():
        if (u, v) in links or (v, u) in links:
            continue
        if u in blocked or v in blocked:
            continue  # transit through a blocked host is not disjoint either
        out.add_link(u, v, LinkSpec(spec.bw_fw, spec.bw_bw,
                                    spec.delay_fw, spec.delay_bw))
    return out


def generate_failures(net: PhysicalNetwork, *, rate_per_s: float,
                      horizon_s: float, seed: int = 0,
                      mean_downtime_s: float | None = None,
                      protect: tuple[str, ...] = (),
                      node_fraction: float = 0.3) -> list[FailureEvent]:
    """Deterministic seeded failure schedule: Poisson(rate_per_s) events over
    ``[0, horizon_s)``, each hitting a uniformly chosen link (or, with
    probability ``node_fraction``, a node outside ``protect`` — sources and
    destinations are typically protected so chains stay definable).  With
    ``mean_downtime_s`` every failure is paired with an Exponential-delayed
    ``recover``; without it failures are permanent.  A resource already down
    at the draw is skipped (no nested outages), keeping the schedule's
    semantics identical under set-based down-state replay."""
    if rate_per_s <= 0 or horizon_s <= 0:
        return []
    rng = random.Random(seed * 60013 + 7)
    links = sorted({tuple(sorted((u, v))) for (u, v) in net.links})
    nodes = [n for n in sorted(net.nodes) if n not in protect]
    if not links and not nodes:
        return []
    events: list[FailureEvent] = []
    down_until: dict[tuple, float] = {}
    t = 0.0
    while True:
        t += rng.expovariate(rate_per_s)
        if t >= horizon_s:
            break
        # both draws always happen so the stream is choice-independent
        hit_node = (rng.random() < node_fraction and nodes) or not links
        idx = rng.randrange(len(nodes) if hit_node else len(links))
        if hit_node:
            key: tuple = ("node", nodes[idx])
            ev = FailureEvent(t, "node_down", node=nodes[idx])
        else:
            key = ("link",) + links[idx]
            ev = FailureEvent(t, "link_down", link=links[idx])
        up_at = down_until.get(key)
        if up_at is None or (up_at != float("inf") and up_at <= t):
            events.append(ev)
            if mean_downtime_s is not None:
                dt = rng.expovariate(1.0 / mean_downtime_s)
                down_until[key] = t + dt
                events.append(FailureEvent(t + dt, "recover", node=ev.node,
                                           link=ev.link))
            else:
                down_until[key] = float("inf")
    events.sort(key=lambda e: e.t_s)  # recovers interleave with later failures
    return events
