"""ServeGateway — the long-running admission control plane (Layer 3).

The static round plans a fleet once; the simulator replays a finite trace.
A *gateway* is the always-on object a serving deployment would actually run
(ROADMAP item 2): requests stream in via :meth:`submit`, admission happens in
**ticks** (:meth:`tick`), and :meth:`drain` closes the stream and returns the
full outcome.  One tick:

1. **releases** every committed chain whose ``depart_s`` is due, then (with
   ``retry``) re-attempts the retry queue against the freed residuals —
   mirroring the simulator's "drain all departures first" rule at tick
   granularity;
2. **presolves** the tick's arrival batch in one shot: content-hash lookups
   against the warm cross-stream :class:`~repro.serve.plancache.PlanCache`,
   with the misses solved by a single ``solve_batch`` call (one batched/JAX
   dispatch per tick, not N Python solves);
3. **admits** the batch in policy order through the shared
   :class:`~repro.serve.admission.AdmissionCore` — the same
   snapshot-fits → residual-replan → commit machinery as the static round
   and the simulator, plus the gateway-only gates:

   * **backpressure** — :meth:`submit` rejects on a full bounded queue
     (reason ``"queue-full"``) before any planning happens;
   * **SLO** — an admissible plan whose contended latency exceeds
     ``slo_latency_s`` is rejected before commit (reason ``"slo"``).

Timestamps are *stream* time (request ``arrival_s``), supplied by the caller
per tick; per-tick wall-clock cost is measured separately into
:class:`GatewayStats`.  :meth:`run_stream` is the batch-window driver used by
the CLI / benchmark / sweep: it partitions a fleet's arrivals into windows of
``batch_window_s`` and submits+ticks each window.

Anchor invariant (docs/gateway.md, pinned in ``tests/test_gateway.py``): a
gateway fed an entire fleet in one tick with an unbounded queue, no SLO, and
a cold cache reproduces the static :meth:`ServePlanner.admit` round
bit-for-bit (same plans, latencies, statuses, decision order).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import EvalCache, ModelProfile, PhysicalNetwork

from .admission import AdmissionCore, ServedRequest
from .failures import FailureEvent, MigrationCostModel
from .plancache import PlanCache
from .planner import ServePlanner
from .policies import POLICIES
from .requests import ServeRequest
from .sim import _DEPART, SimOutcome

_INF = float("inf")


@dataclass(frozen=True)
class GatewayConfig:
    """Control-plane knobs (the planning engine has its own, on the planner).

    ``batch_window_s`` — arrival-grouping window of :meth:`run_stream`
    (0 = one tick per distinct arrival timestamp, the simulator's
    granularity).  ``max_queue`` — bounded admission queue; `submit` rejects
    (``"queue-full"``) once this many requests await a tick.  ``slo_latency_s``
    — reject plans whose contended latency exceeds this before commit.
    ``retry`` — park capacity-blocked requests and re-attempt on departures.
    """

    batch_window_s: float = 0.0
    max_queue: int | None = None  # None = unbounded
    slo_latency_s: float | None = None  # None = no SLO gate
    retry: bool = False

    def __post_init__(self) -> None:
        if self.batch_window_s < 0:
            raise ValueError("batch_window_s must be >= 0")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 or None")
        if self.slo_latency_s is not None and self.slo_latency_s <= 0:
            raise ValueError("slo_latency_s must be > 0 or None")


@dataclass
class GatewayStats:
    """Per-tick observability: wall time, queue depth, cache hit rates."""

    ticks: list[dict] = field(default_factory=list)
    n_submitted: int = 0
    n_queue_rejected: int = 0  # backpressure rejections at submit()

    def record_tick(self, **row) -> None:
        self.ticks.append(row)

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)

    def tick_wall_percentiles(self,
                              qs: tuple[float, ...] = (50, 95, 99)) -> dict:
        walls = [t["wall_s"] for t in self.ticks]
        if not walls:
            return {f"p{int(q)}": None for q in qs}
        arr = np.asarray(sorted(walls))
        return {f"p{int(q)}": float(np.percentile(arr, q)) for q in qs}

    def summary(self) -> dict:
        walls = [t["wall_s"] for t in self.ticks]
        admitted = sum(t["n_admitted"] for t in self.ticks)
        wall = sum(walls)
        return {
            "n_ticks": self.n_ticks,
            "n_submitted": self.n_submitted,
            "n_queue_rejected": self.n_queue_rejected,
            "tick_wall_total_s": wall,
            "tick_wall_mean_s": wall / self.n_ticks if self.ticks else None,
            "tick_wall_pct": self.tick_wall_percentiles(),
            "max_queue_depth": max((t["queue_depth"] for t in self.ticks),
                                   default=0),
            "admissions_per_s": admitted / wall if wall > 0 else None,
        }


@dataclass
class GatewayOutcome(SimOutcome):
    """A drained gateway stream: the sim trace fields + control-plane stats.

    ``served`` records carry the same admit/depart timestamps as a simulator
    trace, so ``replay_verify_sim`` re-verifies gateway traces unchanged
    (``"slo"`` / ``"queue-full"`` rejections never touched the fabric and are
    skipped by the replay like any other rejection).
    """

    gateway_stats: dict = field(default_factory=dict)
    failures: list = field(default_factory=list)  # injected FailureEvents

    @property
    def n_slo_rejected(self) -> int:
        return sum(1 for s in self.served
                   if not s.accepted and s.reason == "slo")

    @property
    def n_queue_rejected(self) -> int:
        return sum(1 for s in self.served
                   if not s.accepted and s.reason == "queue-full")

    def summary(self) -> dict:
        s = super().summary()
        s.update({
            "n_slo_rejected": self.n_slo_rejected,
            "n_queue_rejected": self.n_queue_rejected,
            "gateway": self.gateway_stats,
        })
        return s


class ServeGateway:
    """Always-on admission over one fabric: ``submit() / tick() / drain()``.

    Owns a :class:`ServePlanner` wired to a warm :class:`PlanCache` (Layer 2)
    and an :class:`AdmissionCore` (Layer 1) whose presolved maps grow
    incrementally as new shapes stream in.  See the module docstring for the
    tick anatomy and docs/gateway.md for the full contract.
    """

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 solver: str = "bcd", replan: bool = True,
                 policy: str = "fcfs",
                 config: GatewayConfig | None = None,
                 cache: EvalCache | None = None,
                 plan_cache: PlanCache | None = None,
                 solver_kwargs: dict | None = None,
                 cost_model: MigrationCostModel | None = None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {sorted(POLICIES)}")
        self.config = config if config is not None else GatewayConfig()
        self.policy = policy
        self.planner = ServePlanner(
            net, profile, solver=solver, replan=replan, cache=cache,
            plan_cache=plan_cache if plan_cache is not None else PlanCache(),
            solver_kwargs=solver_kwargs)
        self.core = AdmissionCore(
            self.planner, {}, {}, retry=self.config.retry,
            slo_latency_s=self.config.slo_latency_s, record_events=True,
            cost_model=cost_model)
        self.stats = GatewayStats()
        self.queue: list[ServeRequest] = []  # submitted, awaiting a tick
        self.estimates: dict[int, float] = {}  # solo latencies (policy input)
        self._departures: list[tuple] = []  # (depart_s, prio, seq, record)
        self._seq = itertools.count()  # deterministic heap tie-break
        self._failures: list[FailureEvent] = []  # injected, time-ordered
        self._fail_i = 0  # next failure event not yet applied
        self.now = 0.0  # stream time of the last tick
        self._t0 = time.perf_counter()
        self._drained = False

    def inject_failures(self, events: list[FailureEvent]) -> None:
        """Register a substrate failure schedule (docs/failures.md): events
        are applied in timestamp order as stream time advances past them —
        interleaved with due departures, failures after the departures of
        their instant.  Must be called before the events' timestamps pass."""
        if self._drained:
            raise RuntimeError("gateway already drained")
        self._failures = sorted(self._failures[self._fail_i:] + list(events),
                                key=lambda e: e.t_s)
        self._fail_i = 0

    # ----------------------------------------------------------- control plane
    def submit(self, requests: list[ServeRequest] | ServeRequest) -> int:
        """Enqueue requests for the next tick; returns how many were accepted
        into the queue.  With a bounded queue, overflow requests are rejected
        immediately (reason ``"queue-full"``) — backpressure costs no
        planning work and never touches the fabric."""
        if self._drained:
            raise RuntimeError("gateway already drained")
        if isinstance(requests, ServeRequest):
            requests = [requests]
        accepted = 0
        cap = self.config.max_queue
        for r in requests:
            self.stats.n_submitted += 1
            if cap is not None and len(self.queue) >= cap:
                self.stats.n_queue_rejected += 1
                self.core.served.append(ServedRequest(
                    r, False, reason="queue-full"))
                continue
            self.queue.append(r)
            accepted += 1
        return accepted

    def _release_due(self, now: float) -> int:
        """Advance substrate time to `now`: process every due departure and
        injected failure event in timestamp order (departures before the
        failures of their instant, same-instant failures as one batch), then
        re-attempt parked victims and the retry queue once against the
        settled residuals (the sim's drain-departures-first rule,
        tick-grained)."""
        released = 0
        changed = False
        while True:
            t_dep = self._departures[0][0] if self._departures else _INF
            t_fail = (self._failures[self._fail_i].t_s
                      if self._fail_i < len(self._failures) else _INF)
            t = min(t_dep, t_fail)
            if t > now:
                break
            if t_dep <= t_fail:
                _, _, _, rec = heapq.heappop(self._departures)
                if self.core.depart(rec, t_dep):
                    released += 1
            else:
                j = self._fail_i
                while (j < len(self._failures)
                       and self._failures[j].t_s == t_fail):
                    j += 1
                self.core.apply_failures(self._failures[self._fail_i:j],
                                         t_fail)
                self._fail_i = j
                changed = True
        if (released or changed) and self.config.retry:
            if self.core.fail_parked:
                self.core.drain_failed(now)  # keep scheduled departures
            if self.core.pending:  # kills free capacity too, not just departs
                for rec in self.core.drain_pending(now):
                    self._push_depart(rec)
        return released

    def _push_depart(self, rec: ServedRequest) -> None:
        if rec.depart_s is not None:
            heapq.heappush(self._departures,
                           (rec.depart_s, _DEPART, next(self._seq), rec))

    def tick(self, now: float | None = None) -> dict:
        """One admission tick at stream time `now` (default: the latest
        arrival in the queue).  Returns the tick's stats row."""
        if self._drained:
            raise RuntimeError("gateway already drained")
        wall0 = time.perf_counter()
        batch, self.queue = self.queue, []
        if now is None:
            now = max([self.now] + [r.arrival_s for r in batch])
        self.now = max(self.now, now)

        released = self._release_due(self.now)

        # Layer 2: one batched presolve for the tick's new shapes — PlanCache
        # hits skip the solver, misses share a single solve_batch dispatch.
        plan_cache = self.planner.plan_cache
        hits0, misses0 = plan_cache.hits, plan_cache.misses
        presolved, keys, estimates = self.planner.presolve(batch)
        self.core.presolved.update(presolved)
        self.core.keys.update(keys)
        self.estimates.update(estimates)

        n_admitted = n_rejected = 0
        for r in POLICIES[self.policy](batch, self.estimates):
            rec = self.core.try_admit(r, self.now)
            if rec is not None:
                self._push_depart(rec)
                n_admitted += 1
            elif r not in self.core.pending:
                n_rejected += 1

        row = {
            "tick": self.stats.n_ticks,
            "t": self.now,
            "wall_s": time.perf_counter() - wall0,
            "n_arrivals": len(batch),
            "n_released": released,
            "n_admitted": n_admitted,
            "n_rejected": n_rejected,
            "n_pending": len(self.core.pending),
            "queue_depth": len(self.queue),
            "concurrent": self.core.concurrent,
            "plan_cache_hits": plan_cache.hits - hits0,
            "plan_cache_misses": plan_cache.misses - misses0,
        }
        self.stats.record_tick(**row)
        return row

    def drain(self, horizon_s: float | None = None) -> GatewayOutcome:
        """Close the stream: tick any queued arrivals, release every chain
        departing by `horizon_s` (default: all of them), finally reject the
        still-pending retries, and return the full outcome."""
        if self._drained:
            raise RuntimeError("gateway already drained")
        if self.queue:
            self.tick()
        horizon = self.now
        while True:
            t_dep = self._departures[0][0] if self._departures else _INF
            t_fail = (self._failures[self._fail_i].t_s
                      if self._fail_i < len(self._failures) else _INF)
            t = min(t_dep, t_fail)
            if t == _INF or (horizon_s is not None and t > horizon_s):
                break
            horizon = max(horizon, t)
            # advance one instant at a time so retries see the same
            # all-departures-at-this-instant residuals as the simulator
            self._release_due(t)
        self.core.reject_pending(horizon)
        self._drained = True
        assert self.core.conservation_ok()
        stats = self.stats.summary()
        stats["plan_cache"] = self.planner.plan_cache.stats()
        stats["eval_cache"] = self.planner.cache.stats()
        return GatewayOutcome(
            policy=self.policy, solver=self.planner.solver_name,
            served=self.core.served,
            wall_time_s=time.perf_counter() - self._t0,
            n_presolved=len(self.core.presolved),
            cache_stats=self.planner.round_cache_stats(),
            retry=self.config.retry, horizon_s=horizon,
            timeline=self.core.timeline, gateway_stats=stats,
            failures=list(self._failures))

    # -------------------------------------------------------------- stream API
    def run_stream(self, requests: list[ServeRequest],
                   failures: list[FailureEvent] | None = None
                   ) -> GatewayOutcome:
        """Drive a whole fleet through the gateway: arrivals are grouped into
        ``batch_window_s`` windows (window start = first arrival in it), each
        window is submitted and ticked at its last arrival's timestamp, and
        the stream is drained at the end.  ``failures`` injects a substrate
        failure schedule applied as stream time passes each event."""
        if failures:
            self.inject_failures(failures)
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.request_id))
        i = 0
        while i < len(reqs):
            w_end = reqs[i].arrival_s + self.config.batch_window_s
            j = i
            while j < len(reqs) and reqs[j].arrival_s <= w_end:
                j += 1
            self.submit(reqs[i:j])
            self.tick(now=reqs[j - 1].arrival_s)
            i = j
        return self.drain()
