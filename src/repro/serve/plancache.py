"""PlanCache — warm snapshot-solve cache across the request stream (Layer 2).

The static round dedups snapshot solves *within one fleet* (first-seen
`ProblemInstance` content hashes share one ``solve_batch`` call).  A serving
gateway sees the same shapes recur across ticks for hours — this cache keys
full :class:`~repro.core.problem.SolveOutcome` objects by that same engine-wide
content hash so a recurring shape skips the solver entirely, with LRU
eviction and hit/miss/eviction counters for the observability block
(``GatewayStats`` / ``ServeOutcome.solver_stats()``).

Soundness: solvers are deterministic functions of the instance *content*
(the hash covers network + profile + request + K + candidate sets), and
snapshot solves always run against the uncontended base network — so a cached
outcome is bit-identical to a fresh solve, and residual-capacity admission
still re-checks every cached plan against the live fabric before commit.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.core import SolveOutcome


class PlanCache:
    """LRU map: ProblemInstance content hash -> snapshot SolveOutcome."""

    __slots__ = ("capacity", "hits", "misses", "evictions", "_data")

    def __init__(self, capacity: int | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        self.capacity = capacity  # None = unbounded
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._data: OrderedDict[str, SolveOutcome] = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        return key in self._data

    def get(self, key: str) -> SolveOutcome | None:
        """Counted lookup: a hit refreshes the entry's LRU position."""
        out = self._data.get(key)
        if out is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return out

    def put(self, key: str, outcome: SolveOutcome) -> None:
        self._data[key] = outcome
        self._data.move_to_end(key)
        if self.capacity is not None and len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float | None:
        total = self.hits + self.misses
        return self.hits / total if total else None

    def stats(self) -> dict:
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}

    def clear(self) -> None:
        """Drop entries; counters keep accumulating (lifetime observability)."""
        self._data.clear()
