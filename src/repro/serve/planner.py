"""ServePlanner — concurrent multi-request admission onto one fabric.

Admission round (one call to :meth:`ServePlanner.admit`):

1. **Pre-solve** every distinct request shape once against the *snapshot*
   (the uncontended base network) with shared caches — one `EvalCache`
   (batch/mode-keyed) and the network's dense frontier matrices, so the
   vectorized DFTS relaxations are shared across the whole fleet.  With a
   :class:`~repro.serve.plancache.PlanCache` attached, shapes already solved
   by *earlier* rounds/ticks are reused too (the gateway's cross-stream
   dedup); misses go through one `solve_batch` call.
2. **Order** the fleet with the chosen admission policy (pre-solved solo
   latencies feed the latency-greedy policy).
3. **Admit** in order through the shared :class:`AdmissionCore`: a request's
   snapshot plan is checked against the live residuals; if it no longer fits,
   capacity-aware **replanning** re-runs the solver against the materialized
   residual network (reduced link rates and node capacities) before the
   request is declared blocked.  Accepted plans are committed and their
   latency is evaluated on the residual fabric they were admitted onto, so
   per-request latencies reflect contention.

The solvers themselves are the paper's single-chain solvers — their
formulation has no link capacities, so every plan (snapshot or replanned) is
re-verified against the residuals before commit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import (EvalCache, ModelProfile, PhysicalNetwork, Plan,
                        PlanEvaluator, SolveOutcome, get_solver, solve,
                        solve_batch)

from .admission import INF, AdmissionCore, ServedRequest
from .plancache import PlanCache
from .policies import POLICIES
from .requests import ServeRequest
from .residual import ResidualState

__all__ = ["INF", "ServedRequest", "ServeOutcome", "ServePlanner",
           "replay_verify"]


@dataclass
class ServeOutcome:
    """Result of one admission round, in admission order."""

    policy: str
    solver: str
    served: list[ServedRequest] = field(default_factory=list)
    wall_time_s: float = 0.0
    n_presolved: int = 0  # distinct request shapes actually solved in step 1
    # planning-engine cache counters of the round (EvalCache hits/misses,
    # PlanCache hits/misses/evictions when one is attached) — see
    # solver_stats(); empty when the driver recorded none.
    cache_stats: dict = field(default_factory=dict)

    @property
    def n_requests(self) -> int:
        return len(self.served)

    @property
    def n_accepted(self) -> int:
        return sum(s.accepted for s in self.served)

    @property
    def n_replanned(self) -> int:
        return sum(s.accepted and s.replanned for s in self.served)

    @property
    def acceptance_ratio(self) -> float:
        return self.n_accepted / self.n_requests if self.served else 0.0

    @property
    def status(self) -> str:
        """Aggregate engine status of the round: ``optimal`` when every
        accepted chain's winning solve (snapshot or replan) was optimal,
        ``feasible`` when at least one chain was admitted, ``infeasible``
        otherwise.  This is per-chain solver optimality — the admission
        *order* itself is a heuristic either way."""
        acc = [s.status for s in self.served if s.accepted]
        if not acc:
            return "infeasible"
        return "optimal" if all(st == "optimal" for st in acc) else "feasible"

    def solver_stats(self) -> dict:
        """Per-round solve bookkeeping for sweep artifacts (``solver_stats``
        column): distinct shapes pre-solved, replans, per-status counts, and
        the planning-engine cache counters."""
        counts: dict[str, int] = {}
        for s in self.served:
            if s.status is not None:
                counts[s.status] = counts.get(s.status, 0) + 1
        return {"n_presolved": self.n_presolved,
                "n_replanned": self.n_replanned,
                "statuses": counts,
                "cache": self.cache_stats}

    def accepted_latencies(self) -> list[float]:
        return [s.latency_s for s in self.served
                if s.accepted and s.latency_s is not None]

    def latency_percentiles(self, qs: tuple[float, ...] = (50, 95, 99)) -> dict:
        lats = self.accepted_latencies()
        if not lats:
            return {f"p{int(q)}": None for q in qs}
        arr = np.asarray(sorted(lats))
        return {f"p{int(q)}": float(np.percentile(arr, q)) for q in qs}

    def summary(self) -> dict:
        pct = self.latency_percentiles()
        lats = self.accepted_latencies()
        return {
            "policy": self.policy,
            "solver": self.solver,
            "status": self.status,
            "n_requests": self.n_requests,
            "n_accepted": self.n_accepted,
            "n_replanned": self.n_replanned,
            "acceptance_ratio": self.acceptance_ratio,
            "latency_mean_s": float(np.mean(lats)) if lats else None,
            "latency_p50_s": pct["p50"],
            "latency_p95_s": pct["p95"],
            "latency_p99_s": pct["p99"],
            "wall_time_s": self.wall_time_s,
            "n_presolved": self.n_presolved,
        }

    def mode_split(self) -> dict:
        """Per-mode (IF vs TR) admission breakdown of the round: how training
        and inference chains fared under shared-fabric contention
        (docs/training.md).  Keys are the modes present in the fleet; each
        carries the per-mode acceptance and latency percentiles the mixed
        training sweep reports on."""
        by_mode: dict[str, list[ServedRequest]] = {}
        for s in self.served:
            by_mode.setdefault(s.request.mode, []).append(s)
        out: dict[str, dict] = {}
        for m in sorted(by_mode):
            rows = by_mode[m]
            lats = sorted(s.latency_s for s in rows
                          if s.accepted and s.latency_s is not None)
            arr = np.asarray(lats) if lats else None
            n_acc = sum(s.accepted for s in rows)
            out[m] = {
                "n_requests": len(rows),
                "n_accepted": n_acc,
                "acceptance_ratio": n_acc / len(rows),
                "latency_mean_s": float(np.mean(arr)) if lats else None,
                **{f"latency_p{int(q)}_s":
                   (float(np.percentile(arr, q)) if lats else None)
                   for q in (50, 95, 99)},
            }
        return out


class ServePlanner:
    """Admits fleets of :class:`ServeRequest` onto one `PhysicalNetwork`."""

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 solver: str = "bcd", replan: bool = True,
                 cache: EvalCache | None = None,
                 plan_cache: PlanCache | None = None,
                 solver_kwargs: dict | None = None):
        get_solver(solver)  # uniform unknown-solver error from the registry
        self.net = net
        self.profile = profile
        self.solver_name = solver
        self.solver_kwargs = dict(solver_kwargs or {})
        self.replan = replan
        # snapshot cache: batch/mode are part of EvalCache keys, so one cache
        # serves the whole heterogeneous fleet against the base network
        self.cache = cache if cache is not None else EvalCache()
        # optional cross-round snapshot-outcome cache (the gateway's Layer 2):
        # keyed by ProblemInstance content hash, so recurring shapes skip the
        # solver entirely on later rounds/ticks
        self.plan_cache = plan_cache
        # request-shape tuple -> content hash.  The sha256-of-canonical-JSON
        # identity is ~50us per request; under a streaming gateway the same
        # few shapes recur for the whole run, so the hash is computed once
        # per shape instead of once per request.  The tuple is strictly finer
        # than the content identity (pipe with M=1 normalizes to seq in the
        # hash), which can only cost a duplicate hash, never alias two keys.
        self._key_memo: dict[tuple, str] = {}

    def _solve_key(self, r: ServeRequest) -> str:
        ident = (r.model_id, r.source, r.destination, r.batch_size, r.mode,
                 r.K, r.candidates, r.schedule, r.n_microbatches)
        key = self._key_memo.get(ident)
        if key is None:
            key = self._key_memo[ident] = r.solve_key(self.net, self.profile)
        return key

    def _solve(self, net: PhysicalNetwork, request: ServeRequest,
               cache: EvalCache | None) -> SolveOutcome:
        return solve(request.problem(net, self.profile), self.solver_name,
                     cache=cache, **self.solver_kwargs)

    def presolve(self, requests: list[ServeRequest]
                 ) -> tuple[dict[str, SolveOutcome], dict[int, str],
                            dict[int, float]]:
        """Solve each distinct request shape once on the snapshot network,
        deduped by ProblemInstance content hash (the engine-wide instance
        identity) and — when a :class:`PlanCache` is attached — by what
        earlier rounds already solved.  Returns (outcome by key, key by
        request id, solo-latency estimate by request id — the policies'
        ordering input)."""
        keys: dict[int, str] = {}
        seen: set[str] = set()
        order: list[str] = []  # first-seen key order (scalar-loop parity)
        problems: list = []
        presolved: dict[str, SolveOutcome] = {}
        for r in requests:
            key = keys[r.request_id] = self._solve_key(r)
            if key in seen:
                continue
            seen.add(key)
            if self.plan_cache is not None:
                hit = self.plan_cache.get(key)
                if hit is not None:
                    presolved[key] = hit
                    continue
            order.append(key)
            problems.append(r.problem(self.net, self.profile))
        outcomes = (solve_batch(problems, self.solver_name, cache=self.cache,
                                **self.solver_kwargs) if problems else [])
        presolved.update(zip(order, outcomes))
        if self.plan_cache is not None:
            for key, out in zip(order, outcomes):
                self.plan_cache.put(key, out)
        estimates = {r.request_id: presolved[keys[r.request_id]].latency_s
                     for r in requests}
        return presolved, keys, estimates

    def attempt(self, state: ResidualState, r: ServeRequest,
                snapshot: SolveOutcome,
                res_net_cache: dict | None = None
                ) -> tuple[Plan | None, bool, str | None, str]:
        """One admission attempt against the live residuals: try the
        snapshot plan, else replan on the materialized residual network.
        Returns ``(plan | None, replanned, status, reason)`` — the capacity
        half of :class:`AdmissionCore.try_admit`.

        ``res_net_cache`` (a per-mode dict) memoizes the materialized
        residual network across *consecutive failed* attempts — the caller
        must clear it whenever `state` changes (any commit/release), since a
        stale residual view would admit against freed/occupied capacity that
        no longer matches."""
        plan = snapshot.plan
        if plan is None:
            return None, False, snapshot.status, "no-plan"
        if state.fits(self.profile, r, plan):
            return plan, False, snapshot.status, ""
        if self.replan:
            # replan only capacity-blocked requests: if even the uncontended
            # snapshot had no feasible plan, the strictly tighter residual
            # network cannot have one either
            res_net = (res_net_cache.get(r.mode)
                       if res_net_cache is not None else None)
            if res_net is None:
                res_net = state.materialize(r.mode)
                if res_net_cache is not None:
                    res_net_cache[r.mode] = res_net
            res = self._solve(res_net, r, self.cache.fork_fits())
            if res.plan is not None and state.fits(self.profile, r, res.plan):
                return res.plan, True, res.status, ""
        return None, False, snapshot.status, "capacity"

    def planned_latency_s(self, state: ResidualState, r: ServeRequest,
                          plan: Plan) -> float:
        """The latency `plan` would see on the residual fabric as it stands —
        evaluated on the state's live keep-saturated view (saturated links
        clamped, not dropped: a zero-demand tail may legitimately cross
        them), *without* committing.  The SLO gate and the commit path both
        read this one number."""
        ev = PlanEvaluator(state.live_view(), self.profile,
                           r.chain_request(), cache=self.cache.fork_fits())
        return ev.latency_s(plan)

    def commit_latency_s(self, state: ResidualState, r: ServeRequest,
                         plan: Plan) -> float:
        """Commit an admitted plan and return its latency, evaluated on the
        residual fabric the request was admitted onto."""
        latency = self.planned_latency_s(state, r, plan)
        state.commit(self.profile, r, plan)
        return latency

    def round_cache_stats(self) -> dict:
        """The planning-engine cache counters a driver stamps on its outcome."""
        stats = {"eval_cache": self.cache.stats()}
        if self.plan_cache is not None:
            stats["plan_cache"] = self.plan_cache.stats()
        return stats

    def admit(self, requests: list[ServeRequest],
              policy: str = "fcfs") -> ServeOutcome:
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {sorted(POLICIES)}")
        t0 = time.perf_counter()

        # 1. pre-solve each distinct request shape on the snapshot, deduped by
        # ProblemInstance content hash (the engine-wide instance identity)
        presolved, keys, estimates = self.presolve(requests)

        # 2. policy order
        order = POLICIES[policy](requests, estimates)

        # 3. admission with residual accounting + capacity-aware replanning —
        # the static round is the simplest AdmissionCore driver: one pass, no
        # timestamps, no retries
        core = AdmissionCore(self, presolved, keys)
        for r in order:
            core.try_admit(r)
        assert core.conservation_ok()
        return ServeOutcome(policy=policy, solver=self.solver_name,
                            served=core.served,
                            wall_time_s=time.perf_counter() - t0,
                            n_presolved=len(presolved),
                            cache_stats=self.round_cache_stats())


def replay_verify(net: PhysicalNetwork, profile: ModelProfile,
                  served: list[ServedRequest]) -> bool:
    """Re-verify a (possibly reloaded) admission outcome from scratch: replay
    the accepted plans in admission order against a fresh ResidualState and
    confirm each fits as it is committed — i.e. accepted chains never
    oversubscribe a link or node — and that plans are structurally valid."""
    state = ResidualState(net)
    for s in served:
        if not s.accepted:
            continue
        assert s.plan is not None
        PlanEvaluator(net, profile, s.request.chain_request()).check(s.plan)
        if not state.fits(profile, s.request, s.plan):
            return False
        state.commit(profile, s.request, s.plan)
    return state.conservation_ok(profile)
