"""Pluggable admission-order policies.

A policy maps (requests, estimates) to the order in which the planner tries
to admit them, where ``estimates[request_id]`` is the request's pre-solved
solo latency on the admission-round snapshot (``inf`` when even the
uncontended fabric has no feasible plan).  Every policy is a *total*
deterministic order — ties always fall back to (arrival, id) — so admission
outcomes are reproducible across runs and dict orderings.
"""
from __future__ import annotations

from .requests import ServeRequest

INF = float("inf")


def fcfs(requests: list[ServeRequest],
         estimates: dict[int, float]) -> list[ServeRequest]:
    """First come, first served: by arrival time, then request id."""
    return sorted(requests, key=lambda r: (r.arrival_s, r.request_id))


def latency_greedy(requests: list[ServeRequest],
                   estimates: dict[int, float]) -> list[ServeRequest]:
    """Shortest-job-first on the pre-solved solo latency: cheap chains are
    admitted before expensive ones, maximizing accepted count under load."""
    return sorted(requests, key=lambda r: (estimates.get(r.request_id, INF),
                                           r.arrival_s, r.request_id))


def batch_size_descending(requests: list[ServeRequest],
                          estimates: dict[int, float]) -> list[ServeRequest]:
    """Largest batch first: heavy chains grab capacity while the fabric is
    empty (bin-packing style), small ones fill the leftovers."""
    return sorted(requests, key=lambda r: (-r.batch_size, r.arrival_s,
                                           r.request_id))


POLICIES = {
    "fcfs": fcfs,
    "latency-greedy": latency_greedy,
    "batch-desc": batch_size_descending,
}

POLICY_NAMES = tuple(POLICIES)
