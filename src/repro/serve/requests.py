"""Serve-layer requests: a service chain request with admission metadata.

The paper solves one R = (s, d, b, mode); the serve layer admits a *fleet* of
them onto one fabric.  A :class:`ServeRequest` adds what admission needs on
top of the paper's tuple: an id, an arrival time, the chain length K, the
candidate sets V^k, and a sustained execution rate (chain runs per second)
that converts the chain's smashed-data sizes into link-bandwidth demand.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass

from repro.core import (IF, SCHEDULES, SEQ, TR, ModelProfile, PhysicalNetwork,
                        ProblemInstance, ServiceChainRequest, candidate_sets)

INF = float("inf")


@dataclass(frozen=True)
class ServeRequest:
    """One admission-layer request: the paper's R plus fleet metadata."""

    request_id: int
    source: str
    destination: str
    batch_size: int
    mode: str  # IF | TR
    K: int
    candidates: tuple[tuple[str, ...], ...]
    arrival_s: float = 0.0
    rate_rps: float = 1.0  # sustained chain executions per second
    model_id: str = "model"
    schedule: str = SEQ  # seq | pipe (see docs/pipeline.md)
    n_microbatches: int = 1
    # Holding time: how long an admitted chain occupies its reservation before
    # departing (docs/sim.md).  inf = holds forever, the static-admission
    # behaviour; the event-driven ServeSim releases the chain's exact demand
    # at arrival_s (admit time) + duration_s.
    duration_s: float = INF
    # High-availability flag (docs/failures.md): admission also pre-plans a
    # placement/path-disjoint standby for this chain, promoted on failure.
    ha: bool = False

    def __post_init__(self) -> None:
        assert self.mode in (IF, TR)
        assert len(self.candidates) == self.K
        assert self.rate_rps > 0
        assert self.schedule in SCHEDULES
        assert self.n_microbatches >= 1
        assert self.duration_s > 0

    def chain_request(self) -> ServiceChainRequest:
        return ServiceChainRequest(self.model_id, self.source, self.destination,
                                   self.batch_size, self.mode,
                                   schedule=self.schedule,
                                   n_microbatches=self.n_microbatches)

    def candidate_lists(self) -> list[list[str]]:
        return [list(c) for c in self.candidates]

    def problem(self, net: PhysicalNetwork,
                profile: ModelProfile) -> ProblemInstance:
        """The request's :class:`ProblemInstance` on a concrete fabric."""
        return ProblemInstance(net, profile, self.chain_request(), self.K,
                               self.candidates)

    def solve_key(self, net: PhysicalNetwork, profile: ModelProfile) -> str:
        """Requests sharing this key are the same planning problem — the
        planner pre-solves each distinct key once per admission round.
        Delegates to :meth:`ProblemInstance.content_hash`, the same identity
        ``ScenarioSpec.instance_key`` uses, so serve presolve dedup and sweep
        instance grouping can never disagree."""
        return self.problem(net, profile).content_hash()


# The deterministic batch-size spread applied across a generated fleet (cycled
# per request id) so batch-aware policies have heterogeneous work to order.
BATCH_SPREAD = (1, 2, 4)

ARRIVALS = ("batch", "poisson")

# Holding-time models for generated fleets: "none" keeps every chain forever
# (duration_s = inf, the static behaviour), "fixed" holds each chain exactly
# `hold_time_s`, "exp" draws seeded Exponential(mean=hold_time_s) durations.
HOLD_MODELS = ("none", "fixed", "exp")


def generate_fleet(
    net: PhysicalNetwork,
    n_requests: int,
    source: str,
    destination: str,
    batch_size: int,
    mode: str,
    K: int,
    seed: int = 0,
    arrival: str = "batch",
    arrival_rate_rps: float = 1.0,
    rate_rps: float = 1.0,
    candidates: list[list[str]] | None = None,
    candidates_per_stage: int = 2,
    model_id: str = "model",
    batch_spread: tuple[int, ...] = BATCH_SPREAD,
    schedule: str = SEQ,
    n_microbatches: int = 1,
    hold_model: str = "none",
    hold_time_s: float = INF,
    ha: bool = False,
    train_share: float = 0.0,
) -> list[ServeRequest]:
    """Deterministic seeded fleet of `n_requests` chains on one fabric.

    Request i gets batch size ``batch_size * batch_spread[i % len]``, its own
    seeded candidate sets (unless `candidates` pins them for every request),
    an arrival time — 0.0 for ``arrival="batch"`` or cumulative
    Exponential(arrival_rate_rps) inter-arrivals for ``"poisson"`` — and a
    holding time from `hold_model` (see :data:`HOLD_MODELS`).  Holding times
    are drawn from a *dedicated* seeded stream, so a churn fleet and its
    ``hold_model="none"`` counterpart share identical arrivals/candidates.

    ``train_share > 0`` mixes training into the fleet: each request is TR
    with that probability (IF otherwise), overriding `mode`, drawn from its
    own dedicated seeded stream — the arrival/holding/candidate streams are
    untouched, so a mixed fleet and its all-IF (``train_share=0``) twin see
    identical arrival processes, and raising the share only flips individual
    requests IF -> TR (per-request draws are share-monotone).
    """
    if arrival not in ARRIVALS:
        raise ValueError(f"arrival must be one of {ARRIVALS}, got {arrival!r}")
    if hold_model not in HOLD_MODELS:
        raise ValueError(
            f"hold_model must be one of {HOLD_MODELS}, got {hold_model!r}")
    if hold_model != "none" and not (hold_time_s > 0 and math.isfinite(hold_time_s)):
        raise ValueError(f"hold_model={hold_model!r} needs a positive finite "
                         f"hold_time_s, got {hold_time_s!r}")
    if not 0.0 <= train_share <= 1.0:
        raise ValueError(f"train_share must be in [0, 1], got {train_share!r}")
    rng = random.Random(seed)
    hold_rng = random.Random(seed * 7919 + 1)  # independent of the arrival stream
    mode_rng = random.Random(seed * 5557 + 3)  # independent mode-mixing stream
    nodes = sorted(net.nodes)
    fleet = []
    t = 0.0
    for i in range(n_requests):
        if arrival == "poisson":
            t += rng.expovariate(arrival_rate_rps)
        if hold_model == "none":
            duration = INF
        elif hold_model == "fixed":
            duration = hold_time_s
        else:  # "exp"
            duration = hold_rng.expovariate(1.0 / hold_time_s)
        req_mode = mode
        if train_share > 0.0:
            req_mode = TR if mode_rng.random() < train_share else IF
        if candidates is not None:
            cands = candidates
        else:
            cands = candidate_sets(K, seed * 10007 + i, nodes, source,
                                   destination, candidates_per_stage)
        fleet.append(ServeRequest(
            request_id=i,
            source=source,
            destination=destination,
            batch_size=batch_size * batch_spread[i % len(batch_spread)],
            mode=req_mode,
            K=K,
            candidates=tuple(tuple(c) for c in cands),
            arrival_s=t,
            rate_rps=rate_rps,
            model_id=model_id,
            schedule=schedule,
            n_microbatches=n_microbatches,
            duration_s=duration,
            ha=ha,
        ))
    return fleet
