"""Residual-capacity accounting for concurrent service chains.

Accepted chains consume fabric capacity:

* **link bandwidth** — a chain executing ``rate_rps`` times per second ships
  ``b * delta_cut`` bytes per execution across every link of the cut's
  subpath, i.e. a sustained flow of ``b * delta * 8 * rate`` bits/s, charged
  against the link's forward rate (and its backward rate for the gradient
  flow when training, per the paper's R^BW_{i,j} convention).  *Pipelined*
  chains reserve their **steady-state occupancy** instead: a full pipeline
  streams one batch per bottleneck-stage period tau, so it can never ship
  faster than ``b * delta * 8 / tau`` bits/s — the effective reserved rate is
  ``min(rate_rps, 1/tau)``, which admits heavily-loaded pipelined chains where
  the naive accounting would reserve an unattainable flow (docs/pipeline.md);
* **node memory / disk** — a placed sub-model [lo, hi] holds its parameters
  plus the batch-scaled peak smashed data in memory (exactly the left side of
  constraints (14)-(15)) for as long as the chain is admitted.

:class:`ResidualState` tracks the running usage, answers "does this plan
still fit?", and materializes the *residual network* — the same topology with
capacities reduced by current usage — that capacity-aware replanning solves
against.  The paper's solvers know nothing about link capacities (their
formulation has none), so a replanned chain is always re-checked against the
residuals before being committed.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

from repro.core import (BW, FW, TR, EvalCache, LinkSpec, ModelProfile,
                        NodeSpec, PhysicalNetwork, Plan, PlanEvaluator,
                        round_trip_bottleneck_s)

from .requests import ServeRequest

# Absolute + relative slack for capacity comparisons (float sums of demands).
_EPS_ABS = 1e-9
_EPS_REL = 1e-12

# Floor (bits/s) a kept residual link is clamped to in the direction a mode
# does not reserve — keeps edge costs finite without admitting real flow.
_MIN_RATE_BPS = 1e-3


def _fits_cap(used: float, cap: float) -> bool:
    return used <= cap + _EPS_ABS + _EPS_REL * abs(cap)


def plan_footprint(plan: Plan) -> tuple[set[tuple[str, str]], set[str]]:
    """Every resource a committed plan depends on: the directed links of all
    its subpaths (including the zero-demand tail) and the nodes it places
    sub-models on *or* routes through.  This is the failure-domain of the
    plan — losing any of these kills the chain — which is deliberately wider
    than its :class:`PlanDemand` (a tail subpath reserves no bandwidth but
    still dies with its links)."""
    links: set[tuple[str, str]] = set()
    nodes: set[str] = set(plan.placement)
    for path in list(plan.paths) + [plan.tail_path]:
        nodes.update(path)
        links.update(zip(path, path[1:]))
    return links, nodes


@dataclass(frozen=True)
class PlanDemand:
    """The capacity footprint of one accepted chain."""

    link_fw_bps: dict[tuple[str, str], float]
    link_bw_bps: dict[tuple[str, str], float]
    node_mem_bytes: dict[str, float]
    node_disk_bytes: dict[str, float]


def effective_rate_rps(profile: ModelProfile, request: ServeRequest,
                       plan: Plan, net: PhysicalNetwork,
                       cache: EvalCache | None = None) -> float:
    """The execution rate a chain's bandwidth reservation is based on.

    Sequential chains reserve the requested sustained rate.  A pipelined chain
    (M > 1) streams microbatches through its bottleneck stage tau, completing
    at most one batch per tau seconds regardless of M, so its steady-state
    link occupancy corresponds to ``min(rate_rps, 1/tau)`` — reserving more
    would hold bandwidth the chain can physically never use.  A pipelined
    *training* chain's steady-state period is the round-trip
    ``tau_fw + tau_bw`` (the bottleneck stage runs one forward and one
    backward pass per microbatch — docs/training.md), so its clamp is
    ``min(rate_rps, 1/(tau_fw + tau_bw))``.  tau is computed against the
    *base* fabric's compute/link models so the reservation is stable across
    residual views."""
    chain = request.chain_request()
    if chain.microbatches() <= 1:
        return request.rate_rps
    ev = PlanEvaluator(net, profile, chain, cache=cache)
    tau = (round_trip_bottleneck_s(ev, plan) if chain.mode == TR
           else ev.bottleneck_s(plan))
    if tau <= 0.0:
        return request.rate_rps
    return min(request.rate_rps, 1.0 / tau)


def plan_demand(profile: ModelProfile, request: ServeRequest,
                plan: Plan, net: PhysicalNetwork | None = None,
                cache: EvalCache | None = None) -> PlanDemand:
    """Per-link flow (bits/s) and per-node memory/disk (bytes) of a plan.

    ``net`` enables the pipelined steady-state occupancy rate
    (:func:`effective_rate_rps`); without it the requested rate is reserved
    (the sequential behaviour).  ``cache`` collapses the repeated
    segment-compute lookups behind the bottleneck computation across the many
    fits/commit/conservation calls of an admission round."""
    b = request.batch_size
    training = request.mode == TR
    rate = (effective_rate_rps(profile, request, plan, net, cache)
            if net is not None else request.rate_rps)
    link_fw: dict[tuple[str, str], float] = defaultdict(float)
    link_bw: dict[tuple[str, str], float] = defaultdict(float)
    for k, path in enumerate(plan.paths):
        cut = plan.segments[k][1]
        fw_bps = b * profile.cut_bytes(cut, FW) * 8.0 * rate
        bw_bps = (b * profile.cut_bytes(cut, BW) * 8.0 * rate
                  if training else 0.0)
        for u, v in zip(path, path[1:]):
            link_fw[(u, v)] += fw_bps
            link_bw[(u, v)] += bw_bps
    # the tail subpath ships psi_K = 0 — no bandwidth reservation
    node_mem: dict[str, float] = defaultdict(float)
    node_disk: dict[str, float] = defaultdict(float)
    for (lo, hi), node in zip(plan.segments, plan.placement):
        mem = profile.seg_mem_bytes(lo, hi)
        mem += b * profile.seg_peak_smashed(lo, hi, request.mode)
        node_mem[node] += mem
        node_disk[node] += profile.seg_disk_bytes(lo, hi)
    return PlanDemand(dict(link_fw), dict(link_bw), dict(node_mem),
                      dict(node_disk))


@dataclass
class ResidualState:
    """Running capacity usage of one fabric under a set of accepted chains."""

    base: PhysicalNetwork
    used_link_fw: dict[tuple[str, str], float] = field(
        default_factory=lambda: defaultdict(float))
    used_link_bw: dict[tuple[str, str], float] = field(
        default_factory=lambda: defaultdict(float))
    used_mem: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    used_disk: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    committed: list[tuple[ServeRequest, Plan]] = field(default_factory=list)
    # shared compute-time memo for the pipelined bottleneck lookups behind
    # plan_demand — one cache per fabric state, reused across the whole round
    eval_cache: EvalCache = field(default_factory=EvalCache, repr=False)
    # per-key committed-chain counts mirroring each tally.  Float tallies
    # accumulate summation residue over long commit/release streams (each
    # `+= f` / `-= f` pair can leave ~ulp(peak) behind), so "this key should
    # be empty now" cannot be decided from the float alone once the residue
    # outgrows _EPS_ABS.  The counts are exact integer bookkeeping: when the
    # last contributing chain departs, release snaps the key to exactly zero
    # instead of trusting the drifted float.
    _cnt_link_fw: dict[tuple[str, str], int] = field(
        default_factory=lambda: defaultdict(int), repr=False, compare=False)
    _cnt_link_bw: dict[tuple[str, str], int] = field(
        default_factory=lambda: defaultdict(int), repr=False, compare=False)
    _cnt_mem: dict[str, int] = field(
        default_factory=lambda: defaultdict(int), repr=False, compare=False)
    _cnt_disk: dict[str, int] = field(
        default_factory=lambda: defaultdict(int), repr=False, compare=False)
    # Failure state (docs/failures.md): resources currently down.  A down
    # link is absent from every materialized view (capacity exactly zero); a
    # down node keeps routability metadata but loses its memory/disk *and*
    # every incident link, so nothing can be placed on it or routed through
    # it.  Both directions of an undirected failure are recorded.
    down_nodes: set[str] = field(default_factory=set)
    down_links: set[tuple[str, str]] = field(default_factory=set)
    # Reverse index resource -> {request_id: multiplicity}: which committed
    # chains' footprints touch each directed link / node, in commit order
    # (dict insertion order).  Lets a failure event find its victims in
    # O(affected) instead of scanning every committed chain.
    _hosted_links: dict[tuple[str, str], dict[int, int]] = field(
        default_factory=dict, repr=False, compare=False)
    _hosted_nodes: dict[str, dict[int, int]] = field(
        default_factory=dict, repr=False, compare=False)
    # request_id -> monotone commit sequence number, so victim sets gathered
    # from several resources can be ordered by commit time in O(n log n)
    _commit_seq: dict[int, int] = field(
        default_factory=dict, repr=False, compare=False)
    _seq_counter: int = field(default=0, repr=False, compare=False)
    # id(plan) -> (plan, links, nodes): memoized plan_footprint, identity-
    # checked like _demand_memo
    _footprint_memo: dict = field(default_factory=dict, repr=False,
                                  compare=False)
    # (request demand identity, id(plan)) -> (plan, profile, PlanDemand).
    # One admission computes the same demand three times (fits, commit,
    # eventually release) and a streaming gateway sees the same few
    # (shape, snapshot-plan) pairs thousands of times; the demand is a pure
    # function of those inputs on the fixed base fabric, so memoize it.  The
    # stored plan/profile references both pin the ids against reuse and are
    # identity-checked on lookup.
    _demand_memo: dict = field(default_factory=dict, repr=False, compare=False)
    # lazily-built keep-saturated materialized view, updated *incrementally*
    # on commit/release (only the links/nodes a plan touches) so the
    # per-admission latency evaluation does not rebuild the whole topology
    _live: PhysicalNetwork | None = field(default=None, init=False,
                                          repr=False, compare=False)

    # ---------------------------------------------------------------- queries
    def _demand(self, profile: ModelProfile, request: ServeRequest,
                plan: Plan) -> PlanDemand:
        """Memoized :func:`plan_demand` (see ``_demand_memo``): keyed by the
        request fields the demand is a function of plus the plan's identity,
        so clones of a recurring shape admitted against a cached snapshot
        plan share one computation."""
        ident = (request.model_id, request.source, request.destination,
                 request.batch_size, request.mode, request.rate_rps,
                 request.schedule, request.n_microbatches)
        key = (ident, id(plan))
        hit = self._demand_memo.get(key)
        if hit is not None and hit[0] is plan and hit[1] is profile:
            return hit[2]
        d = plan_demand(profile, request, plan, self.base, self.eval_cache)
        self._demand_memo[key] = (plan, profile, d)
        return d

    def _footprint(self, plan: Plan) -> tuple[set[tuple[str, str]], set[str]]:
        """Memoized :func:`plan_footprint` (identity-checked, like
        :meth:`_demand`)."""
        hit = self._footprint_memo.get(id(plan))
        if hit is not None and hit[0] is plan:
            return hit[1], hit[2]
        links, nodes = plan_footprint(plan)
        self._footprint_memo[id(plan)] = (plan, links, nodes)
        return links, nodes

    def footprint_clear(self, plan: Plan) -> bool:
        """Does `plan` avoid every down resource?  A down link or node has
        exactly zero capacity — any plan whose footprint touches one cannot
        be committed, including zero-demand tail subpaths."""
        if not (self.down_nodes or self.down_links):
            return True
        links, nodes = self._footprint(plan)
        if self.down_nodes and not self.down_nodes.isdisjoint(nodes):
            return False
        if self.down_links and not self.down_links.isdisjoint(links):
            return False
        return True

    def fits(self, profile: ModelProfile, request: ServeRequest,
             plan: Plan) -> bool:
        """Would committing `plan` keep every link/node within capacity?
        Down resources have zero capacity: any plan touching one never fits."""
        if not self.footprint_clear(plan):
            return False
        d = self._demand(profile, request, plan)
        for (u, v), f in d.link_fw_bps.items():
            spec = self.base.links[(u, v)]
            if not _fits_cap(self.used_link_fw[(u, v)] + f, spec.bw_fw):
                return False
            g = d.link_bw_bps.get((u, v), 0.0)
            if g and not _fits_cap(self.used_link_bw[(u, v)] + g, spec.bw_bw):
                return False
        for n, m in d.node_mem_bytes.items():
            if not _fits_cap(self.used_mem[n] + m,
                             self.base.nodes[n].mem_capacity):
                return False
        for n, s in d.node_disk_bytes.items():
            if not _fits_cap(self.used_disk[n] + s,
                             self.base.nodes[n].disk_capacity):
                return False
        return True

    def _index_commit(self, request: ServeRequest, plan: Plan) -> None:
        rid = request.request_id
        links, nodes = self._footprint(plan)
        for link in links:
            hosted = self._hosted_links.setdefault(link, {})
            hosted[rid] = hosted.get(rid, 0) + 1
        for node in nodes:
            hosted = self._hosted_nodes.setdefault(node, {})
            hosted[rid] = hosted.get(rid, 0) + 1
        self._seq_counter += 1
        cnt, seq = self._commit_seq.get(rid, (0, self._seq_counter))
        self._commit_seq[rid] = (cnt + 1, seq)

    def _index_release(self, request: ServeRequest, plan: Plan) -> None:
        rid = request.request_id
        links, nodes = self._footprint(plan)
        for key, index in ((links, self._hosted_links),
                           (nodes, self._hosted_nodes)):
            for k in key:
                hosted = index[k]
                hosted[rid] -= 1
                if hosted[rid] <= 0:
                    del hosted[rid]
                if not hosted:
                    del index[k]
        cnt, seq = self._commit_seq[rid]
        if cnt <= 1:
            del self._commit_seq[rid]
        else:
            self._commit_seq[rid] = (cnt - 1, seq)

    def commit(self, profile: ModelProfile, request: ServeRequest,
               plan: Plan) -> None:
        if not self.footprint_clear(plan):
            raise ValueError(
                f"commit of chain request_id={request.request_id} touches a "
                f"down resource (down_nodes={sorted(self.down_nodes)}, "
                f"down_links={sorted(self.down_links)})")
        d = self._demand(profile, request, plan)
        for k, f in d.link_fw_bps.items():
            self.used_link_fw[k] += f
            self._cnt_link_fw[k] += 1
        for k, g in d.link_bw_bps.items():
            self.used_link_bw[k] += g
            self._cnt_link_bw[k] += 1
        for n, m in d.node_mem_bytes.items():
            self.used_mem[n] += m
            self._cnt_mem[n] += 1
        for n, s in d.node_disk_bytes.items():
            self.used_disk[n] += s
            self._cnt_disk[n] += 1
        self.committed.append((request, plan))
        self._index_commit(request, plan)
        self._update_live(d)

    def release(self, profile: ModelProfile, request: ServeRequest,
                plan: Plan) -> None:
        """Exact inverse of :meth:`commit`: a departing chain returns its
        :class:`PlanDemand` to the fabric.

        The demand comes from the same memo :meth:`commit` populated, so the
        subtracted floats are bit-identical to the ones :meth:`commit`
        added; a key whose last contributor departs (per the exact integer
        counts) is snapped to exactly zero — summation residue from hundreds
        of commit/release cycles on a hot key can exceed any fixed epsilon,
        so emptiness is decided by the count, not the float.  A fully drained
        state therefore compares clean against a fresh one.  Raises ``KeyError``
        if the (request, plan) pair was never committed — releasing a chain
        twice (or one that was never admitted) is a caller bug, and silently
        subtracting would break :meth:`conservation_ok`, which re-derives
        usage from the committed list."""
        for i, (req, pl) in enumerate(self.committed):
            if req == request and pl == plan:
                del self.committed[i]
                break
        else:
            raise KeyError(f"release of uncommitted chain "
                           f"request_id={request.request_id}")
        self._index_release(request, plan)
        d = self._demand(profile, request, plan)
        for tally, cnt, demand in (
                (self.used_link_fw, self._cnt_link_fw, d.link_fw_bps),
                (self.used_link_bw, self._cnt_link_bw, d.link_bw_bps),
                (self.used_mem, self._cnt_mem, d.node_mem_bytes),
                (self.used_disk, self._cnt_disk, d.node_disk_bytes)):
            for k, v in demand.items():
                cnt[k] -= 1
                if cnt[k] <= 0:
                    # last contributor gone: exact-zero snap (see docstring)
                    del cnt[k]
                    tally.pop(k, None)
                    continue
                tally[k] -= v
                if abs(tally[k]) <= _EPS_ABS:
                    del tally[k]
        self._update_live(d)

    # --------------------------------------------------------------- failures
    def _order_victims(self, ids: set[int]) -> list[int]:
        """Victim request ids in commit order (oldest chain first)."""
        return sorted(ids, key=lambda rid: self._commit_seq[rid][1])

    def chains_on_link(self, u: str, v: str) -> list[int]:
        """Committed chains whose footprint crosses link (u, v) in either
        direction, in commit order — O(affected) via the reverse index."""
        ids: set[int] = set()
        ids.update(self._hosted_links.get((u, v), ()))
        ids.update(self._hosted_links.get((v, u), ()))
        return self._order_victims(ids)

    def chains_on_node(self, node: str) -> list[int]:
        """Committed chains hosted on / routed through `node` or crossing any
        of its incident links, in commit order.  A dead node takes its links
        with it, so transit chains are victims too."""
        ids: set[int] = set(self._hosted_nodes.get(node, ()))
        for (u, v), hosted in self._hosted_links.items():
            if u == node or v == node:
                ids.update(hosted)
        return self._order_victims(ids)

    def fail_link(self, u: str, v: str) -> list[int]:
        """Mark the undirected link {u, v} down; returns the affected chain
        ids (commit order).  The caller (the migration engine) must release
        every victim — this method only flips the capacity state."""
        victims = self.chains_on_link(u, v)
        self.down_links.add((u, v))
        self.down_links.add((v, u))
        self._live = None  # full rebuild: the live view loses the link
        return victims

    def fail_node(self, node: str) -> list[int]:
        """Mark `node` down (memory/disk and every incident link gone);
        returns the affected chain ids (commit order)."""
        victims = self.chains_on_node(node)
        self.down_nodes.add(node)
        self._live = None
        return victims

    def recover_link(self, u: str, v: str) -> None:
        self.down_links.discard((u, v))
        self.down_links.discard((v, u))
        self._live = None  # full rebuild: the live view regains the link

    def recover_node(self, node: str) -> None:
        self.down_nodes.discard(node)
        self._live = None

    def _link_down(self, u: str, v: str) -> bool:
        return (u in self.down_nodes or v in self.down_nodes
                or (u, v) in self.down_links)

    def down_ok(self) -> bool:
        """No committed chain's footprint touches a down resource — the
        invariant the replay verifier asserts after every instant with
        failure events (a down resource has exactly zero capacity, so any
        surviving tenancy would be an accounting bug)."""
        for link in self.down_links:
            if self._hosted_links.get(link):
                return False
        for node in self.down_nodes:
            if self.chains_on_node(node):
                return False
        return True

    # ---------------------------------------------------------- materialization
    def materialize(self, mode: str | None = None,
                    keep_saturated: bool = False) -> PhysicalNetwork:
        """The residual network: capacities minus current usage.

        Links with no forward residual are dropped (they can carry no smashed
        data); for training chains (`mode=TR`) links with no backward residual
        are dropped too, since the gradient flow reserves that direction.  A
        kept link's unreserved direction is clamped to a tiny positive floor
        so edge costs stay finite.  Nodes always remain routable — a node with
        exhausted memory can still forward traffic, it just cannot host a
        sub-model (its residual capacity is 0, so `segment_fits` rejects it).

        ``keep_saturated=True`` keeps every link (rates clamped to the floor
        instead of dropping) — used to *evaluate* an admitted plan's latency,
        where zero-demand tail subpaths may legitimately cross saturated
        links.  Prefer :meth:`live_view` for that: it maintains the same view
        incrementally instead of rebuilding the topology per admission.
        """
        out = PhysicalNetwork()
        for name, spec in self.base.nodes.items():
            if name in self.down_nodes:
                # a down node stays in the topology (solvers index candidate
                # nodes by name) but with zero hosting capacity; its links
                # are dropped below, so nothing can route through it either
                out.add_node(NodeSpec(name, spec.compute, 0.0, 0.0))
                continue
            out.add_node(NodeSpec(
                name, spec.compute,
                max(0.0, spec.mem_capacity - self.used_mem[name]),
                max(0.0, spec.disk_capacity - self.used_disk[name])))
        for (u, v), spec in self.base.links.items():
            if self._link_down(u, v):
                continue  # down = capacity exactly zero, even keep_saturated
            fw = spec.bw_fw - self.used_link_fw[(u, v)]
            bw = spec.bw_bw - self.used_link_bw[(u, v)]
            if not keep_saturated:
                if fw <= 0.0:
                    continue
                if mode == TR and bw <= 0.0:
                    continue
            out.add_link(u, v, LinkSpec(max(fw, _MIN_RATE_BPS),
                                        max(bw, _MIN_RATE_BPS),
                                        spec.delay_fw, spec.delay_bw))
        return out

    def live_view(self) -> PhysicalNetwork:
        """The keep-saturated residual view, maintained *incrementally*.

        Bit-identical to ``materialize(keep_saturated=True)`` at every state
        — the update below recomputes exactly the same clamp expressions from
        the same running tallies, but only for the links/nodes the committed
        (released) plan's demand touches, so the per-admission latency
        evaluation in a long-running gateway costs O(plan) instead of
        O(topology).  Treat as read-only; it is patched in place on every
        commit/release.
        """
        if self._live is None:
            self._live = self.materialize(keep_saturated=True)
        return self._live

    def _update_live(self, d: PlanDemand) -> None:
        live = self._live
        if live is None:
            return
        for (u, v) in set(d.link_fw_bps) | set(d.link_bw_bps):
            if self._link_down(u, v):
                continue  # a victim release must not resurrect a down link
            spec = self.base.links[(u, v)]
            fw = spec.bw_fw - self.used_link_fw[(u, v)]
            bw = spec.bw_bw - self.used_link_bw[(u, v)]
            live.links[(u, v)] = LinkSpec(max(fw, _MIN_RATE_BPS),
                                          max(bw, _MIN_RATE_BPS),
                                          spec.delay_fw, spec.delay_bw)
        for name in set(d.node_mem_bytes) | set(d.node_disk_bytes):
            if name in self.down_nodes:
                continue  # rebuilt with zero capacity on the next full view
            spec = self.base.nodes[name]
            live.nodes[name] = NodeSpec(
                name, spec.compute,
                max(0.0, spec.mem_capacity - self.used_mem[name]),
                max(0.0, spec.disk_capacity - self.used_disk[name]))
        # direct spec assignment bypasses add_link/add_node invalidation
        live.clear_routing_cache()

    # ----------------------------------------------------------- verification
    def conservation_ok(self, profile: ModelProfile) -> bool:
        """Recompute usage from the committed plans and confirm (a) it matches
        the running tallies, (b) nothing exceeds base capacity, and (c) the
        resource -> hosting-chains reverse index matches a fresh re-derivation
        (it is what failure events trust to find their victims)."""
        want_links: dict[tuple[str, str], dict[int, int]] = {}
        want_nodes: dict[str, dict[int, int]] = {}
        for request, plan in self.committed:
            links, nodes = self._footprint(plan)
            rid = request.request_id
            for link in links:
                hosted = want_links.setdefault(link, {})
                hosted[rid] = hosted.get(rid, 0) + 1
            for node in nodes:
                hosted = want_nodes.setdefault(node, {})
                hosted[rid] = hosted.get(rid, 0) + 1
        if (want_links != self._hosted_links
                or want_nodes != self._hosted_nodes):
            return False
        fw: dict[tuple[str, str], float] = defaultdict(float)
        bwd: dict[tuple[str, str], float] = defaultdict(float)
        mem: dict[str, float] = defaultdict(float)
        disk: dict[str, float] = defaultdict(float)
        for request, plan in self.committed:
            d = self._demand(profile, request, plan)
            for k, f in d.link_fw_bps.items():
                fw[k] += f
            for k, g in d.link_bw_bps.items():
                bwd[k] += g
            for n, m in d.node_mem_bytes.items():
                mem[n] += m
            for n, s in d.node_disk_bytes.items():
                disk[n] += s
        for tracked, recomputed in ((self.used_link_fw, fw),
                                    (self.used_link_bw, bwd),
                                    (self.used_mem, mem),
                                    (self.used_disk, disk)):
            keys = set(tracked) | set(recomputed)
            for k in keys:
                a, b = tracked.get(k, 0.0), recomputed.get(k, 0.0)
                if abs(a - b) > _EPS_ABS + _EPS_REL * max(abs(a), abs(b)):
                    return False
        for (u, v), f in fw.items():
            if not _fits_cap(f, self.base.links[(u, v)].bw_fw):
                return False
        for (u, v), g in bwd.items():
            if g and not _fits_cap(g, self.base.links[(u, v)].bw_bw):
                return False
        for n, m in mem.items():
            if not _fits_cap(m, self.base.nodes[n].mem_capacity):
                return False
        for n, s in disk.items():
            if not _fits_cap(s, self.base.nodes[n].disk_capacity):
                return False
        return True
