"""ServeSim — deterministic event-driven serving under churn (docs/sim.md).

The static :meth:`ServePlanner.admit` round admits a fleet once and every
accepted chain holds its reservation forever.  Real serving is a *process*:
chains arrive, hold fabric capacity for a finite time, and leave — the
multi-cloud SFC setting (Bhamare et al.) and the companion SFC architecture
paper (Hara & Sasabe) both evaluate admission over time.  `ServeSim` replays
that process exactly:

* **events** — one arrival event per distinct arrival timestamp (simultaneous
  arrivals are ordered by the admission policy), one departure event per
  admitted chain with a finite ``duration_s``.  Events are processed in
  timestamp order; at equal timestamps departures are processed first, so
  capacity freed "now" is available to arrivals "now".
* **arrivals** run the same snapshot-fits / residual-replan / commit
  admission as the static round (the shared
  :class:`~repro.serve.admission.AdmissionCore`), against the residual state
  *at that instant*.
* **departures** release the departing chain's exact :class:`PlanDemand`
  through :meth:`ResidualState.release` — bit-identical floats to the ones
  its commit added, so conservation holds at every event.
* an optional **retry queue** parks capacity-blocked requests and re-attempts
  them (in arrival order) whenever a departure frees room; requests still
  queued when the event stream drains are finally rejected.

* **failures** (docs/failures.md) — ``link_down`` / ``node_down`` /
  ``recover`` events interleave with the stream: same-instant failures are
  applied as one batch *after* that instant's departures and *before* its
  arrivals, victims are detected through the ResidualState reverse index,
  released, and migrated (or parked/killed) by
  :meth:`AdmissionCore.apply_failures`.

With every ``duration_s = inf`` there are no departures and the simulation
degenerates to the static admission round — bit-for-bit, which is the
anchoring invariant (`tests/test_sim.py`).  With no failure events the run is
bit-for-bit the PR 7 behaviour (`tests/test_failures.py`).

`replay_verify_sim` re-verifies a (possibly reloaded) trace from scratch:
plans re-checked structurally, every commit re-checked against the residuals
at its admission instant (a down resource has exactly zero capacity while
down), migration audit entries re-derived, and conservation re-checked after
*every* event.  :func:`replay_verify_sim_report` returns the first violation
as an actionable message instead of a bare bool.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import ModelProfile, PhysicalNetwork, PlanEvaluator

from .admission import (INF, AdmissionCore, ServedRequest, _plan_from_dict)
from .failures import FailureEvent, MigrationCostModel, migration_delta
from .planner import ServeOutcome, ServePlanner
from .policies import POLICIES
from .requests import ServeRequest
from .residual import ResidualState

# Event priorities at equal timestamps: departures release capacity first,
# then failures hit the settled fabric, then arrivals (and retry/restore
# drains) contend for what is left.
_DEPART, _FAIL, _ARRIVE = 0, 1, 2


@dataclass
class SimOutcome(ServeOutcome):
    """One simulation run: the static round's fields plus the event trace.

    ``served`` is in *decision* order (the order admit/reject decisions were
    made); accepted records carry ``admit_s`` / ``depart_s`` / ``n_retries``,
    which is the full trace — `replay_verify_sim` needs nothing else.
    ``timeline`` is the per-event audit log (admit/depart/reject with the
    concurrent-chain count after each event), from which the time-series
    metrics derive.
    """

    retry: bool = False
    horizon_s: float = 0.0  # timestamp of the last processed event
    timeline: list = field(default_factory=list)

    # ------------------------------------------------------------ churn metrics
    @property
    def n_departed(self) -> int:
        return sum(1 for e in self.timeline if e["event"] == "depart")

    @property
    def n_retried(self) -> int:
        """Chains admitted only after >= 1 failed capacity attempt."""
        return sum(1 for s in self.served if s.accepted and s.n_retries > 0)

    @property
    def n_blocked(self) -> int:
        """Requests finally rejected for capacity (not infeasibility)."""
        return sum(1 for s in self.served
                   if not s.accepted and s.reason == "capacity")

    @property
    def blocking_probability(self) -> float:
        """Erlang-style blocking: capacity rejections over offered requests
        (``no-plan`` rejections are infeasible on an empty fabric too, so
        they are not *blocking* — they count in the denominator only)."""
        return self.n_blocked / self.n_requests if self.served else 0.0

    @property
    def peak_concurrent(self) -> int:
        return max((e["concurrent"] for e in self.timeline), default=0)

    def concurrent_curve(self) -> list[tuple[float, int]]:
        """(t, concurrently held chains) after every event."""
        return [(e["t"], e["concurrent"]) for e in self.timeline]

    def acceptance_curve(self) -> list[tuple[float, float]]:
        """(t, cumulative accepted / decided) after every admit/reject."""
        out, acc, dec = [], 0, 0
        for e in self.timeline:
            if e["event"] == "admit":
                acc, dec = acc + 1, dec + 1
            elif e["event"] == "reject":
                dec += 1
            else:
                continue
            out.append((e["t"], acc / dec))
        return out

    def epoch_percentiles(self, n_epochs: int = 4,
                          qs: tuple[float, ...] = (50, 95, 99)) -> list[dict]:
        """Latency percentiles of admitted chains, bucketed by admit-time
        epoch (the horizon split into `n_epochs` equal windows) — shows how
        contention moves the latency distribution over the run."""
        end = self.horizon_s
        width = end / n_epochs if end > 0 else 1.0

        def admit_time(s: ServedRequest) -> float:
            # explicit None check: admit_s == 0.0 is a legitimate admission
            # at t=0, not a missing timestamp (records imported from a static
            # round fall back to their arrival instant)
            return s.admit_s if s.admit_s is not None else s.request.arrival_s

        epochs = []
        for e in range(n_epochs):
            lo, hi = e * width, (e + 1) * width
            lats = [s.latency_s for s in self.served
                    if s.accepted and s.latency_s is not None
                    and lo <= admit_time(s)
                    and (admit_time(s) < hi or e == n_epochs - 1)]
            row = {"epoch": e, "start_s": lo, "end_s": hi, "n": len(lats)}
            for q in qs:
                row[f"p{int(q)}"] = (float(np.percentile(np.asarray(lats), q))
                                     if lats else None)
            epochs.append(row)
        return epochs

    # -------------------------------------------------------- failure metrics
    # Derived from the served records alone, so they work for any driver's
    # outcome (sim, gateway); all-zero on failure-free runs.
    @property
    def n_failed(self) -> int:
        """Disruption incidents: every time a failure took a chain down
        (counting each migration of a multiply-hit chain, plus kills)."""
        return self.n_restored + self.n_killed

    @property
    def n_restored(self) -> int:
        """Disruptions resolved by a successful migration."""
        return sum(len(s.migrations) for s in self.served if s.accepted)

    @property
    def n_killed(self) -> int:
        """Chains that ended down: released by a failure, never restored."""
        return sum(1 for s in self.served
                   if s.accepted and s.failed_s is not None)

    @property
    def restored_fraction(self) -> float | None:
        return self.n_restored / self.n_failed if self.n_failed else None

    def restore_latencies(self) -> list[float]:
        """Disruption seconds of every completed migration (outage +
        restage time, per the run's :class:`MigrationCostModel`)."""
        return [m["disruption_s"] for s in self.served if s.accepted
                for m in s.migrations]

    def restore_percentiles(self,
                            qs: tuple[float, ...] = (50, 95, 99)) -> dict:
        lats = self.restore_latencies()
        if not lats:
            return {f"p{int(q)}": None for q in qs}
        arr = np.asarray(sorted(lats))
        return {f"p{int(q)}": float(np.percentile(arr, q)) for q in qs}

    @property
    def moved_bytes(self) -> float:
        """Parameter + smashed bytes restaged by all migrations."""
        return sum(m["moved_bytes"] for s in self.served if s.accepted
                   for m in s.migrations)

    def failure_summary(self) -> dict:
        pct = self.restore_percentiles()
        return {
            "n_failed": self.n_failed,
            "n_restored": self.n_restored,
            "n_killed": self.n_killed,
            "restored_fraction": self.restored_fraction,
            "restore_p50_s": pct["p50"],
            "restore_p95_s": pct["p95"],
            "restore_p99_s": pct["p99"],
            "moved_bytes": self.moved_bytes,
            "moved_param_bytes": sum(
                m["moved_param_bytes"] for s in self.served if s.accepted
                for m in s.migrations),
            "moved_smashed_bytes": sum(
                m["moved_smashed_bytes"] for s in self.served if s.accepted
                for m in s.migrations),
        }

    def _has_failures(self) -> bool:
        return bool(self.n_failed or getattr(self, "failures", None))

    def sim_summary(self) -> dict:
        """The JSON-able churn block sweep artifacts store alongside the
        static summary fields (``ScenarioResult.sim``)."""
        s = {
            "retry": self.retry,
            "horizon_s": self.horizon_s,
            "n_departed": self.n_departed,
            "n_retried": self.n_retried,
            "n_blocked": self.n_blocked,
            "blocking_probability": self.blocking_probability,
            "peak_concurrent": self.peak_concurrent,
            "concurrent_curve": [[t, n] for t, n in self.concurrent_curve()],
            "acceptance_curve": [[t, a] for t, a in self.acceptance_curve()],
            "epochs": self.epoch_percentiles(),
        }
        # only on failure runs, so failure-free artifacts stay bit-identical
        if self._has_failures():
            s["failures"] = self.failure_summary()
        return s

    def summary(self) -> dict:
        s = super().summary()
        s.update({
            "retry": self.retry,
            "horizon_s": self.horizon_s,
            "n_departed": self.n_departed,
            "n_retried": self.n_retried,
            "blocking_probability": self.blocking_probability,
            "peak_concurrent": self.peak_concurrent,
        })
        if self._has_failures():
            s["failures"] = self.failure_summary()
        return s


@dataclass
class FailureOutcome(SimOutcome):
    """A simulation run with substrate failures: the sim trace plus the
    applied failure schedule (`ServeSim.run(..., failures=...)` returns this
    whenever a schedule — even an empty one — was supplied).  The
    survivability metrics live on :class:`SimOutcome` (they derive from the
    served records); the schedule rides along for replay verification."""

    failures: list = field(default_factory=list)  # FailureEvent, time order

    def sim_summary(self) -> dict:
        s = super().sim_summary()
        s.setdefault("failures", self.failure_summary())
        s["failure_events"] = [ev.to_dict() for ev in self.failures]
        return s


class ServeSim:
    """Event-driven dynamic admission on one fabric.

    Thin orchestration over the existing machinery: pre-solve + per-arrival
    admission delegate to a :class:`ServePlanner` (same solver registry,
    caches, and replan behaviour), capacity accounting to
    :class:`ResidualState` (`commit` on admit, `release` on departure).
    """

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 solver: str = "bcd", replan: bool = True,
                 retry: bool = False, cache=None,
                 solver_kwargs: dict | None = None,
                 cost_model: MigrationCostModel | None = None):
        self.planner = ServePlanner(net, profile, solver=solver, replan=replan,
                                    cache=cache, solver_kwargs=solver_kwargs)
        self.retry = retry
        self.cost_model = cost_model

    def run(self, requests: list[ServeRequest], policy: str = "fcfs",
            failures: list[FailureEvent] | None = None) -> SimOutcome:
        """Run the fleet through the event loop.  ``failures`` injects a
        substrate failure schedule (docs/failures.md) and switches the return
        type to :class:`FailureOutcome`; without it the run is bit-for-bit
        the failure-free simulator."""
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {sorted(POLICIES)}")
        t0 = time.perf_counter()
        planner = self.planner
        presolved, keys, estimates = planner.presolve(requests)

        # one arrival event per distinct timestamp; the admission policy
        # orders simultaneous arrivals (so a batch fleet reproduces the
        # static round's policy order exactly)
        batches: dict[float, list[ServeRequest]] = {}
        for r in requests:
            batches.setdefault(r.arrival_s, []).append(r)
        tick = itertools.count()  # deterministic heap tie-break
        heap: list[tuple] = [(t, _ARRIVE, next(tick), batch)
                             for t, batch in batches.items()]
        fail_events = sorted(failures or [], key=lambda e: e.t_s)
        heap += [(ev.t_s, _FAIL, next(tick), ev) for ev in fail_events]
        heapq.heapify(heap)

        core = AdmissionCore(planner, presolved, keys, retry=self.retry,
                             record_events=True, cost_model=self.cost_model)
        horizon = 0.0

        def push_depart(rec: ServedRequest) -> None:
            if rec.depart_s is not None:
                heapq.heappush(heap, (rec.depart_s, _DEPART, next(tick), rec))

        while heap:
            t, prio, _, payload = heapq.heappop(heap)
            horizon = max(horizon, t)
            if prio == _ARRIVE:
                for r in POLICIES[policy](payload, estimates):
                    rec = core.try_admit(r, t)
                    if rec is not None:
                        push_depart(rec)
                continue
            if prio == _DEPART:
                core.depart(payload, t)
            else:  # _FAIL: this instant's failures apply as one batch
                evs = [payload]
                while heap and heap[0][0] == t and heap[0][1] == _FAIL:
                    evs.append(heapq.heappop(heap)[3])
                core.apply_failures(evs, t)
            # once this instant's departures *and* failures have all
            # settled, re-attempt parked victims, then the retry queue (in
            # arrival order), against the freed/degraded residuals
            more_now = (heap and heap[0][0] == t and heap[0][1] != _ARRIVE)
            if not more_now:
                if core.fail_parked:
                    core.drain_failed(t)
                if self.retry and core.pending:
                    for rec in core.drain_pending(t):
                        push_depart(rec)

        # the event stream drained with these still queued: final rejections
        core.reject_pending(horizon)
        assert core.conservation_ok()
        kw = dict(
            policy=policy, solver=planner.solver_name, served=core.served,
            wall_time_s=time.perf_counter() - t0, n_presolved=len(presolved),
            cache_stats=planner.round_cache_stats(),
            retry=self.retry, horizon_s=horizon, timeline=core.timeline)
        if failures is None:
            return SimOutcome(**kw)
        return FailureOutcome(failures=fail_events, **kw)


# Replay priorities at equal timestamps, mirroring the simulator's causal
# order within one instant: departures release first, then failure marks
# flip capacity, then failure releases take victims down, then drain-phase
# commits (migrations, restores, retries), then first-try arrival commits.
_R_DEPART, _R_MARK, _R_RELEASE, _R_COMMIT, _R_FIRST = 0, 1, 2, 3, 4


def replay_verify_sim(net: PhysicalNetwork, profile: ModelProfile,
                      served: list[ServedRequest],
                      failures: list[FailureEvent] | None = None) -> bool:
    """Re-verify a (possibly reloaded) sim trace from scratch; see
    :func:`replay_verify_sim_report` for the checks (this is its bool
    form — the two never disagree)."""
    return replay_verify_sim_report(net, profile, served, failures) is None


def replay_verify_sim_report(net: PhysicalNetwork, profile: ModelProfile,
                             served: list[ServedRequest],
                             failures: list[FailureEvent] | None = None
                             ) -> str | None:
    """Re-verify a sim/gateway trace event-by-event; ``None`` if it holds,
    else an actionable description of the first violation.

    Rebuilds the event stream from the served records (commit at ``admit_s``,
    each migration entry as a release at ``t_down`` + recommit of the next
    plan at ``t_restored``, kills as final releases at ``failed_s``, release
    at ``depart_s``) interleaved with the failure schedule's capacity marks,
    and replays it against a fresh :class:`ResidualState`:

    * every plan is structurally re-checked against the base topology;
    * every commit must fit the residuals *at its instant* — including the
      exactly-zero capacity of any resource down at that instant;
    * every migration entry's moved bytes must re-derive from its old/new
      plans, and its disruption must cover the outage interval;
    * conservation (tallies, base capacities, and the resource->chains
      reverse index) must hold after every single event;
    * after each instant with failure marks, no committed chain may span a
      down resource (``ResidualState.down_ok``).
    """
    events: list[tuple[float, int, int, tuple]] = []
    for i, ev in enumerate(sorted(failures or [], key=lambda e: e.t_s)):
        events.append((ev.t_s, _R_MARK, i, ("mark", ev, None)))
    for seq, s in enumerate(served):
        if not s.accepted:
            continue
        rid = s.request.request_id
        if s.plan is None:
            return f"accepted record request_id={rid} has no plan"
        t = s.admit_s if s.admit_s is not None else s.request.arrival_s
        # the chain's plan timeline: plans[j] holds from its commit to the
        # j-th migration's release (the record's plan is the current one)
        try:
            plans = [_plan_from_dict(m["old_plan"]) for m in s.migrations]
        except (KeyError, TypeError):
            return (f"request_id={rid}: malformed migration entries "
                    f"(missing old_plan)")
        plans.append(s.plan)
        first = _R_COMMIT if s.n_retries > 0 else _R_FIRST
        events.append((t, first, seq, ("commit", s, plans[0])))
        prev_restored = t
        for j, m in enumerate(s.migrations):
            if m["t_down"] < prev_restored - _EPS_T or \
                    m["t_restored"] < m["t_down"] - _EPS_T:
                return (f"request_id={rid}: migration {j} timestamps out of "
                        f"order (down {m['t_down']}, restored "
                        f"{m['t_restored']})")
            prev_restored = m["t_restored"]
            want = migration_delta(profile, s.request, plans[j], plans[j + 1])
            got = m.get("moved_bytes")
            if got is None or abs(got - want["moved_bytes"]) > \
                    1e-6 * max(1.0, want["moved_bytes"]):
                return (f"request_id={rid}: migration {j} moved_bytes "
                        f"mismatch (recorded {got}, re-derived "
                        f"{want['moved_bytes']})")
            if m["disruption_s"] < (m["t_restored"] - m["t_down"]) - _EPS_T:
                return (f"request_id={rid}: migration {j} disruption_s "
                        f"{m['disruption_s']} shorter than its outage "
                        f"interval")
            events.append((m["t_down"], _R_RELEASE, seq,
                           ("release", s, plans[j])))
            events.append((m["t_restored"], _R_COMMIT, seq,
                           ("commit", s, plans[j + 1])))
        if s.failed_s is not None:  # killed: released by a failure, never back
            if s.failed_s < prev_restored - _EPS_T:
                return (f"request_id={rid}: failed_s {s.failed_s} precedes "
                        f"its last restoration at {prev_restored}")
            events.append((s.failed_s, _R_RELEASE, seq,
                           ("release", s, plans[-1])))
        elif s.depart_s is not None and s.depart_s != INF:
            events.append((s.depart_s, _R_DEPART, seq,
                           ("release", s, plans[-1])))
    events.sort(key=lambda e: (e[0], e[1], e[2]))
    state = ResidualState(net)
    i = 0
    while i < len(events):
        t = events[i][0]
        saw_mark = False
        while i < len(events) and events[i][0] == t:
            _, _, _, (kind, payload, plan) = events[i]
            i += 1
            if kind == "mark":
                saw_mark = True
                ev = payload
                if ev.kind == "recover":
                    if ev.node is not None:
                        state.recover_node(ev.node)
                    else:
                        state.recover_link(*ev.link)
                elif ev.kind == "node_down":
                    state.fail_node(ev.node)
                else:
                    state.fail_link(*ev.link)
                continue
            s = payload
            rid = s.request.request_id
            if kind == "commit":
                try:
                    PlanEvaluator(net, profile,
                                  s.request.chain_request()).check(plan)
                except (AssertionError, KeyError) as exc:
                    return (f"request_id={rid}: structurally invalid plan "
                            f"at t={t}: {exc}")
                if not state.footprint_clear(plan):
                    return (f"request_id={rid}: commit at t={t} touches a "
                            f"down resource (down_nodes="
                            f"{sorted(state.down_nodes)}, down_links="
                            f"{sorted(state.down_links)})")
                if not state.fits(profile, s.request, plan):
                    return (f"request_id={rid}: commit at t={t} exceeds "
                            f"residual capacity")
                state.commit(profile, s.request, plan)
            else:
                try:
                    state.release(profile, s.request, plan)
                except KeyError:
                    return (f"request_id={rid}: release at t={t} of a "
                            f"chain/plan that was never committed")
            if not state.conservation_ok(profile):
                return (f"conservation broken after {kind} of "
                        f"request_id={rid} at t={t}")
        if saw_mark and not state.down_ok():
            return (f"a committed chain still spans a down resource after "
                    f"the failure events at t={t}")
    return None


_EPS_T = 1e-9  # timestamp-ordering slack in the replay checks
