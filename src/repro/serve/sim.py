"""ServeSim — deterministic event-driven serving under churn (docs/sim.md).

The static :meth:`ServePlanner.admit` round admits a fleet once and every
accepted chain holds its reservation forever.  Real serving is a *process*:
chains arrive, hold fabric capacity for a finite time, and leave — the
multi-cloud SFC setting (Bhamare et al.) and the companion SFC architecture
paper (Hara & Sasabe) both evaluate admission over time.  `ServeSim` replays
that process exactly:

* **events** — one arrival event per distinct arrival timestamp (simultaneous
  arrivals are ordered by the admission policy), one departure event per
  admitted chain with a finite ``duration_s``.  Events are processed in
  timestamp order; at equal timestamps departures are processed first, so
  capacity freed "now" is available to arrivals "now".
* **arrivals** run the same snapshot-fits / residual-replan / commit
  admission as the static round (the shared
  :class:`~repro.serve.admission.AdmissionCore`), against the residual state
  *at that instant*.
* **departures** release the departing chain's exact :class:`PlanDemand`
  through :meth:`ResidualState.release` — bit-identical floats to the ones
  its commit added, so conservation holds at every event.
* an optional **retry queue** parks capacity-blocked requests and re-attempts
  them (in arrival order) whenever a departure frees room; requests still
  queued when the event stream drains are finally rejected.

With every ``duration_s = inf`` there are no departures and the simulation
degenerates to the static admission round — bit-for-bit, which is the
anchoring invariant (`tests/test_sim.py`).

`replay_verify_sim` re-verifies a (possibly reloaded) trace from scratch:
plans re-checked structurally, every commit re-checked against the residuals
at its admission instant, and conservation re-derived after *every* event.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import ModelProfile, PhysicalNetwork, PlanEvaluator

from .admission import INF, AdmissionCore, ServedRequest
from .planner import ServeOutcome, ServePlanner
from .policies import POLICIES
from .requests import ServeRequest
from .residual import ResidualState

# Event priorities at equal timestamps: departures release capacity before
# simultaneous arrivals (or retries) contend for it.
_DEPART, _ARRIVE = 0, 1


@dataclass
class SimOutcome(ServeOutcome):
    """One simulation run: the static round's fields plus the event trace.

    ``served`` is in *decision* order (the order admit/reject decisions were
    made); accepted records carry ``admit_s`` / ``depart_s`` / ``n_retries``,
    which is the full trace — `replay_verify_sim` needs nothing else.
    ``timeline`` is the per-event audit log (admit/depart/reject with the
    concurrent-chain count after each event), from which the time-series
    metrics derive.
    """

    retry: bool = False
    horizon_s: float = 0.0  # timestamp of the last processed event
    timeline: list = field(default_factory=list)

    # ------------------------------------------------------------ churn metrics
    @property
    def n_departed(self) -> int:
        return sum(1 for e in self.timeline if e["event"] == "depart")

    @property
    def n_retried(self) -> int:
        """Chains admitted only after >= 1 failed capacity attempt."""
        return sum(1 for s in self.served if s.accepted and s.n_retries > 0)

    @property
    def n_blocked(self) -> int:
        """Requests finally rejected for capacity (not infeasibility)."""
        return sum(1 for s in self.served
                   if not s.accepted and s.reason == "capacity")

    @property
    def blocking_probability(self) -> float:
        """Erlang-style blocking: capacity rejections over offered requests
        (``no-plan`` rejections are infeasible on an empty fabric too, so
        they are not *blocking* — they count in the denominator only)."""
        return self.n_blocked / self.n_requests if self.served else 0.0

    @property
    def peak_concurrent(self) -> int:
        return max((e["concurrent"] for e in self.timeline), default=0)

    def concurrent_curve(self) -> list[tuple[float, int]]:
        """(t, concurrently held chains) after every event."""
        return [(e["t"], e["concurrent"]) for e in self.timeline]

    def acceptance_curve(self) -> list[tuple[float, float]]:
        """(t, cumulative accepted / decided) after every admit/reject."""
        out, acc, dec = [], 0, 0
        for e in self.timeline:
            if e["event"] == "admit":
                acc, dec = acc + 1, dec + 1
            elif e["event"] == "reject":
                dec += 1
            else:
                continue
            out.append((e["t"], acc / dec))
        return out

    def epoch_percentiles(self, n_epochs: int = 4,
                          qs: tuple[float, ...] = (50, 95, 99)) -> list[dict]:
        """Latency percentiles of admitted chains, bucketed by admit-time
        epoch (the horizon split into `n_epochs` equal windows) — shows how
        contention moves the latency distribution over the run."""
        end = self.horizon_s
        width = end / n_epochs if end > 0 else 1.0

        def admit_time(s: ServedRequest) -> float:
            # explicit None check: admit_s == 0.0 is a legitimate admission
            # at t=0, not a missing timestamp (records imported from a static
            # round fall back to their arrival instant)
            return s.admit_s if s.admit_s is not None else s.request.arrival_s

        epochs = []
        for e in range(n_epochs):
            lo, hi = e * width, (e + 1) * width
            lats = [s.latency_s for s in self.served
                    if s.accepted and s.latency_s is not None
                    and lo <= admit_time(s)
                    and (admit_time(s) < hi or e == n_epochs - 1)]
            row = {"epoch": e, "start_s": lo, "end_s": hi, "n": len(lats)}
            for q in qs:
                row[f"p{int(q)}"] = (float(np.percentile(np.asarray(lats), q))
                                     if lats else None)
            epochs.append(row)
        return epochs

    def sim_summary(self) -> dict:
        """The JSON-able churn block sweep artifacts store alongside the
        static summary fields (``ScenarioResult.sim``)."""
        return {
            "retry": self.retry,
            "horizon_s": self.horizon_s,
            "n_departed": self.n_departed,
            "n_retried": self.n_retried,
            "n_blocked": self.n_blocked,
            "blocking_probability": self.blocking_probability,
            "peak_concurrent": self.peak_concurrent,
            "concurrent_curve": [[t, n] for t, n in self.concurrent_curve()],
            "acceptance_curve": [[t, a] for t, a in self.acceptance_curve()],
            "epochs": self.epoch_percentiles(),
        }

    def summary(self) -> dict:
        s = super().summary()
        s.update({
            "retry": self.retry,
            "horizon_s": self.horizon_s,
            "n_departed": self.n_departed,
            "n_retried": self.n_retried,
            "blocking_probability": self.blocking_probability,
            "peak_concurrent": self.peak_concurrent,
        })
        return s


class ServeSim:
    """Event-driven dynamic admission on one fabric.

    Thin orchestration over the existing machinery: pre-solve + per-arrival
    admission delegate to a :class:`ServePlanner` (same solver registry,
    caches, and replan behaviour), capacity accounting to
    :class:`ResidualState` (`commit` on admit, `release` on departure).
    """

    def __init__(self, net: PhysicalNetwork, profile: ModelProfile,
                 solver: str = "bcd", replan: bool = True,
                 retry: bool = False, cache=None,
                 solver_kwargs: dict | None = None):
        self.planner = ServePlanner(net, profile, solver=solver, replan=replan,
                                    cache=cache, solver_kwargs=solver_kwargs)
        self.retry = retry

    def run(self, requests: list[ServeRequest],
            policy: str = "fcfs") -> SimOutcome:
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {sorted(POLICIES)}")
        t0 = time.perf_counter()
        planner = self.planner
        presolved, keys, estimates = planner.presolve(requests)

        # one arrival event per distinct timestamp; the admission policy
        # orders simultaneous arrivals (so a batch fleet reproduces the
        # static round's policy order exactly)
        batches: dict[float, list[ServeRequest]] = {}
        for r in requests:
            batches.setdefault(r.arrival_s, []).append(r)
        tick = itertools.count()  # deterministic heap tie-break
        heap: list[tuple] = [(t, _ARRIVE, next(tick), batch)
                             for t, batch in batches.items()]
        heapq.heapify(heap)

        core = AdmissionCore(planner, presolved, keys, retry=self.retry,
                             record_events=True)
        horizon = 0.0

        def push_depart(rec: ServedRequest) -> None:
            if rec.depart_s is not None:
                heapq.heappush(heap, (rec.depart_s, _DEPART, next(tick), rec))

        while heap:
            t, prio, _, payload = heapq.heappop(heap)
            horizon = max(horizon, t)
            if prio == _DEPART:
                core.release(payload, t)
                # drain all departures at this instant, then re-attempt the
                # queue (in arrival order) against the fully freed residuals
                more_departs_now = (heap and heap[0][0] == t
                                    and heap[0][1] == _DEPART)
                if self.retry and core.pending and not more_departs_now:
                    for rec in core.drain_pending(t):
                        push_depart(rec)
            else:
                for r in POLICIES[policy](payload, estimates):
                    rec = core.try_admit(r, t)
                    if rec is not None:
                        push_depart(rec)

        # the event stream drained with these still queued: final rejections
        core.reject_pending(horizon)
        assert core.conservation_ok()
        return SimOutcome(
            policy=policy, solver=planner.solver_name, served=core.served,
            wall_time_s=time.perf_counter() - t0, n_presolved=len(presolved),
            cache_stats=planner.round_cache_stats(),
            retry=self.retry, horizon_s=horizon, timeline=core.timeline)


def replay_verify_sim(net: PhysicalNetwork, profile: ModelProfile,
                      served: list[ServedRequest]) -> bool:
    """Re-verify a (possibly reloaded) sim trace from scratch.

    Rebuilds the event stream from the served records (commit at ``admit_s``,
    release at ``depart_s``; departures before commits at equal timestamps,
    decision order within ties — the simulator's own ordering) and replays it
    against a fresh :class:`ResidualState`: every plan is structurally
    re-checked, every commit must fit the residuals at its instant, and
    conservation must hold after *every* event.
    """
    events: list[tuple[float, int, int, ServedRequest]] = []
    for seq, s in enumerate(served):
        if not s.accepted:
            continue
        if s.plan is None:
            return False
        t = s.admit_s if s.admit_s is not None else s.request.arrival_s
        events.append((t, _ARRIVE, seq, s))
        if s.depart_s is not None and s.depart_s != INF:
            events.append((s.depart_s, _DEPART, seq, s))
    events.sort(key=lambda e: (e[0], e[1], e[2]))
    state = ResidualState(net)
    for _, kind, _, s in events:
        if kind == _ARRIVE:
            PlanEvaluator(net, profile, s.request.chain_request()).check(s.plan)
            if not state.fits(profile, s.request, s.plan):
                return False
            state.commit(profile, s.request, s.plan)
        else:
            try:
                state.release(profile, s.request, s.plan)
            except KeyError:  # departure of a never-committed chain
                return False
        if not state.conservation_ok(profile):
            return False
    return True
