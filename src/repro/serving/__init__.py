from .engine import ServingEngine, decode_step, make_serve_step, prefill

__all__ = ["prefill", "decode_step", "make_serve_step", "ServingEngine"]
