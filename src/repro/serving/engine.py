"""Serving: prefill + decode steps with per-family caches.

`serve_step` = one new token against a cache of `cache_len` (the shape suite's
decode_32k / long_500k cells lower exactly this).  Batched requests: the engine
packs requests into the fixed batch; continuous batching slots free as requests
hit EOS (host-side loop in `ServingEngine`).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import transformer as T
from ..models.layers import Ctx


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, memory=None):
    """tokens (B, S) -> (next-token logits (B, 1, V), cache)."""
    B, S = tokens.shape
    cache = T.init_cache(cfg, B, cache_len)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = Ctx(mode="prefill", positions=pos)
    enc = T.encode_memory(params, cfg, memory) if memory is not None else None
    if enc is not None:
        ctx = Ctx(mode="prefill", positions=pos, memory=enc)
    hidden, cache, _ = T.forward(params, cfg, tokens, ctx, cache=cache)
    logits = T.logits_last(params, cfg, hidden)
    extras = {"enc_memory": enc} if enc is not None else {}
    return logits, {"stack": cache, **extras}


def decode_step(params, cfg: ModelConfig, cache, tokens, positions):
    """One token per sequence: tokens (B, 1), positions (B, 1) absolute."""
    ctx = Ctx(mode="decode", positions=positions,
              memory=cache.get("enc_memory"))
    hidden, stack_cache, _ = T.forward(params, cfg, tokens, ctx,
                                       cache=cache["stack"])
    logits = T.logits_last(params, cfg, hidden)
    new_cache = dict(cache, stack=stack_cache)
    return logits, new_cache


def make_serve_step(cfg: ModelConfig):
    """The dry-run's serve_step: greedy-decode one token."""

    def serve_step(params, cache, tokens, positions):
        logits, cache = decode_step(params, cfg, cache, tokens, positions)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = None


class ServingEngine:
    """Host-side batched serving loop (example application scale)."""

    def __init__(self, cfg: ModelConfig, params, batch_size: int, cache_len: int,
                 eos_id: int = 0):
        self.cfg, self.params = cfg, params
        self.B, self.cache_len, self.eos = batch_size, cache_len, eos_id
        self._prefill = jax.jit(partial(prefill, cfg=cfg, cache_len=cache_len),
                                static_argnames=())
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, prompts: list[np.ndarray], max_new: int) -> list[list[int]]:
        outs: list[list[int]] = []
        for start in range(0, len(prompts), self.B):
            group = prompts[start : start + self.B]
            pad_to = max(len(p) for p in group)
            toks = np.zeros((self.B, pad_to), np.int32)
            for i, p in enumerate(group):
                toks[i, pad_to - len(p):] = p  # left-pad
            logits, cache = self._prefill(self.params, tokens=jnp.asarray(toks))
            cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            pos = jnp.full((self.B, 1), pad_to, jnp.int32)
            gen = [[] for _ in group]
            done = np.zeros(self.B, bool)
            for _ in range(max_new):
                for i in range(len(group)):
                    if not done[i]:
                        gen[i].append(int(cur[i]))
                        done[i] = int(cur[i]) == self.eos
                if done[: len(group)].all():
                    break
                cur, cache = self._step(self.params, cache, cur[:, None], pos)
                pos = pos + 1
            outs.extend(gen)
        return outs
