"""Scenario-sweep engine: declarative scenario specs, named suites, a cached
multiprocessing runner, and structured artifacts + comparison reports.

The paper's evaluation (Sec. VI, Figs. 4-11) is a grid of scenarios —
topology x model profile x request mode x cut count K x solver.  This package
turns each grid point into a serializable :class:`ScenarioSpec`, groups them
into named suites (``repro.sweep.suites.SUITES``), executes them through
:class:`SweepRunner` (process fan-out, shared ``EvalCache`` / Dijkstra-frontier
tables, on-disk result cache) and emits JSON/CSV artifacts with a BCD-vs-optimal
comparison and Pareto report.

CLI:  ``PYTHONPATH=src python -m repro.sweep --suite nsfnet_paper --quick``
"""
from .report import churn_pairs, comparison_report, format_report, schedule_pairs
from .runner import ScenarioResult, SweepRunner, run_scenario, verify_result
from .spec import (SUITE_SCHEMA_VERSION, ScenarioSpec, apply_faults,
                   build_profile, build_topology, candidate_sets)
from .suites import SUITES

__all__ = [
    "SUITE_SCHEMA_VERSION", "ScenarioSpec", "ScenarioResult", "SweepRunner",
    "SUITES", "apply_faults", "build_profile", "build_topology",
    "candidate_sets", "churn_pairs", "comparison_report", "format_report",
    "run_scenario", "schedule_pairs", "verify_result",
]
