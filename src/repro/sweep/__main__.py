"""CLI for the scenario-sweep engine.

    PYTHONPATH=src python -m repro.sweep --suite nsfnet_paper --quick
    PYTHONPATH=src python -m repro.sweep --list
    PYTHONPATH=src python -m repro.sweep --list-solvers
    PYTHONPATH=src python -m repro.sweep --suite nsfnet_faults --workers 2 \
        --out sweep_out --cache-dir sweep_out/.cache

Artifacts land in ``--out`` (default ``sweep_out/``): ``<suite>.json`` with
per-scenario latency breakdowns + the comparison/Pareto report, and a flat
``<suite>.csv``.  With a cache dir (default ``<out>/.cache``) a re-run of the
same suite is served from disk and reports its cache-hit count.
"""
from __future__ import annotations

import argparse
import sys
import time

from .artifacts import write_artifacts
from .report import comparison_report, format_report
from .runner import SweepRunner
from .suites import SUITES


def _workers_arg(value: str) -> int | None:
    """'auto' -> None (all cores); otherwise an int (see SweepRunner.resolve_workers)."""
    if value == "auto":
        return None
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected an integer or 'auto', got {value!r}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep",
                                 description="scenario-sweep engine")
    ap.add_argument("--suite", nargs="*", default=None,
                    help=f"suites to run (default: nsfnet_paper); have {list(SUITES)}")
    ap.add_argument("--quick", action="store_true", help="reduced grids (CI tier)")
    ap.add_argument("--out", default="sweep_out", help="artifact directory")
    ap.add_argument("--cache-dir", default=None,
                    help="result cache dir (default <out>/.cache)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk result cache")
    ap.add_argument("--workers", type=_workers_arg, default=0,
                    help="worker processes: 0 or 1 = serial in-process "
                         "(default), N >= 2 = N processes, 'auto' or a "
                         "negative value = all cores (os.cpu_count())")
    ap.add_argument("--list", action="store_true", help="list suites and exit")
    ap.add_argument("--list-solvers", action="store_true",
                    help="list registered solvers + declared capabilities "
                         "and exit")
    args = ap.parse_args(argv)

    if args.list_solvers:
        from repro.core import solver_capabilities

        print(f"{'solver':<12} {'schedules':<10} {'optimal':>7} {'meta':>5}  "
              f"description")
        for cap in solver_capabilities():
            print(f"{cap['name']:<12} {'+'.join(cap['schedules']):<10} "
                  f"{str(cap['optimal']):>7} {str(cap['meta']):>5}  "
                  f"{cap['description']}")
        return 0

    if args.list:
        for name, fn in SUITES.items():
            n_quick, n_full = len(fn(quick=True)), len(fn(quick=False))
            print(f"{name:<16} quick={n_quick:>4} scenarios, full={n_full:>5}")
        return 0

    names = args.suite or ["nsfnet_paper"]
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        print(f"unknown suite(s) {unknown}; have {list(SUITES)}", file=sys.stderr)
        return 2

    cache_dir = None if args.no_cache else (args.cache_dir or f"{args.out}/.cache")
    runner = SweepRunner(cache_dir=cache_dir, workers=args.workers)
    rc = 0
    for name in names:
        specs = SUITES[name](quick=args.quick)
        print(f"# suite {name}: {len(specs)} scenarios "
              f"(quick={args.quick}, workers={runner.workers})", file=sys.stderr)
        t0 = time.perf_counter()
        results = runner.run(specs)
        wall = time.perf_counter() - t0
        st = runner.last_stats
        paths = write_artifacts(args.out, name, results,
                                meta={"quick": args.quick, "stats": st})
        n_feas = sum(r.feasible for r in results)
        print(f"# {name}: {n_feas}/{len(results)} feasible, "
              f"{st['n_cache_hits']} cache hits, {st['n_solved']} solved, "
              f"{wall:.2f}s", file=sys.stderr)
        print(format_report(comparison_report(results)))
        print(f"# artifacts: {paths['json']} {paths['csv']}", file=sys.stderr)
        if n_feas == 0:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
