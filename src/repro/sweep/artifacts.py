"""Structured sweep artifacts: one JSON document per suite run plus a flat CSV.

The JSON artifact is self-contained — every record embeds its full
ScenarioSpec, so ``load_artifact`` can rebuild and re-verify any plan without
the code that generated it (see ``runner.verify_result``).
"""
from __future__ import annotations

import csv
import json
import time
from pathlib import Path

from .report import churn_pairs, comparison_report, schedule_pairs
from .runner import ScenarioResult
from .spec import SUITE_SCHEMA_VERSION

CSV_FIELDS = [
    "scenario_id", "suite", "figure", "cell", "topology", "profile", "mode",
    "K", "batch_size", "schedule", "n_microbatches", "solver",
    "candidate_seed", "feasible", "status", "error", "latency_s",
    "computation_s", "transmission_s", "propagation_s", "bubble_s",
    # seq-vs-pipe pairing (pipe rows with a feasible seq counterpart only)
    "seq_latency_s", "pipe_speedup",
    "wall_time_s", "iterations", "from_cache",
    # serve-layer (fleet) columns; empty for single-chain scenarios
    "n_requests", "policy", "arrival", "n_accepted", "acceptance_ratio",
    "latency_p50_s", "latency_p95_s", "latency_p99_s",
    # event-driven sim columns (docs/sim.md); empty for static scenarios
    "sim", "hold_model", "duration_s", "retry",
    "blocking_probability", "peak_concurrent", "n_retried",
    # static-vs-churn pairing (sim/gateway rows with a static counterpart)
    "static_acceptance", "churn_uplift",
    # streaming gateway columns (docs/gateway.md); empty otherwise
    "gateway", "batch_window_s", "max_queue", "slo_latency_s",
    # cache observability (serve scenarios): hit rates over the run
    "eval_cache_hit_rate", "plan_cache_hit_rate",
    # substrate failures + live migration (docs/failures.md); empty otherwise
    "failure_rate", "ha", "n_failed", "n_restored", "restore_p95_s",
    "moved_bytes",
    # mixed training fleets (docs/training.md); empty for pure-mode fleets
    "train_share", "tr_n_requests", "tr_n_accepted", "tr_acceptance_ratio",
    "tr_latency_p50_s", "tr_latency_p95_s", "tr_latency_p99_s",
    "if_n_requests", "if_n_accepted", "if_acceptance_ratio",
    "if_latency_p50_s", "if_latency_p95_s", "if_latency_p99_s",
]


def _opt(v):
    return "" if v is None else v


def write_artifacts(out_dir: str | Path, suite_name: str,
                    results: list[ScenarioResult],
                    meta: dict | None = None) -> dict[str, Path]:
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    report = comparison_report(results)
    doc = {
        "schema_version": SUITE_SCHEMA_VERSION,
        "suite": suite_name,
        "created_unix": time.time(),
        "meta": meta or {},
        "report": report,
        "results": [r.to_dict() for r in results],
    }
    json_path = out / f"{suite_name}.json"
    json_path.write_text(json.dumps(doc, indent=1))

    csv_path = out / f"{suite_name}.csv"
    pairs = schedule_pairs(results)
    cpairs = churn_pairs(results)
    with csv_path.open("w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=CSV_FIELDS)
        w.writeheader()
        for r in results:
            s = r.spec
            pair = pairs.get(s.scenario_id())
            cpair = cpairs.get(s.scenario_id())
            w.writerow({
                "scenario_id": s.scenario_id(),
                "suite": s.tags.get("suite", suite_name),
                "figure": s.tags.get("figure", ""),
                "cell": s.tags.get("cell", ""),
                "topology": s.topology,
                "profile": s.profile,
                "mode": s.mode,
                "K": s.K,
                "batch_size": s.batch_size,
                "schedule": s.schedule,
                "n_microbatches": s.n_microbatches,
                "solver": s.solver,
                "candidate_seed": s.candidate_seed,
                "feasible": r.feasible,
                "status": _opt(r.status),
                "error": _opt(r.error),
                "latency_s": r.latency_s,
                "computation_s": r.computation_s,
                "transmission_s": r.transmission_s,
                "propagation_s": r.propagation_s,
                "bubble_s": _opt(r.bubble_s),
                "seq_latency_s": _opt(pair["seq_latency_s"] if pair else None),
                "pipe_speedup": _opt(pair["speedup"] if pair else None),
                "wall_time_s": r.wall_time_s,
                "iterations": r.iterations,
                "from_cache": r.from_cache,
                "n_requests": s.n_requests if s.n_requests > 1 else "",
                "policy": s.policy if s.n_requests > 1 else "",
                "arrival": s.arrival if s.n_requests > 1 else "",
                "n_accepted": _opt(r.n_accepted),
                "acceptance_ratio": _opt(r.acceptance_ratio),
                "latency_p50_s": _opt(r.latency_p50_s),
                "latency_p95_s": _opt(r.latency_p95_s),
                "latency_p99_s": _opt(r.latency_p99_s),
                "sim": s.sim if s.n_requests > 1 else "",
                "hold_model": s.hold_model if (s.sim or s.gateway) else "",
                "duration_s": _opt(s.duration_s if (s.sim or s.gateway)
                                   else None),
                "retry": s.retry if (s.sim or s.gateway) else "",
                "blocking_probability": _opt(r.blocking_probability),
                "peak_concurrent": _opt(r.peak_concurrent),
                "n_retried": _opt(r.n_retried),
                "static_acceptance": _opt(
                    cpair["static_acceptance"] if cpair else None),
                "churn_uplift": _opt(cpair["uplift"] if cpair else None),
                "gateway": s.gateway if s.n_requests > 1 else "",
                "batch_window_s": _opt(s.batch_window_s if s.gateway
                                       else None),
                "max_queue": _opt(s.max_queue if s.gateway else None),
                "slo_latency_s": _opt(s.slo_latency_s if s.gateway else None),
                "eval_cache_hit_rate": _opt(r.eval_cache_hit_rate),
                "plan_cache_hit_rate": _opt(r.plan_cache_hit_rate),
                "failure_rate": _opt(s.failure_rate if (s.sim or s.gateway)
                                     else None),
                "ha": s.ha if (s.sim or s.gateway) else "",
                "n_failed": _opt(r.n_failed),
                "n_restored": _opt(r.n_restored),
                "restore_p95_s": _opt(r.restore_p95_s),
                "moved_bytes": _opt(r.moved_bytes),
                "train_share": _opt(s.train_share if s.n_requests > 1
                                    else None),
                **{f"{m.lower()}_{col}": _opt(
                    (r.mode_split or {}).get(m, {}).get(col))
                   for m in ("TR", "IF")
                   for col in ("n_requests", "n_accepted", "acceptance_ratio",
                               "latency_p50_s", "latency_p95_s",
                               "latency_p99_s")},
            })
    return {"json": json_path, "csv": csv_path}


def load_artifact(path: str | Path) -> tuple[dict, list[ScenarioResult]]:
    """Read a suite JSON artifact back into (meta document, results)."""
    doc = json.loads(Path(path).read_text())
    results = [ScenarioResult.from_dict(d) for d in doc["results"]]
    meta = {k: v for k, v in doc.items() if k != "results"}
    return meta, results
