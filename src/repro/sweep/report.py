"""Comparison / Pareto reporting over sweep results.

Scenarios sharing a :meth:`ScenarioSpec.group_key` are one problem instance
solved by several schemes; within each group we measure every scheme against
the best optimal-class solver present (``exact`` or ``ilp``, else the group's
best latency): optimality gap in % and wall-time speedup.  A scheme is on the
group's Pareto front if no other scheme is at least as good on both latency
and solver wall time and strictly better on one.
"""
from __future__ import annotations

from collections import defaultdict

from .runner import ScenarioResult

OPTIMAL_SOLVERS = ("exact", "ilp")


def _pareto(points: list[tuple[str, float, float]]) -> set[str]:
    front = set()
    for name, lat, wall in points:
        dominated = any(
            (l2 <= lat and w2 <= wall) and (l2 < lat or w2 < wall)
            for n2, l2, w2 in points if n2 != name
        )
        if not dominated:
            front.add(name)
    return front


def comparison_report(results: list[ScenarioResult]) -> dict:
    groups: dict[str, list[ScenarioResult]] = defaultdict(list)
    for r in results:
        groups[r.spec.group_key()].append(r)

    per_group = []
    agg: dict[str, dict] = defaultdict(
        lambda: {"n": 0, "n_feasible": 0, "gap_pct_sum": 0.0, "gap_pct_max": 0.0,
                 "n_gap": 0, "speedup_sum": 0.0, "n_speedup": 0,
                 "pareto_count": 0, "accept_sum": 0.0, "n_accept": 0})

    for key, rs in sorted(groups.items()):
        feas = [r for r in rs if r.feasible]
        ref = None
        for r in feas:
            if r.spec.solver in OPTIMAL_SOLVERS:
                if ref is None or r.latency_s < ref.latency_s:
                    ref = r
        if ref is None and feas:
            ref = min(feas, key=lambda r: r.latency_s)

        entry = {"group": rs[0].spec.tags.get("cell", key[:48]),
                 "tags": rs[0].spec.tags,
                 "reference_solver": ref.spec.solver if ref else None,
                 "solvers": {}}
        points = []
        for r in rs:
            a = agg[r.spec.solver]
            a["n"] += 1
            row: dict = {"feasible": r.feasible,
                         "wall_time_s": r.wall_time_s,
                         "iterations": r.iterations}
            if r.acceptance_ratio is not None:  # serve (fleet) scenario
                # gap/speedup/Pareto compare one plan against the optimum;
                # a fleet's mean latency averages a *different accepted set*
                # per scheme, so fleets compare on acceptance ratio instead.
                row["n_requests"] = r.spec.n_requests
                row["n_accepted"] = r.n_accepted
                row["acceptance_ratio"] = r.acceptance_ratio
                row["latency_mean_s"] = r.latency_s
                row["latency_p50_s"] = r.latency_p50_s
                row["latency_p95_s"] = r.latency_p95_s
                row["latency_p99_s"] = r.latency_p99_s
                a["accept_sum"] += r.acceptance_ratio
                a["n_accept"] += 1
                if r.feasible:
                    a["n_feasible"] += 1
                entry["solvers"][r.spec.solver] = row
                continue
            if r.feasible:
                a["n_feasible"] += 1
                row["latency_s"] = r.latency_s
                if ref is not None and ref.latency_s > 0:
                    gap = (r.latency_s - ref.latency_s) / ref.latency_s * 100.0
                    row["gap_pct"] = gap
                    a["gap_pct_sum"] += gap
                    a["gap_pct_max"] = max(a["gap_pct_max"], gap)
                    a["n_gap"] += 1
                if ref is not None and r.wall_time_s > 0:
                    row["speedup_vs_ref"] = ref.wall_time_s / r.wall_time_s
                    a["speedup_sum"] += row["speedup_vs_ref"]
                    a["n_speedup"] += 1
                points.append((r.spec.solver, r.latency_s, r.wall_time_s))
            entry["solvers"][r.spec.solver] = row
        front = _pareto(points)
        entry["pareto_front"] = sorted(front)
        for s in front:
            agg[s]["pareto_count"] += 1
        per_group.append(entry)

    summary = {}
    for solver, a in sorted(agg.items()):
        summary[solver] = {
            "n": a["n"],
            "n_feasible": a["n_feasible"],
            "mean_gap_pct": a["gap_pct_sum"] / a["n_gap"] if a["n_gap"] else None,
            "max_gap_pct": a["gap_pct_max"] if a["n_gap"] else None,
            "mean_speedup_vs_ref": (a["speedup_sum"] / a["n_speedup"]
                                    if a["n_speedup"] else None),
            "pareto_count": a["pareto_count"],
            "mean_acceptance_ratio": (a["accept_sum"] / a["n_accept"]
                                      if a["n_accept"] else None),
        }
    return {"n_groups": len(per_group), "summary": summary, "groups": per_group}


def format_report(report: dict) -> str:
    lines = [f"comparison over {report['n_groups']} scenario groups",
             f"{'solver':<10} {'feas':>9} {'mean gap%':>10} {'max gap%':>10} "
             f"{'speedup':>9} {'pareto':>7} {'accept':>7}"]
    for solver, s in report["summary"].items():
        gap = "-" if s["mean_gap_pct"] is None else f"{s['mean_gap_pct']:.2f}"
        mgap = "-" if s["max_gap_pct"] is None else f"{s['max_gap_pct']:.2f}"
        spd = ("-" if s["mean_speedup_vs_ref"] is None
               else f"{s['mean_speedup_vs_ref']:.1f}x")
        acc = ("-" if s.get("mean_acceptance_ratio") is None
               else f"{s['mean_acceptance_ratio']:.2f}")
        lines.append(f"{solver:<10} {s['n_feasible']:>4}/{s['n']:<4} {gap:>10} "
                     f"{mgap:>10} {spd:>9} {s['pareto_count']:>7} {acc:>7}")
    return "\n".join(lines)
