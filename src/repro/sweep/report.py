"""Comparison / Pareto reporting over sweep results.

Scenarios sharing a :meth:`ScenarioSpec.group_key` are one problem instance
solved by several schemes; within each group we measure every scheme against
the best optimal-class solver present (``exact`` or ``ilp``, else the group's
best latency): optimality gap in % and wall-time speedup.  A scheme is on the
group's Pareto front if no other scheme is at least as good on both latency
and solver wall time and strictly better on one.

Scenarios sharing a :meth:`ScenarioSpec.schedule_key` (same instance, same
solver, different execution schedule) are additionally paired seq-vs-pipe:
each pipelined scenario gets its sequential counterpart's latency and the
speedup ``seq / pipe`` — the headline number of the pipelined execution model
(docs/pipeline.md), which is >= 1 by construction (the pipelined schedule can
always execute the sequential plan).
"""
from __future__ import annotations

from collections import defaultdict

from .runner import ScenarioResult

# Name-based fallback for results recorded before the engine stamped a solve
# status (schema < 4); current results carry SolveOutcome.status directly.
OPTIMAL_SOLVERS = ("exact", "ilp")


def _is_optimal(r: ScenarioResult) -> bool:
    """Optimal-class reference test: the engine-stamped status when present
    (covers e.g. a portfolio whose winning member is optimal), else the
    legacy solver-name convention."""
    if r.status is not None:
        return r.status == "optimal"
    return r.spec.solver in OPTIMAL_SOLVERS


def schedule_pairs(results: list[ScenarioResult]) -> dict[str, dict]:
    """Map each *pipe* scenario id to its seq counterpart's latency + speedup.

    Pairing key is :meth:`ScenarioSpec.schedule_key` — only feasible
    single-chain pairs are reported.  Returned rows carry
    ``seq_latency_s`` / ``pipe_latency_s`` / ``speedup`` plus labels.
    """
    seq_by_key: dict[str, ScenarioResult] = {}
    for r in results:
        if r.spec.schedule == "seq" and r.spec.n_requests == 1 and r.feasible:
            seq_by_key[r.spec.schedule_key()] = r
    pairs: dict[str, dict] = {}
    for r in results:
        if r.spec.schedule != "pipe" or r.spec.n_requests != 1 or not r.feasible:
            continue
        seq = seq_by_key.get(r.spec.schedule_key())
        if seq is None or not seq.latency_s:
            continue
        pairs[r.spec.scenario_id()] = {
            "cell": r.spec.tags.get("cell", ""),
            "solver": r.spec.solver,
            "n_microbatches": r.spec.n_microbatches,
            "seq_latency_s": seq.latency_s,
            "pipe_latency_s": r.latency_s,
            "bubble_s": r.bubble_s,
            "speedup": seq.latency_s / r.latency_s,
        }
    return pairs


def churn_pairs(results: list[ScenarioResult]) -> dict[str, dict]:
    """Map each dynamic (*sim* churn or *gateway* stream) scenario id to its
    static counterpart's acceptance, pairing on
    :meth:`ScenarioSpec.churn_key` — identical fleet, solver, and policy;
    only the churn/gateway knobs differ.  ``uplift`` is
    ``dynamic acceptance - static acceptance`` (in ratio points): the
    headline of the event-driven serving model, >= 0 whenever departures
    free capacity that the one-shot round holds forever."""
    static_by_key: dict[str, ScenarioResult] = {}
    for r in results:
        if (r.spec.n_requests > 1 and not r.spec.sim and not r.spec.gateway
                and r.error is None and r.acceptance_ratio is not None):
            static_by_key[r.spec.churn_key()] = r
    pairs: dict[str, dict] = {}
    for r in results:
        if not (r.spec.sim or r.spec.gateway):
            continue
        if r.error is not None or r.acceptance_ratio is None:
            continue
        static = static_by_key.get(r.spec.churn_key())
        if static is None:
            continue
        pairs[r.spec.scenario_id()] = {
            "cell": r.spec.tags.get("cell", ""),
            "driver": "gateway" if r.spec.gateway else "sim",
            "solver": r.spec.solver,
            "policy": r.spec.policy,
            "n_requests": r.spec.n_requests,
            "static_accepted": static.n_accepted,
            "churn_accepted": r.n_accepted,
            "static_acceptance": static.acceptance_ratio,
            "churn_acceptance": r.acceptance_ratio,
            "uplift": r.acceptance_ratio - static.acceptance_ratio,
            "blocking_probability": r.blocking_probability,
            "peak_concurrent": r.peak_concurrent,
        }
    return pairs


def training_rows(results: list[ScenarioResult]) -> list[dict]:
    """One train/inference contention row per mixed training fleet
    (``spec.train_share > 0``, docs/training.md): the per-mode acceptance and
    latency percentiles recorded by the run, plus — when the suite also swept
    the ``train_share=0`` twin (identical arrivals/candidates by stream
    construction, pairing on :meth:`ScenarioSpec.training_key`) — the all-IF
    acceptance and the contention cost ``if_acceptance_delta`` (how many
    acceptance-ratio points the *inference* side lost to sharing the fabric
    with training chains)."""
    twin_by_key: dict[str, ScenarioResult] = {}
    for r in results:
        if (r.spec.n_requests > 1 and r.spec.train_share == 0.0
                and r.error is None and r.acceptance_ratio is not None):
            twin_by_key[r.spec.training_key()] = r
    rows = []
    for r in results:
        s = r.spec
        if s.train_share <= 0.0 or r.error is not None:
            continue
        split = r.mode_split or {}
        row = {
            "scenario_id": s.scenario_id(),
            "cell": s.tags.get("cell", ""),
            "profile": s.profile,
            "arch": (s.profile_kwargs or {}).get("arch", s.profile),
            "solver": s.solver,
            "train_share": s.train_share,
            "n_requests": s.n_requests,
            "acceptance_ratio": r.acceptance_ratio,
            "mode_split": split,
        }
        twin = twin_by_key.get(s.training_key())
        if twin is not None:
            row["all_if_acceptance"] = twin.acceptance_ratio
            if_split = split.get("IF")
            if if_split is not None and twin.acceptance_ratio is not None:
                row["if_acceptance_delta"] = (if_split["acceptance_ratio"]
                                              - twin.acceptance_ratio)
        rows.append(row)
    return rows


def failure_rows(results: list[ScenarioResult]) -> list[dict]:
    """One survivability row per failure-injected scenario (docs/failures.md):
    how many committed chains a substrate event took down, how many came back
    (migrated or promoted standbys), the restoration-latency tail, and the
    bytes the migrations moved.  Rate-0 anchors are excluded — they carry no
    failure schedule and pair through :func:`churn_pairs` instead."""
    rows = []
    for r in results:
        s = r.spec
        if not (s.sim or s.gateway) or r.error is not None:
            continue
        if s.failure_rate <= 0 and s.failures is None:
            continue
        n_failed = r.n_failed or 0
        n_restored = r.n_restored or 0
        rows.append({
            "scenario_id": s.scenario_id(),
            "cell": s.tags.get("cell", ""),
            "variant": s.tags.get("variant", ""),
            "failure_rate": s.failure_rate,
            "ha": s.ha,
            "solver": s.solver,
            "n_requests": s.n_requests,
            "acceptance_ratio": r.acceptance_ratio,
            "n_failed": n_failed,
            "n_restored": n_restored,
            "n_killed": n_failed - n_restored,
            "survivability": (n_restored / n_failed) if n_failed else None,
            "restore_p95_s": r.restore_p95_s,
            "moved_bytes": r.moved_bytes,
        })
    return rows


def _pareto(points: list[tuple[str, float, float]]) -> set[str]:
    front = set()
    for name, lat, wall in points:
        dominated = any(
            (l2 <= lat and w2 <= wall) and (l2 < lat or w2 < wall)
            for n2, l2, w2 in points if n2 != name
        )
        if not dominated:
            front.add(name)
    return front


def comparison_report(results: list[ScenarioResult]) -> dict:
    groups: dict[str, list[ScenarioResult]] = defaultdict(list)
    for r in results:
        groups[r.spec.group_key()].append(r)

    per_group = []
    agg: dict[str, dict] = defaultdict(
        lambda: {"n": 0, "n_feasible": 0, "n_errors": 0, "gap_pct_sum": 0.0,
                 "gap_pct_max": 0.0, "n_gap": 0, "speedup_sum": 0.0,
                 "n_speedup": 0, "pareto_count": 0, "accept_sum": 0.0,
                 "n_accept": 0})

    for key, rs in sorted(groups.items()):
        feas = [r for r in rs if r.feasible]
        ref = None
        for r in feas:
            if _is_optimal(r):
                if ref is None or r.latency_s < ref.latency_s:
                    ref = r
        if ref is None and feas:
            ref = min(feas, key=lambda r: r.latency_s)

        entry = {"group": rs[0].spec.tags.get("cell", key[:48]),
                 "tags": rs[0].spec.tags,
                 "reference_solver": ref.spec.solver if ref else None,
                 "solvers": {}}
        points = []
        for r in rs:
            a = agg[r.spec.solver]
            a["n"] += 1
            row: dict = {"feasible": r.feasible,
                         "status": r.status,
                         "wall_time_s": r.wall_time_s,
                         "iterations": r.iterations}
            if r.error is not None:  # crashed scenario (status="error")
                row["error"] = r.error
                a["n_errors"] += 1
                entry["solvers"][r.spec.solver] = row
                continue
            if r.acceptance_ratio is not None:  # serve (fleet) scenario
                # gap/speedup/Pareto compare one plan against the optimum;
                # a fleet's mean latency averages a *different accepted set*
                # per scheme, so fleets compare on acceptance ratio instead.
                row["n_requests"] = r.spec.n_requests
                row["n_accepted"] = r.n_accepted
                row["acceptance_ratio"] = r.acceptance_ratio
                row["latency_mean_s"] = r.latency_s
                row["latency_p50_s"] = r.latency_p50_s
                row["latency_p95_s"] = r.latency_p95_s
                row["latency_p99_s"] = r.latency_p99_s
                if r.eval_cache_hit_rate is not None:
                    row["eval_cache_hit_rate"] = r.eval_cache_hit_rate
                if r.plan_cache_hit_rate is not None:
                    row["plan_cache_hit_rate"] = r.plan_cache_hit_rate
                if r.spec.sim or r.spec.gateway:  # event-driven scenario
                    row["sim"] = True
                    row["blocking_probability"] = r.blocking_probability
                    row["peak_concurrent"] = r.peak_concurrent
                    row["n_retried"] = r.n_retried
                if r.spec.gateway:  # streaming gateway (docs/gateway.md)
                    row["gateway"] = True
                    if r.gateway:
                        row["gateway_stats"] = r.gateway
                a["accept_sum"] += r.acceptance_ratio
                a["n_accept"] += 1
                if r.feasible:
                    a["n_feasible"] += 1
                entry["solvers"][r.spec.solver] = row
                continue
            if r.feasible:
                a["n_feasible"] += 1
                row["latency_s"] = r.latency_s
                if ref is not None and ref.latency_s > 0:
                    gap = (r.latency_s - ref.latency_s) / ref.latency_s * 100.0
                    row["gap_pct"] = gap
                    a["gap_pct_sum"] += gap
                    a["gap_pct_max"] = max(a["gap_pct_max"], gap)
                    a["n_gap"] += 1
                if ref is not None and r.wall_time_s > 0:
                    row["speedup_vs_ref"] = ref.wall_time_s / r.wall_time_s
                    a["speedup_sum"] += row["speedup_vs_ref"]
                    a["n_speedup"] += 1
                points.append((r.spec.solver, r.latency_s, r.wall_time_s))
            entry["solvers"][r.spec.solver] = row
        front = _pareto(points)
        entry["pareto_front"] = sorted(front)
        for s in front:
            agg[s]["pareto_count"] += 1
        per_group.append(entry)

    summary = {}
    for solver, a in sorted(agg.items()):
        summary[solver] = {
            "n": a["n"],
            "n_feasible": a["n_feasible"],
            "n_errors": a["n_errors"],
            "mean_gap_pct": a["gap_pct_sum"] / a["n_gap"] if a["n_gap"] else None,
            "max_gap_pct": a["gap_pct_max"] if a["n_gap"] else None,
            "mean_speedup_vs_ref": (a["speedup_sum"] / a["n_speedup"]
                                    if a["n_speedup"] else None),
            "pareto_count": a["pareto_count"],
            "mean_acceptance_ratio": (a["accept_sum"] / a["n_accept"]
                                      if a["n_accept"] else None),
        }

    pairs = schedule_pairs(results)
    schedule_cmp = None
    if pairs:
        sp = [p["speedup"] for p in pairs.values()]
        schedule_cmp = {
            "n_pairs": len(sp),
            "mean_speedup": sum(sp) / len(sp),
            "min_speedup": min(sp),
            "max_speedup": max(sp),
            "pairs": pairs,
        }
    cpairs = churn_pairs(results)
    churn_cmp = None
    if cpairs:
        up = [p["uplift"] for p in cpairs.values()]
        churn_cmp = {
            "n_pairs": len(up),
            "mean_uplift": sum(up) / len(up),
            "min_uplift": min(up),
            "max_uplift": max(up),
            "pairs": cpairs,
        }
    frows = failure_rows(results)
    failure_cmp = None
    if frows:
        n_failed = sum(row["n_failed"] for row in frows)
        n_restored = sum(row["n_restored"] for row in frows)
        p95s = [row["restore_p95_s"] for row in frows
                if row["restore_p95_s"] is not None]
        failure_cmp = {
            "n_scenarios": len(frows),
            "n_failed": n_failed,
            "n_restored": n_restored,
            "n_killed": n_failed - n_restored,
            "survivability": (n_restored / n_failed) if n_failed else None,
            "worst_restore_p95_s": max(p95s) if p95s else None,
            "moved_bytes": sum(row["moved_bytes"] or 0.0 for row in frows),
            "rows": frows,
        }
    trows = training_rows(results)
    training_cmp = None
    if trows:
        def _mode_totals(mode: str) -> tuple[int, int]:
            n = sum(row["mode_split"].get(mode, {}).get("n_requests", 0)
                    for row in trows)
            acc = sum(row["mode_split"].get(mode, {}).get("n_accepted", 0)
                      for row in trows)
            return n, acc

        n_tr, acc_tr = _mode_totals("TR")
        n_if, acc_if = _mode_totals("IF")
        training_cmp = {
            "n_scenarios": len(trows),
            "n_train_requests": n_tr,
            "train_acceptance": (acc_tr / n_tr) if n_tr else None,
            "n_inference_requests": n_if,
            "inference_acceptance": (acc_if / n_if) if n_if else None,
            "rows": trows,
        }
    return {"n_groups": len(per_group), "summary": summary,
            "schedule_comparison": schedule_cmp,
            "churn_comparison": churn_cmp,
            "failure_survivability": failure_cmp,
            "training_contention": training_cmp, "groups": per_group}


def format_report(report: dict) -> str:
    lines = [f"comparison over {report['n_groups']} scenario groups",
             f"{'solver':<10} {'feas':>9} {'mean gap%':>10} {'max gap%':>10} "
             f"{'speedup':>9} {'pareto':>7} {'accept':>7}"]
    for solver, s in report["summary"].items():
        gap = "-" if s["mean_gap_pct"] is None else f"{s['mean_gap_pct']:.2f}"
        mgap = "-" if s["max_gap_pct"] is None else f"{s['max_gap_pct']:.2f}"
        spd = ("-" if s["mean_speedup_vs_ref"] is None
               else f"{s['mean_speedup_vs_ref']:.1f}x")
        acc = ("-" if s.get("mean_acceptance_ratio") is None
               else f"{s['mean_acceptance_ratio']:.2f}")
        lines.append(f"{solver:<10} {s['n_feasible']:>4}/{s['n']:<4} {gap:>10} "
                     f"{mgap:>10} {spd:>9} {s['pareto_count']:>7} {acc:>7}")
    n_err = sum(s.get("n_errors", 0) for s in report["summary"].values())
    if n_err:
        lines.append(f"! {n_err} scenario(s) crashed (status=error) — see "
                     f"per-group rows for messages")
    sc = report.get("schedule_comparison")
    if sc:
        lines.append(
            f"seq-vs-pipe: {sc['n_pairs']} pairs, speedup "
            f"mean {sc['mean_speedup']:.2f}x, min {sc['min_speedup']:.2f}x, "
            f"max {sc['max_speedup']:.2f}x")
        by_m: dict[int, list[float]] = {}
        for p in sc["pairs"].values():
            by_m.setdefault(p["n_microbatches"], []).append(p["speedup"])
        for m in sorted(by_m):
            sp = by_m[m]
            lines.append(f"  M={m:<4} {len(sp):>3} pairs, "
                         f"mean speedup {sum(sp) / len(sp):.2f}x")
    cc = report.get("churn_comparison")
    if cc:
        lines.append(
            f"static-vs-churn: {cc['n_pairs']} pairs, acceptance uplift "
            f"mean {cc['mean_uplift']:+.2f}, min {cc['min_uplift']:+.2f}, "
            f"max {cc['max_uplift']:+.2f}")
        for sid, p in sorted(cc["pairs"].items(), key=lambda kv: kv[1]["cell"]):
            lines.append(
                f"  {p['cell']:<16} {p['driver']:<7} {p['solver']:<8} "
                f"static {p['static_accepted']}/{p['n_requests']} -> churn "
                f"{p['churn_accepted']}/{p['n_requests']} "
                f"(uplift {p['uplift']:+.2f}, peak {p['peak_concurrent']} "
                f"concurrent)")
    fc = report.get("failure_survivability")
    if fc:
        surv = ("-" if fc["survivability"] is None
                else f"{fc['survivability']:.2f}")
        p95 = ("-" if fc["worst_restore_p95_s"] is None
               else f"{fc['worst_restore_p95_s']:.2f}s")
        lines.append(
            f"failures: {fc['n_scenarios']} scenarios, "
            f"{fc['n_failed']} chains hit, {fc['n_restored']} restored, "
            f"{fc['n_killed']} killed (survivability {surv}), worst restore "
            f"p95 {p95}, moved {fc['moved_bytes'] / 1e6:.1f} MB")
        for row in sorted(fc["rows"],
                          key=lambda x: (x["cell"], x["variant"])):
            sv = ("-" if row["survivability"] is None
                  else f"{row['survivability']:.2f}")
            lines.append(
                f"  {row['cell']:<16} {row['variant']:<10} "
                f"rate {row['failure_rate']:<5} "
                f"{'ha ' if row['ha'] else '   '}"
                f"hit {row['n_failed']:>2} restored {row['n_restored']:>2} "
                f"killed {row['n_killed']:>2} (surv {sv})")
    tc = report.get("training_contention")
    if tc:
        ta = ("-" if tc["train_acceptance"] is None
              else f"{tc['train_acceptance']:.2f}")
        ia = ("-" if tc["inference_acceptance"] is None
              else f"{tc['inference_acceptance']:.2f}")
        lines.append(
            f"training contention: {tc['n_scenarios']} mixed fleets, "
            f"TR accept {ta} ({tc['n_train_requests']} reqs), "
            f"IF accept {ia} ({tc['n_inference_requests']} reqs)")
        for row in sorted(tc["rows"],
                          key=lambda x: (x["cell"], x["train_share"])):
            parts = []
            for m in ("TR", "IF"):
                ms = row["mode_split"].get(m)
                if ms is None:
                    continue
                p95 = ms.get("latency_p95_s")
                p95s = "-" if p95 is None else f"{p95 * 1e3:.1f}ms"
                parts.append(f"{m} {ms['n_accepted']}/{ms['n_requests']} "
                             f"p95 {p95s}")
            delta = row.get("if_acceptance_delta")
            tail = "" if delta is None else f" (IF delta {delta:+.2f})"
            lines.append(f"  {row['cell']:<20} share {row['train_share']:<4} "
                         + ", ".join(parts) + tail)
    return "\n".join(lines)
