"""SweepRunner: execute ScenarioSpecs with shared caches, process fan-out, and
an on-disk result cache.

Per-process context caches (module-level, so they survive across scenarios
handled by the same worker):

  * networks keyed by the spec's topology signature — so the cached Dijkstra
    frontiers on ``PhysicalNetwork`` accumulate across grid points;
  * model profiles keyed by profile signature — so the prefix-sum tables are
    built once;
  * ``EvalCache`` keyed by (topology, profile) — batch/mode live in the
    cache's own entry keys, so per-(node, segment) compute/fit tables are
    shared by every scheme, candidate seed, and (b, mode) cell of the grid.

The on-disk cache (``<cache_dir>/<spec_hash>.json``) memoizes finished
scenario results, making warm re-runs of a suite near-instant.
"""
from __future__ import annotations

import json
import os
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from dataclasses import asdict, dataclass
from pathlib import Path

from repro.core import (EvalCache, LatencyBreakdown, Plan, PlanEvaluator,
                        SolveOutcome, get_solver, solve, solve_batch)

from .spec import ScenarioSpec


@dataclass
class ScenarioResult:
    """Structured outcome of one grid point (JSON round-trippable).

    Serve scenarios (``spec.n_requests > 1``) fill the fleet fields instead of
    the single-plan ones: ``latency_s`` is then the mean accepted-chain
    latency, ``served`` holds the per-request admission records (enough to
    replay and re-verify residual-capacity conservation), and ``iterations``
    counts capacity-aware replans.
    """

    spec: ScenarioSpec
    feasible: bool
    status: str | None = None  # SolveOutcome status, or "error" (see `error`)
    solver_stats: dict | None = None  # SolveOutcome.stats (portfolio members, ...)
    error: str | None = None  # exception repr when the scenario crashed
    latency_s: float | None = None
    computation_s: float | None = None
    transmission_s: float | None = None
    propagation_s: float | None = None
    bubble_s: float | None = None  # pipeline drain term; None/0 for seq
    wall_time_s: float = 0.0
    iterations: int = 0
    segments: list | None = None
    placement: list | None = None
    paths: list | None = None
    tail_path: list | None = None
    from_cache: bool = False
    # serve-layer (fleet) fields
    n_accepted: int | None = None
    acceptance_ratio: float | None = None
    latency_p50_s: float | None = None
    latency_p95_s: float | None = None
    latency_p99_s: float | None = None
    served: list | None = None  # per-request admission records
    # per-mode (IF vs TR) admission breakdown of mixed training fleets
    # (docs/training.md): acceptance + latency percentiles split by mode
    mode_split: dict | None = None
    # event-driven sim scenarios (spec.sim, docs/sim.md)
    blocking_probability: float | None = None
    peak_concurrent: int | None = None
    n_retried: int | None = None
    sim: dict | None = None  # SimOutcome.sim_summary(): curves, epochs, ...
    # cache observability (docs/gateway.md): hit rates over the scenario run
    eval_cache_hit_rate: float | None = None
    plan_cache_hit_rate: float | None = None
    # gateway scenarios (spec.gateway): GatewayOutcome.gateway_stats summary
    gateway: dict | None = None
    # failure scenarios (spec.failure_rate / spec.failures, docs/failures.md):
    # survivability metrics from FailureOutcome.failure_summary()
    n_failed: int | None = None
    n_restored: int | None = None
    restore_p95_s: float | None = None
    moved_bytes: float | None = None

    def to_dict(self) -> dict:
        d = asdict(self)
        d["spec"] = self.spec.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioResult":
        d = dict(d)
        d["spec"] = ScenarioSpec.from_dict(d["spec"])
        return cls(**d)

    def plan(self) -> Plan | None:
        if not self.feasible:
            return None
        return Plan(segments=[tuple(s) for s in self.segments],
                    placement=list(self.placement),
                    paths=[list(p) for p in self.paths],
                    tail_path=list(self.tail_path or []))


# ------------------------------------------------------- per-process context
_NETS: dict = {}
_PROFILES: dict = {}
_EVAL_CACHES: dict = {}


def _context(spec: ScenarioSpec):
    topo_key = json.dumps([spec.topology, spec.topology_kwargs, spec.drop_nodes,
                           spec.drop_links], sort_keys=True)
    prof_key = json.dumps([spec.profile, spec.profile_kwargs], sort_keys=True)
    net = _NETS.get(topo_key)
    if net is None:
        net = _NETS[topo_key] = spec.build_network()
    profile = _PROFILES.get(prof_key)
    if profile is None:
        profile = _PROFILES[prof_key] = spec.build_profile()
    # batch/mode are part of EvalCache entry keys, so one cache per
    # (network, profile) pair is shared across every cell of the grid
    ev_key = (topo_key, prof_key)
    cache = _EVAL_CACHES.get(ev_key)
    if cache is None:
        cache = _EVAL_CACHES[ev_key] = EvalCache()
    return net, profile, cache


def clear_context() -> None:
    """Drop the per-process memo tables (tests use this to force cold runs)."""
    _NETS.clear()
    _PROFILES.clear()
    _EVAL_CACHES.clear()


def _run_serve_scenario(spec: ScenarioSpec, net, profile, cache) -> ScenarioResult:
    """One fleet scenario (spec.n_requests > 1) through repro.serve: a static
    admission round, the event-driven `ServeSim` (``spec.sim``), or the
    long-running `ServeGateway` (``spec.gateway``, docs/gateway.md)."""
    from repro.serve import (GatewayConfig, ServeGateway, ServePlanner,
                             ServeSim)

    fleet = spec.build_fleet(net)
    # failure_rate == 0 and no explicit schedule -> failures is None, so the
    # failure-free drivers are bit-for-bit the pre-failure code path
    failures = spec.build_failures(net, fleet) or None
    if spec.sim:
        runner = ServeSim(net, profile, solver=spec.solver, cache=cache,
                          retry=spec.retry, solver_kwargs=spec.solver_kwargs)
        outcome = runner.run(fleet, policy=spec.policy, failures=failures)
    elif spec.gateway:
        gw = ServeGateway(
            net, profile, solver=spec.solver, policy=spec.policy,
            config=GatewayConfig(batch_window_s=spec.batch_window_s,
                                 max_queue=spec.max_queue,
                                 slo_latency_s=spec.slo_latency_s,
                                 retry=spec.retry),
            cache=cache, solver_kwargs=spec.solver_kwargs)
        outcome = gw.run_stream(fleet, failures=failures)
    else:
        planner = ServePlanner(net, profile, solver=spec.solver, cache=cache,
                               solver_kwargs=spec.solver_kwargs)
        outcome = planner.admit(fleet, policy=spec.policy)
    s = outcome.summary()
    res = ScenarioResult(
        spec, outcome.n_accepted > 0,
        status=outcome.status,
        solver_stats=outcome.solver_stats(),
        latency_s=s["latency_mean_s"],
        wall_time_s=outcome.wall_time_s,
        iterations=outcome.n_replanned,
        n_accepted=outcome.n_accepted,
        acceptance_ratio=outcome.acceptance_ratio,
        latency_p50_s=s["latency_p50_s"],
        latency_p95_s=s["latency_p95_s"],
        latency_p99_s=s["latency_p99_s"],
        served=[sr.to_dict() for sr in outcome.served],
        mode_split=outcome.mode_split(),
    )
    cs = outcome.cache_stats or {}
    res.eval_cache_hit_rate = cs.get("eval_cache", {}).get("hit_rate")
    res.plan_cache_hit_rate = cs.get("plan_cache", {}).get("hit_rate")
    if spec.sim or spec.gateway:
        res.blocking_probability = outcome.blocking_probability
        res.peak_concurrent = outcome.peak_concurrent
        res.n_retried = outcome.n_retried
        res.sim = outcome.sim_summary()
        if failures is not None:
            fs = outcome.failure_summary()
            res.n_failed = fs["n_failed"]
            res.n_restored = fs["n_restored"]
            res.restore_p95_s = fs["restore_p95_s"]
            res.moved_bytes = fs["moved_bytes"]
    if spec.gateway:
        res.gateway = outcome.gateway_stats
    return res


def _presolve_key(spec: ScenarioSpec, problem) -> tuple:
    """Identity under which a batch-presolved outcome may substitute for a
    scalar solve: same solver, same solver kwargs, same instance content."""
    return (spec.solver, json.dumps(spec.solver_kwargs, sort_keys=True,
                                    default=str), problem.content_hash())


def run_scenario(spec: ScenarioSpec, use_context_cache: bool = True,
                 presolved: dict | None = None) -> ScenarioResult:
    """Solve one grid point in-process.

    ``presolved`` optionally maps :func:`_presolve_key` identities to
    :class:`SolveOutcome`s computed up front by a batched solver dispatch
    (see :meth:`SweepRunner._batch_presolve`); hits skip the scalar solve.
    """
    if use_context_cache:
        net, profile, cache = _context(spec)
    else:
        net, profile, cache = spec.build_network(), spec.build_profile(), None
    if spec.n_requests > 1:
        return _run_serve_scenario(spec, net, profile, cache)
    problem = spec.problem(net, profile)
    res: SolveOutcome | None = None
    if presolved:
        res = presolved.get(_presolve_key(spec, problem))
    if res is None:
        res = solve(problem, spec.solver, cache=cache, **spec.solver_kwargs)
    if not res.feasible:
        return ScenarioResult(spec, False, status=res.status,
                              solver_stats=res.stats or None,
                              wall_time_s=res.wall_time_s,
                              iterations=res.iterations)
    lb: LatencyBreakdown = res.latency
    p = res.plan
    return ScenarioResult(
        spec, True,
        status=res.status,
        solver_stats=res.stats or None,
        latency_s=lb.total_s,
        computation_s=lb.computation_s,
        transmission_s=lb.transmission_s,
        propagation_s=lb.propagation_s,
        bubble_s=lb.bubble_s,
        wall_time_s=res.wall_time_s,
        iterations=res.iterations,
        segments=[list(s) for s in p.segments],
        placement=list(p.placement),
        paths=[list(path) for path in p.paths],
        tail_path=list(p.tail_path),
    )


def verify_result(result: ScenarioResult, atol: float = 1e-9) -> bool:
    """Re-evaluate a (possibly reloaded) result against the freshly built
    scenario — the artifact round-trip check.

    Single-chain results re-check the plan and its recorded latency; serve
    results replay the admission records in order and confirm the accepted
    chains never oversubscribe any residual link/node capacity, plus the
    recorded acceptance bookkeeping.  Sim results replay the full event trace
    (commits at admit times, releases at departures) with conservation
    re-checked after every event (`repro.serve.replay_verify_sim`).
    """
    spec = result.spec
    if result.error is not None:
        return False  # a crashed scenario has nothing verifiable
    if spec.n_requests > 1:
        from repro.serve import ServedRequest, replay_verify, replay_verify_sim

        served = [ServedRequest.from_dict(d) for d in (result.served or [])]
        if len(served) != spec.n_requests:
            return False
        n_acc = sum(s.accepted for s in served)
        if n_acc != result.n_accepted:
            return False
        if abs((n_acc / len(served)) - result.acceptance_ratio) > atol:
            return False
        net, profile = spec.build_network(), spec.build_profile()
        if spec.sim or spec.gateway:
            # gateway traces carry the same admit/depart timestamps as sim
            # traces, so the event-replay verifier covers both drivers
            n_blocked = sum(1 for s in served
                            if not s.accepted and s.reason == "capacity")
            if abs((n_blocked / len(served))
                   - (result.blocking_probability or 0.0)) > atol:
                return False
            # the failure schedule is deterministic from the spec, so the
            # verifier replays the exact marks the run was produced under
            failures = spec.build_failures(net, spec.build_fleet(net)) or None
            return replay_verify_sim(net, profile, served, failures=failures)
        return replay_verify(net, profile, served)
    if not result.feasible:
        return True
    net, profile = spec.build_network(), spec.build_profile()
    ev = PlanEvaluator(net, profile, spec.request())
    plan = result.plan()
    ev.check(plan)
    return abs(ev.latency_s(plan) - result.latency_s) <= atol


def _worker(args: tuple[dict, bool]) -> dict:
    spec_dict, use_context_cache = args
    return run_scenario(ScenarioSpec.from_dict(spec_dict),
                        use_context_cache=use_context_cache).to_dict()


class SweepRunner:
    """Executes a list of ScenarioSpecs with optional process fan-out and an
    on-disk result cache keyed by spec content hash.

    ``workers`` follows one explicit mapping (see :meth:`resolve_workers`,
    covered by tests and docs/sweep.md): ``0`` or ``1`` runs serially
    in-process (the default), ``n >= 2`` fans out over ``n`` worker
    processes, and ``None`` or any negative value expands to
    ``os.cpu_count()``.

    ``use_context_cache=False`` rebuilds the network/profile and uses a fresh
    EvalCache for every scenario — required when solver *wall time* is the
    measurement (warm shared caches would flatter whichever scheme runs last).
    """

    def __init__(self, cache_dir: str | Path | None = None,
                 workers: int | None = 0, use_disk_cache: bool = True,
                 use_context_cache: bool = True):
        self.cache_dir = Path(cache_dir) if cache_dir else None
        self.workers = self.resolve_workers(workers)
        self.use_disk_cache = use_disk_cache and self.cache_dir is not None
        self.use_context_cache = use_context_cache
        self.last_stats: dict = {}

    @staticmethod
    def resolve_workers(workers: int | None) -> int:
        """The one place the ``workers`` argument is interpreted:

        * ``0`` or ``1`` -> serial, in-process (no pool is created);
        * ``n >= 2``     -> ``n`` worker processes;
        * ``None`` / negative -> ``os.cpu_count()`` (use every core).
        """
        if workers is None or workers < 0:
            return os.cpu_count() or 1
        return workers

    # ------------------------------------------------------------- disk cache
    def _cache_path(self, spec: ScenarioSpec) -> Path:
        return self.cache_dir / f"{spec.spec_hash()}.json"

    def _load_cached(self, spec: ScenarioSpec) -> ScenarioResult | None:
        path = self._cache_path(spec)
        if not path.exists():
            return None
        try:
            res = ScenarioResult.from_dict(json.loads(path.read_text()))
        except (json.JSONDecodeError, KeyError, TypeError):
            return None
        # tolerate label/tag edits: only the solve-relevant key must match
        if res.spec.key() != spec.key():
            return None
        res.spec = spec
        res.from_cache = True
        return res

    def _store(self, result: ScenarioResult) -> None:
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        self._cache_path(result.spec).write_text(json.dumps(result.to_dict()))

    # -------------------------------------------------------------------- run
    def _batch_presolve(self, specs: list[ScenarioSpec]) -> dict:
        """Vectorized pre-pass for the serial path: group single-chain
        scenarios by (solver, solver_kwargs), and for solvers registered
        with a batch entry (``capabilities()["batched"]``) dispatch each
        group through :func:`repro.core.solve_batch` once.  Returns the
        ``presolved`` map :func:`run_scenario` consumes; scenarios not in it
        (serve fleets, scalar-only solvers, unknown solvers) fall through to
        the ordinary scalar solve.  Disabled with ``use_context_cache=False``
        — that mode exists to measure honest per-scenario wall time, which a
        shared warm batch would flatter."""
        if not self.use_context_cache:
            return {}
        groups: dict[tuple, list[ScenarioSpec]] = {}
        for spec in specs:
            if spec.n_requests > 1:
                continue
            try:
                info = get_solver(spec.solver)
            except ValueError:
                continue  # unknown solver: let run_scenario raise per-item
            if info.batch_fn is None:
                continue
            kw = json.dumps(spec.solver_kwargs, sort_keys=True, default=str)
            groups.setdefault((spec.solver, kw), []).append(spec)
        presolved: dict = {}
        for (solver, _), members in groups.items():
            if len(members) < 2:
                continue  # nothing to amortize
            try:
                problems = [s.problem(*_context(s)[:2]) for s in members]
                outs = solve_batch(problems, solver,
                                   **members[0].solver_kwargs)
            except Exception:  # noqa: BLE001 — presolve is best-effort
                continue  # scalar path will solve (and surface errors) per item
            for s, p, o in zip(members, problems, outs):
                presolved[_presolve_key(s, p)] = o
        return presolved

    @staticmethod
    def _error_result(spec: ScenarioSpec, exc: BaseException) -> ScenarioResult:
        """A crashed scenario becomes an infeasible `status="error"` record —
        the sweep keeps going and the failure stays visible in the artifact."""
        return ScenarioResult(spec, False, status="error",
                              error=f"{type(exc).__name__}: {exc}")

    def run(self, specs: list[ScenarioSpec]) -> list[ScenarioResult]:
        """Execute every spec; one scenario crashing never loses the sweep.

        Per-scenario exceptions (worker or in-process) are captured into
        `status="error"` results; completed results are still stored to the
        disk cache (errored ones are not, so a transient failure is retried
        on the next run), and ``last_stats["n_errors"]`` reports the count.
        """
        t0 = time.perf_counter()
        results: list[ScenarioResult | None] = [None] * len(specs)
        misses: list[int] = []
        for idx, spec in enumerate(specs):
            if self.use_disk_cache:
                hit = self._load_cached(spec)
                if hit is not None:
                    results[idx] = hit
                    continue
            misses.append(idx)

        if misses and self.workers >= 2 and len(misses) > 1:
            # submit() instead of map(): map() re-raises the first worker
            # exception when iterated, losing every other result of the sweep
            with ProcessPoolExecutor(max_workers=self.workers) as pool:
                futures = {
                    pool.submit(_worker, (specs[i].to_dict(),
                                          self.use_context_cache)): i
                    for i in misses}
                not_done = set(futures)
                while not_done:
                    done, not_done = wait(not_done, return_when=FIRST_COMPLETED)
                    for fut in done:
                        idx = futures[fut]
                        try:
                            res = ScenarioResult.from_dict(fut.result())
                            res.spec = specs[idx]  # identity incl. name/tags
                        except Exception as exc:  # noqa: BLE001 — per-item capture
                            res = self._error_result(specs[idx], exc)
                        results[idx] = res
        else:
            presolved = self._batch_presolve([specs[i] for i in misses])
            for idx in misses:
                try:
                    results[idx] = run_scenario(
                        specs[idx], use_context_cache=self.use_context_cache,
                        presolved=presolved)
                except Exception as exc:  # noqa: BLE001 — per-item capture
                    results[idx] = self._error_result(specs[idx], exc)

        if self.use_disk_cache:
            for idx in misses:
                if results[idx].error is None:
                    self._store(results[idx])

        out = [r for r in results if r is not None]
        n_errors = sum(1 for r in out if r.error is not None)
        self.last_stats = {
            "n_scenarios": len(specs),
            "n_cache_hits": len(specs) - len(misses),
            "n_solved": len(misses) - n_errors,
            "n_errors": n_errors,
            "errors": {specs[i].scenario_id(): results[i].error
                       for i in misses if results[i].error is not None},
            "wall_time_s": time.perf_counter() - t0,
        }
        return out
