"""Declarative scenario schema for the sweep engine.

A :class:`ScenarioSpec` names everything needed to reproduce one evaluation
grid point — topology factory + kwargs (with optional fault injection), model
profile, ``ServiceChainRequest`` parameters, candidate-set policy, cut count K,
and solver — as plain JSON-able data.  Specs are hashable (content hash) so
results can be memoized on disk and shipped to worker processes.
"""
from __future__ import annotations

import hashlib
import json
import math
from dataclasses import asdict, dataclass, field

from repro.core import (
    IF,
    PIPE,
    SCHEDULES,
    SEQ,
    TR,
    LinkSpec,
    ModelProfile,
    PhysicalNetwork,
    ProblemInstance,
    ServiceChainRequest,
    candidate_sets,
    ensure_solver_supported,
    nsfnet,
    random_network,
    resnet101_profile,
    tpu_pod_topology,
)
from repro.serve.policies import POLICY_NAMES
from repro.serve.requests import ARRIVALS, HOLD_MODELS

# v8: training as a first-class regime (train_share mixed fleets, mode-split
# contention columns, round-trip TR-pipe latencies — docs/training.md); v7:
# failure events + live migration (failure_rate/failure_downtime_s/
# failures/ha knobs, survivability columns); v6: serving gateway (gateway/
# batch_window_s/max_queue/slo_latency_s knobs, cache hit-rate columns); v5:
# event-driven serving sim (sim/hold_model/duration_s/retry knobs, churn
# metrics + error capture in results); v4: engine dispatch (status + stats)
SUITE_SCHEMA_VERSION = 8

# ------------------------------------------------------------------ topologies
TOPOLOGIES = {
    "nsfnet": nsfnet,
    "random": random_network,
    "tpu_pod": tpu_pod_topology,
}


def apply_faults(
    net: PhysicalNetwork,
    drop_nodes: list[str] | tuple[str, ...] = (),
    drop_links: list[tuple[str, str]] | tuple = (),
) -> PhysicalNetwork:
    """Return a copy of `net` with the given nodes / undirected links removed
    (fault-injected scenario variants; both directions of each link go down)."""
    dead_nodes = set(drop_nodes)
    dead_links = {frozenset(pair) for pair in drop_links}
    out = PhysicalNetwork()
    for name, spec in net.nodes.items():
        if name not in dead_nodes:
            out.add_node(spec)
    for (u, v), spec in net.links.items():
        if u in dead_nodes or v in dead_nodes:
            continue
        if frozenset((u, v)) in dead_links:
            continue
        out.add_link(u, v, LinkSpec(spec.bw_fw, spec.bw_bw,
                                    spec.delay_fw, spec.delay_bw))
    return out


def build_topology(name: str, kwargs: dict | None = None,
                   drop_nodes: tuple = (), drop_links: tuple = ()) -> PhysicalNetwork:
    try:
        factory = TOPOLOGIES[name]
    except KeyError:
        raise KeyError(f"unknown topology {name!r}; have {sorted(TOPOLOGIES)}")
    net = factory(**(kwargs or {}))
    if drop_nodes or drop_links:
        net = apply_faults(net, drop_nodes, drop_links)
    return net


# -------------------------------------------------------------------- profiles
def _group_profile(arch: str, seq_len: int = 2048, mode: str = "train",
                   cache_len: int = 0) -> ModelProfile:
    # Lazy import: repro.msl pulls in the jax model stack, which sweep workers
    # only need for TPU-pod scenarios.
    from repro.configs import ARCHS
    from repro.msl import group_profile

    return group_profile(ARCHS[arch], seq_len=seq_len, mode=mode,
                         cache_len=cache_len)


PROFILES = {
    "resnet101": resnet101_profile,
    "group": _group_profile,  # kwargs: arch, seq_len, mode
}


def build_profile(name: str, kwargs: dict | None = None) -> ModelProfile:
    try:
        factory = PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown profile {name!r}; have {sorted(PROFILES)}")
    return factory(**(kwargs or {}))


# ----------------------------------------------------------------------- spec
# Fields excluded from the spec content hash (ScenarioSpec.key/spec_hash).
# THE allowlist the `spec-hash` lint rule (docs/analysis.md) checks: every
# field popped out of the hash must be declared here with a justification,
# so a result-changing knob can never silently fall out of cache identity.
HASH_IRRELEVANT = (
    "name",  # human label only; renaming a scenario must not re-run it
    "tags",  # free-form grouping metadata; never read by the runner
)


@dataclass
class ScenarioSpec:
    """One evaluation grid point, fully determined by plain data."""

    topology: str = "nsfnet"
    topology_kwargs: dict = field(default_factory=dict)
    drop_nodes: list = field(default_factory=list)
    drop_links: list = field(default_factory=list)  # undirected [u, v] pairs
    profile: str = "resnet101"
    profile_kwargs: dict = field(default_factory=dict)
    source: str = "v4"
    destination: str = "v13"
    batch_size: int = 1
    mode: str = IF
    K: int = 3
    schedule: str = SEQ  # seq | pipe — the execution model (docs/pipeline.md)
    n_microbatches: int = 1  # pipeline depth M for schedule="pipe"
    solver: str = "bcd"
    solver_kwargs: dict = field(default_factory=dict)
    candidates: list | None = None  # pinned V^k sets; None -> seeded policy
    candidate_seed: int = 0
    candidates_per_stage: int = 2
    # Serve-layer scenarios (repro.serve): n_requests > 1 turns the grid point
    # into a fleet admission round — batch_size becomes the fleet's base batch
    # and candidate_seed seeds fleet generation (arrivals + per-request V^k).
    n_requests: int = 1
    arrival: str = "batch"  # batch | poisson
    policy: str = "fcfs"  # admission policy (repro.serve.policies)
    # Mixed training fleets (docs/training.md): each request is TR with this
    # probability (IF otherwise), overriding `mode`, from a dedicated seeded
    # stream — a mixed fleet and its train_share=0 twin share identical
    # arrivals/candidates/holds, pairing on ``training_key()``.
    train_share: float = 0.0
    # Event-driven serving sim (repro.serve.sim, docs/sim.md): sim=True runs
    # the fleet through ServeSim instead of one static admission round.
    sim: bool = False
    hold_model: str = "none"  # none | fixed | exp (chain holding times)
    duration_s: float | None = None  # holding time (fixed) / mean (exp)
    retry: bool = False  # re-attempt capacity-blocked requests on departures
    # Serving gateway (repro.serve.gateway, docs/gateway.md): gateway=True
    # streams the fleet through a long-running ServeGateway — batched
    # admission ticks over an incremental residual view with a warm PlanCache
    # — instead of one static round (sim) loop.
    gateway: bool = False
    batch_window_s: float = 0.0  # arrival grouping window per admission tick
    max_queue: int | None = None  # bounded admission queue (None: unbounded)
    slo_latency_s: float | None = None  # reject plans slower than this SLO
    # Substrate failures + live migration (repro.serve.failures,
    # docs/failures.md): failure_rate > 0 injects a seeded link_down/node_down
    # schedule into the sim/gateway run; failure_downtime_s adds paired
    # recover events; `failures` pins an explicit [t_s, kind, target] schedule
    # instead (target: node name, or [u, v] for a link); ha=True pre-plans a
    # disjoint standby per chain, promoted on failure.
    failure_rate: float = 0.0  # substrate failure events per second
    failure_downtime_s: float | None = None  # mean downtime (None: stay down)
    failures: list | None = None  # explicit schedule, overrides failure_rate
    ha: bool = False
    name: str = ""  # optional human label; not part of the content hash
    tags: dict = field(default_factory=dict)  # free-form grouping metadata

    def __post_init__(self) -> None:
        if self.mode not in (IF, TR):
            raise ValueError(f"mode must be IF|TR, got {self.mode!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}")
        if self.n_microbatches < 1:
            raise ValueError("n_microbatches must be >= 1")
        # The one capability check: unknown solver names and solver/schedule
        # mismatches (e.g. ilp models seq only) both come from the registry.
        ensure_solver_supported(self.solver, schedule=self.schedule,
                                batch_size=self.batch_size,
                                n_microbatches=self.n_microbatches)
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival must be one of {ARRIVALS}")
        if self.policy not in POLICY_NAMES:
            raise ValueError(f"policy must be one of {POLICY_NAMES}")
        if self.hold_model not in HOLD_MODELS:
            raise ValueError(f"hold_model must be one of {HOLD_MODELS}")
        if not 0.0 <= self.train_share <= 1.0:
            raise ValueError(f"train_share must be in [0, 1], "
                             f"got {self.train_share!r}")
        if self.train_share > 0.0 and self.n_requests < 2:
            raise ValueError("train_share mixes modes across a fleet; it "
                             "requires n_requests > 1 (set mode=TR for a "
                             "single training chain)")
        if self.sim and self.n_requests < 2:
            raise ValueError("sim=True needs a fleet (n_requests > 1)")
        if self.gateway:
            if self.sim:
                raise ValueError("sim and gateway are mutually exclusive "
                                 "drivers of the same fleet")
            if self.n_requests < 2:
                raise ValueError("gateway=True needs a fleet (n_requests > 1)")
        if self.batch_window_s < 0:
            raise ValueError("batch_window_s must be >= 0")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if self.slo_latency_s is not None and not self.slo_latency_s > 0:
            raise ValueError("slo_latency_s must be > 0 (or None)")
        if not self.gateway and (self.batch_window_s != 0.0
                                 or self.max_queue is not None
                                 or self.slo_latency_s is not None):
            raise ValueError("batch_window_s / max_queue / slo_latency_s "
                             "require gateway=True")
        if self.hold_model != "none":
            if not (self.sim or self.gateway):
                raise ValueError("hold_model requires sim=True or "
                                 "gateway=True (holding times only act "
                                 "through departures)")
            if self.duration_s is None or not (
                    self.duration_s > 0 and math.isfinite(self.duration_s)):
                raise ValueError(f"hold_model={self.hold_model!r} needs a "
                                 f"positive finite duration_s, got "
                                 f"{self.duration_s!r}")
        elif self.duration_s is not None:
            raise ValueError("duration_s is only meaningful with "
                             "hold_model in ('fixed', 'exp')")
        if self.retry and not (self.sim or self.gateway):
            raise ValueError("retry requires sim=True or gateway=True")
        if self.failure_rate < 0:
            raise ValueError("failure_rate must be >= 0")
        if (self.failure_downtime_s is not None
                and not self.failure_downtime_s > 0):
            raise ValueError("failure_downtime_s must be > 0 (or None)")
        has_failures = (self.failure_rate > 0 or self.failures is not None
                        or self.ha)
        if has_failures and not (self.sim or self.gateway):
            raise ValueError("failure_rate / failures / ha require sim=True "
                             "or gateway=True (failures act on the live "
                             "event timeline)")
        if self.failure_downtime_s is not None and not has_failures:
            raise ValueError("failure_downtime_s is only meaningful with "
                             "failure_rate > 0 or an explicit failures list")
        if self.failures is not None:
            norm = []
            for entry in self.failures:
                if len(entry) != 3:
                    raise ValueError(f"each failures entry must be "
                                     f"[t_s, kind, target], got {entry!r}")
                t_s, kind, target = entry
                norm.append([float(t_s), kind,
                             list(target) if isinstance(target, (list, tuple))
                             else target])
            self.failures = norm
        self.drop_links = [list(p) for p in self.drop_links]
        if self.candidates is not None:
            self.candidates = [list(c) for c in self.candidates]

    # ----------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        return cls(**d)

    def key(self) -> str:
        """Canonical JSON of the solve-relevant fields (exactly the
        HASH_IRRELEVANT allowlist is excluded — enforced by the `spec-hash`
        lint rule)."""
        d = self.to_dict()
        for f in HASH_IRRELEVANT:
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    def spec_hash(self) -> str:
        return hashlib.sha256(self.key().encode()).hexdigest()[:16]

    def scenario_id(self) -> str:
        sched = f"_pipeM{self.n_microbatches}" if self.schedule == PIPE else ""
        return self.name or (
            f"{self.topology}_{self.profile}_{self.mode}_K{self.K}"
            f"_b{self.batch_size}{sched}_{self.solver}_s{self.candidate_seed}"
            f"_{self.spec_hash()[:6]}"
        )

    def group_key(self) -> str:
        """Canonical key of everything *except* the solver — scenarios sharing a
        group key are the same problem instance solved by different schemes."""
        d = self.to_dict()
        for f in ("name", "tags", "solver", "solver_kwargs"):
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    def schedule_key(self) -> str:
        """Canonical key of everything *except* the schedule — a pipe scenario
        and its seq counterpart (same instance, same solver) share this key,
        which is what the seq-vs-pipe speedup report pairs on."""
        d = self.to_dict()
        for f in ("name", "tags", "schedule", "n_microbatches"):
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    def training_key(self) -> str:
        """Canonical key of everything *except* ``train_share`` — a mixed
        training fleet and its all-IF twin (identical arrivals, candidates,
        and holding times by stream construction) share this key, which is
        what the report's training-contention pairing uses."""
        d = self.to_dict()
        for f in ("name", "tags", "train_share"):
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    def churn_key(self) -> str:
        """Canonical key of everything *except* the churn knobs — a sim
        scenario and its static counterpart (identical fleet, solver, and
        policy) share this key, which is what the report's static-vs-churn
        acceptance-uplift pairing uses."""
        d = self.to_dict()
        for f in ("name", "tags", "sim", "hold_model", "duration_s", "retry",
                  "gateway", "batch_window_s", "max_queue", "slo_latency_s",
                  "failure_rate", "failure_downtime_s", "failures", "ha"):
            d.pop(f, None)
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    # ------------------------------------------------------------ construction
    def build_network(self) -> PhysicalNetwork:
        return build_topology(self.topology, self.topology_kwargs,
                              tuple(self.drop_nodes),
                              tuple(tuple(p) for p in self.drop_links))

    def build_profile(self) -> ModelProfile:
        return build_profile(self.profile, self.profile_kwargs)

    def build_candidates(self, net: PhysicalNetwork) -> list[list[str]]:
        if self.candidates is not None:
            return [list(c) for c in self.candidates]
        return candidate_sets(self.K, self.candidate_seed, sorted(net.nodes),
                              self.source, self.destination,
                              self.candidates_per_stage)

    def request(self) -> ServiceChainRequest:
        return ServiceChainRequest(self.profile, self.source, self.destination,
                                   self.batch_size, self.mode,
                                   schedule=self.schedule,
                                   n_microbatches=self.n_microbatches)

    def problem(self, net: PhysicalNetwork | None = None,
                profile: ModelProfile | None = None) -> ProblemInstance:
        """The spec's single-chain :class:`ProblemInstance` (built objects can
        be passed in to reuse the runner's per-process context caches).  Fleet
        specs (``n_requests > 1``) describe an admission round, not one solve."""
        if self.n_requests > 1:
            raise ValueError("a fleet spec (n_requests > 1) is an admission "
                             "round, not a single ProblemInstance")
        net = net if net is not None else self.build_network()
        profile = profile if profile is not None else self.build_profile()
        return ProblemInstance(net, profile, self.request(), self.K,
                               tuple(tuple(c) for c in
                                     self.build_candidates(net)))

    def instance_key(self) -> str:
        """Content hash of the spec's problem — the same identity the serve
        layer's presolve dedup uses (``ServeRequest.solve_key``), so sweep
        instance grouping and serve dedup can never disagree."""
        return self.problem().content_hash()

    def build_fleet(self, net: PhysicalNetwork):
        """The seeded request fleet of a serve scenario (n_requests > 1)."""
        from repro.serve.requests import generate_fleet

        return generate_fleet(
            net, self.n_requests, self.source, self.destination,
            self.batch_size, self.mode, self.K, seed=self.candidate_seed,
            arrival=self.arrival, candidates=self.candidates,
            candidates_per_stage=self.candidates_per_stage,
            model_id=self.profile, schedule=self.schedule,
            n_microbatches=self.n_microbatches,
            hold_model=self.hold_model,
            hold_time_s=(self.duration_s if self.duration_s is not None
                         else float("inf")),
            ha=self.ha, train_share=self.train_share)

    def build_failures(self, net: PhysicalNetwork, fleet) -> list:
        """The scenario's substrate-failure schedule (docs/failures.md):
        the explicit ``failures`` list when pinned, else a seeded schedule
        from ``failure_rate`` over the fleet's active horizon.  Deterministic
        from the spec alone, so ``verify_result`` can rebuild the exact
        schedule a result was produced under."""
        from repro.serve.failures import FailureEvent, generate_failures

        if self.failures is not None:
            events = []
            for t_s, kind, target in self.failures:
                if isinstance(target, (list, tuple)):
                    events.append(FailureEvent(t_s, kind,
                                               link=tuple(target)))
                else:
                    events.append(FailureEvent(t_s, kind, node=target))
            return sorted(events, key=lambda e: e.t_s)
        if self.failure_rate <= 0:
            return []
        horizon = (max(r.arrival_s for r in fleet)
                   + (self.duration_s if self.duration_s is not None
                      else 10.0))
        return generate_failures(
            net, rate_per_s=self.failure_rate, horizon_s=horizon,
            seed=self.candidate_seed,
            mean_downtime_s=self.failure_downtime_s,
            protect=(self.source, self.destination))
