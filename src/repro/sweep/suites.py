"""Named scenario suites — the registry every benchmark and the CLI draw from.

Each suite function returns ``list[ScenarioSpec]`` and takes ``quick`` (reduced
grids for CI) plus optional keyword filters so the benchmark drivers can slice
a suite (e.g. one mode of the NSFNET paper grid).
"""
from __future__ import annotations

from repro.core import IF, TR

from .spec import ScenarioSpec, candidate_sets

# The paper's NSFNET node ordering (v1..v14) — candidate sampling is seeded, so
# the ordering is part of the reproducible scenario definition.
NSFNET_NODES = [f"v{i}" for i in range(1, 15)]
SOURCE, DEST = "v4", "v13"

# `exact` is the ILP-equivalent joint DP (tests prove equality with the HiGHS
# MILP); the latency grids use it so the full paper sweep stays fast.  `ilp`
# is reserved for the exec-time suites, where its wall time is the measurement.
# `portfolio` is the engine's best-of-heuristics meta-solver (docs/solvers.md);
# sweeping it alongside its members shows the best-of gap vs the optimum.
LATENCY_SCHEMES = ("exact", "bcd", "comp-ms", "comm-ms", "portfolio")
EXEC_SCHEMES = ("ilp", "bcd", "comp-ms", "comm-ms")


def _nsfnet_spec(mode: str, K: int, b: int, solver: str, seed: int,
                 tags: dict, **overrides) -> ScenarioSpec:
    cands = candidate_sets(K, seed, NSFNET_NODES, SOURCE, DEST)
    return ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": SOURCE},
        profile="resnet101", source=SOURCE, destination=DEST,
        batch_size=b, mode=mode, K=K, solver=solver,
        candidates=cands, candidate_seed=seed,
        tags={"suite": "nsfnet_paper", "seed": seed, **tags},
        **overrides,
    )


def nsfnet_paper(quick: bool = False, modes: tuple[str, ...] = (IF, TR),
                 seeds: int = 10,
                 schemes: tuple[str, ...] = LATENCY_SCHEMES) -> list[ScenarioSpec]:
    """Figs. 4 & 5 grid: latency vs (K, b) per scheme, averaged over seeds."""
    ks = [2, 3, 5] if quick else list(range(2, 8))
    bs = [2, 128] if quick else [2**i for i in range(0, 9)]
    n_seeds = 3 if quick else seeds
    specs = []
    for mode in modes:
        fig = "fig4" if mode == IF else "fig5"
        for K in ks:
            for b in bs:
                for solver in schemes:
                    for seed in range(n_seeds):
                        specs.append(_nsfnet_spec(
                            mode, K, b, solver, seed,
                            {"figure": fig, "cell": f"K{K}_b{b}"}))
    return specs


def exec_time_k(quick: bool = False,
                ilp_time_limit_s: float = 120.0) -> list[ScenarioSpec]:
    """Fig. 10: solver wall time vs chain length K (training, b=128)."""
    ks = [2, 4] if quick else list(range(2, 8))
    specs = []
    for K in ks:
        n_seeds = 1 if (quick or K >= 6) else 3  # big-K MILPs are slow (1 core)
        for solver in EXEC_SCHEMES:
            for seed in range(n_seeds):
                kw = {"time_limit_s": ilp_time_limit_s} if solver == "ilp" else {}
                specs.append(_nsfnet_spec(
                    TR, K, 128, solver, seed,
                    {"suite": "exec_time_k", "figure": "fig10", "cell": f"K{K}"},
                    solver_kwargs=kw))
    return specs


def random_scaling(quick: bool = False,
                   ilp_time_limit_s: float = 120.0) -> list[ScenarioSpec]:
    """Fig. 11 scaling ladder: random G(V, p=0.2) networks, K=4, training."""
    vs = [10, 20] if quick else [10, 20, 30, 40, 50]
    specs = []
    for V in vs:
        nodes = sorted(f"v{i}" for i in range(1, V + 1))
        dest = nodes[-1]
        for solver in EXEC_SCHEMES:
            if solver == "ilp" and V >= 30 and quick:
                continue
            cands = candidate_sets(4, 0, nodes, "v1", dest)
            kw = {"time_limit_s": ilp_time_limit_s} if solver == "ilp" else {}
            specs.append(ScenarioSpec(
                topology="random",
                topology_kwargs={"n_nodes": V, "p": 0.2, "seed": 7,
                                 "source": "v1"},
                profile="resnet101", source="v1", destination=dest,
                batch_size=128, mode=TR, K=4, solver=solver,
                solver_kwargs=kw, candidates=cands,
                tags={"suite": "random_scaling", "figure": "fig11",
                      "cell": f"V{V}"}))
    return specs


def tpu_pod(quick: bool = False) -> list[ScenarioSpec]:
    """TPU-pod graphs: pattern-group profiles planned over ICI/DCN topologies."""
    grids = ([("qwen2-1.5b", 8, 16, 1)] if quick
             else [("qwen2-1.5b", 8, 16, 1), ("qwen2-1.5b", 16, 16, 2),
                   ("qwen3-14b", 8, 32, 1)])
    ks = [2, 4]
    specs = []
    for arch, n_groups, chips, n_pods in grids:
        nodes = sorted(f"p{p}g{g}" for p in range(n_pods) for g in range(n_groups))
        for K in ks:
            for mode, b in ((TR, 8), (IF, 32)):
                for solver in ("exact", "bcd"):
                    specs.append(ScenarioSpec(
                        topology="tpu_pod",
                        topology_kwargs={"n_groups": n_groups,
                                         "chips_per_group": chips,
                                         "n_pods": n_pods},
                        profile="group",
                        profile_kwargs={"arch": arch, "seq_len": 2048,
                                        "mode": "train" if mode == TR else "prefill"},
                        source=nodes[0], destination=nodes[-1],
                        batch_size=b, mode=mode, K=K, solver=solver,
                        tags={"suite": "tpu_pod", "arch": arch,
                              "cell": f"{arch}_g{n_groups}x{chips}_K{K}_{mode}"}))
    return specs


def nsfnet_faults(quick: bool = False) -> list[ScenarioSpec]:
    """Fault-injected NSFNET variants: kill a transit node or trunk link and
    compare how BCD re-plans against the optimum on the degraded fabric."""
    faults = [
        ("baseline", [], []),
        ("node_v7_down", ["v7"], []),
        ("node_v9_down", ["v9"], []),
        ("link_v4_v5_down", [], [["v4", "v5"]]),
        ("links_v6_down", [], [["v6", "v10"], ["v6", "v13"]]),
    ]
    if quick:
        faults = faults[:3]
    specs = []
    for fname, drop_nodes, drop_links in faults:
        alive = [n for n in NSFNET_NODES if n not in drop_nodes]
        for seed in range(1 if quick else 3):
            cands = candidate_sets(3, seed, alive, SOURCE, DEST)
            for solver in ("exact", "bcd"):
                for mode, b in ((IF, 2), (TR, 128)):
                    specs.append(ScenarioSpec(
                        topology="nsfnet", topology_kwargs={"source": SOURCE},
                        drop_nodes=list(drop_nodes), drop_links=drop_links,
                        profile="resnet101", source=SOURCE, destination=DEST,
                        batch_size=b, mode=mode, K=3, solver=solver,
                        candidates=cands, candidate_seed=seed,
                        tags={"suite": "nsfnet_faults", "fault": fname,
                              "cell": f"{fname}_{mode}_b{b}", "seed": seed}))
    return specs


def nsfnet_pipeline(quick: bool = False,
                    microbatches: tuple[int, ...] | None = None,
                    schemes: tuple[str, ...] = ("bcd",)) -> list[ScenarioSpec]:
    """Seq-vs-pipe grid on NSFNET: every cell is solved once under the paper's
    sequential schedule and once per pipeline depth M (docs/pipeline.md).

    Pipe scenarios use BCD (schedule-aware, seq-anchored, so pipe <= seq per
    pair by construction); the seq side additionally runs ``exact`` as the
    optimality reference.  The report's ``schedule_comparison`` section and the
    CSV's ``seq_latency_s`` / ``pipe_speedup`` columns come from this pairing.
    The exact pipelined joint DP is a small-instance parity oracle (its
    bottleneck-cap scan multiplies the DP cost), so it is deliberately not
    swept here.
    """
    if microbatches is None:
        microbatches = (1, 4, 16) if quick else (1, 2, 4, 8, 16, 32)
    ks = [3] if quick else [3, 5]
    cells = [(IF, 32), (TR, 128)]
    seeds = 1 if quick else 3
    specs = []
    for K in ks:
        for mode, b in cells:
            for seed in range(seeds):
                tags = {"suite": "nsfnet_pipeline",
                        "cell": f"K{K}_b{b}_{mode}", "seed": seed}
                for solver in dict.fromkeys(("exact",) + tuple(schemes)):
                    specs.append(_nsfnet_spec(mode, K, b, solver, seed, tags))
                for solver in schemes:
                    for M in microbatches:
                        specs.append(_nsfnet_spec(
                            mode, K, b, solver, seed, tags,
                            schedule="pipe", n_microbatches=M))
    return specs


def nsfnet_multirequest(quick: bool = False,
                        policies: tuple[str, ...] = ("fcfs", "latency-greedy",
                                                     "batch-desc"),
                        schemes: tuple[str, ...] = ("exact", "bcd")
                        ) -> list[ScenarioSpec]:
    """Concurrent serving on NSFNET: fleets of chains (batch spread x1/x2/x4)
    admitted onto one fabric with residual-capacity accounting.  Groups share
    everything but the solver, so the report compares BCD's acceptance ratio
    against the exact replanner's under identical policies and load."""
    fleets = [4, 16] if quick else [2, 4, 8, 16, 32]
    seeds = 1 if quick else 3
    specs = []
    for n in fleets:
        for policy in policies:
            for solver in schemes:
                for seed in range(seeds):
                    specs.append(ScenarioSpec(
                        topology="nsfnet", topology_kwargs={"source": SOURCE},
                        profile="resnet101", source=SOURCE, destination=DEST,
                        batch_size=2, mode=IF, K=3, solver=solver,
                        candidate_seed=seed,
                        n_requests=n, arrival="batch", policy=policy,
                        tags={"suite": "nsfnet_multirequest", "seed": seed,
                              "cell": f"n{n}_{policy}"}))
    return specs


def nsfnet_churn(quick: bool = False,
                 policies: tuple[str, ...] = ("fcfs",),
                 schemes: tuple[str, ...] = ("bcd",),
                 hold_s: float = 4.0) -> list[ScenarioSpec]:
    """Dynamic admission under churn vs the static snapshot round
    (docs/sim.md): every cell is one Poisson fleet admitted twice — once as
    today's one-shot `ServePlanner.admit` (every accepted chain holds its
    reservation forever) and once through the event-driven `ServeSim` with
    Exponential(mean `hold_s`) holding times and the retry queue.  Both
    variants share the *identical* fleet (holding times come from a dedicated
    seeded stream), pair on ``ScenarioSpec.churn_key()``, and feed the
    report's ``churn_comparison`` section: on overloaded cells the churn
    acceptance is strictly higher, because capacity released by departures is
    re-used — the regime the ROADMAP's "heavy traffic" north star needs."""
    fleets = [16, 32] if quick else [8, 16, 32, 64]
    seeds = 1 if quick else 3
    specs = []
    for n in fleets:
        for policy in policies:
            for solver in schemes:
                for seed in range(seeds):
                    base = dict(
                        topology="nsfnet", topology_kwargs={"source": SOURCE},
                        profile="resnet101", source=SOURCE, destination=DEST,
                        batch_size=2, mode=IF, K=3, solver=solver,
                        candidate_seed=seed, n_requests=n, arrival="poisson",
                        policy=policy)
                    tags = {"suite": "nsfnet_churn", "seed": seed,
                            "cell": f"n{n}_{policy}"}
                    specs.append(ScenarioSpec(
                        **base, tags={**tags, "variant": "static"}))
                    specs.append(ScenarioSpec(
                        **base, sim=True, hold_model="exp", duration_s=hold_s,
                        retry=True, tags={**tags, "variant": "churn"}))
    return specs


def nsfnet_failures(quick: bool = False,
                    policies: tuple[str, ...] = ("fcfs",),
                    schemes: tuple[str, ...] = ("bcd",),
                    hold_s: float = 6.0,
                    failure_rates: tuple[float, ...] | None = None
                    ) -> list[ScenarioSpec]:
    """Survivability under substrate failures (docs/failures.md): every cell
    is one Poisson churn fleet admitted at several failure rates — the
    ``rate 0`` anchor is bit-for-bit the plain churn run (``failures`` stays
    None, so the failure-free code path is exercised, not just skipped) —
    plus an HA variant at the highest rate, where each chain pre-plans a
    disjoint standby promoted on failure.  Failed resources recover after
    Exponential(mean ``2 * hold_s``) downtime, so the curves show both the
    migration transient and the post-recovery steady state; the report's
    ``failure_survivability`` section and the CSV's ``n_failed`` /
    ``n_restored`` / ``restore_p95_s`` / ``moved_bytes`` columns come from
    this suite."""
    if failure_rates is None:
        failure_rates = (0.0, 0.2) if quick else (0.0, 0.1, 0.2, 0.4)
    fleets = [16] if quick else [16, 32, 64]
    seeds = 1 if quick else 3
    specs = []
    for n in fleets:
        for policy in policies:
            for solver in schemes:
                for seed in range(seeds):
                    base = dict(
                        topology="nsfnet", topology_kwargs={"source": SOURCE},
                        profile="resnet101", source=SOURCE, destination=DEST,
                        batch_size=2, mode=IF, K=3, solver=solver,
                        candidate_seed=seed, n_requests=n, arrival="poisson",
                        policy=policy, sim=True, hold_model="exp",
                        duration_s=hold_s, retry=True)
                    tags = {"suite": "nsfnet_failures", "seed": seed,
                            "cell": f"n{n}_{policy}"}
                    for rate in failure_rates:
                        specs.append(ScenarioSpec(
                            **base, failure_rate=rate,
                            failure_downtime_s=(2 * hold_s if rate else None),
                            tags={**tags, "variant": f"rate{rate}",
                                  "failure_rate": rate}))
                    specs.append(ScenarioSpec(
                        **base, failure_rate=failure_rates[-1],
                        failure_downtime_s=2 * hold_s, ha=True,
                        tags={**tags, "variant": "ha",
                              "failure_rate": failure_rates[-1]}))
    return specs


def nsfnet_gateway(quick: bool = False,
                   policies: tuple[str, ...] = ("fcfs",),
                   schemes: tuple[str, ...] = ("bcd",),
                   hold_s: float = 4.0,
                   windows: tuple[float, ...] | None = None
                   ) -> list[ScenarioSpec]:
    """Streaming admission through the `ServeGateway` (docs/gateway.md):
    every cell is one Poisson fleet admitted twice — once as the static
    one-shot round and once streamed through the gateway with
    Exponential(mean `hold_s`) holding times, the retry queue, and a swept
    arrival batching window.  Variants share the identical fleet and pair on
    ``ScenarioSpec.churn_key()``; the gateway rows additionally surface the
    plan-cache / eval-cache hit rates and per-tick stats in the artifact."""
    if windows is None:
        windows = (0.0, 0.5) if quick else (0.0, 0.25, 0.5, 1.0)
    fleets = [16, 32] if quick else [8, 16, 32, 64]
    seeds = 1 if quick else 3
    specs = []
    for n in fleets:
        for policy in policies:
            for solver in schemes:
                for seed in range(seeds):
                    base = dict(
                        topology="nsfnet", topology_kwargs={"source": SOURCE},
                        profile="resnet101", source=SOURCE, destination=DEST,
                        batch_size=2, mode=IF, K=3, solver=solver,
                        candidate_seed=seed, n_requests=n, arrival="poisson",
                        policy=policy)
                    tags = {"suite": "nsfnet_gateway", "seed": seed,
                            "cell": f"n{n}_{policy}"}
                    specs.append(ScenarioSpec(
                        **base, tags={**tags, "variant": "static"}))
                    for w in windows:
                        specs.append(ScenarioSpec(
                            **base, gateway=True, batch_window_s=w,
                            hold_model="exp", duration_s=hold_s, retry=True,
                            tags={**tags, "variant": "gateway",
                                  "window": w}))
    return specs


def nsfnet_mixed_training(quick: bool = False,
                          shares: tuple[float, ...] | None = None,
                          archs: tuple[tuple[str, dict], ...] | None = None,
                          policies: tuple[str, ...] = ("fcfs",),
                          schemes: tuple[str, ...] = ("bcd",),
                          n_microbatches: int = 4) -> list[ScenarioSpec]:
    """Mixed training/inference fleets on NSFNET (docs/training.md): every
    cell is one Poisson fleet admitted at several ``train_share`` values —
    each request is drawn TR (a round-trip pipelined training chain whose
    gradients occupy the links' backward channels) or IF from a dedicated
    seeded stream, so the ``share 0`` anchor is bit-for-bit the all-IF fleet
    and every mixed variant sees identical arrivals/candidates, pairing on
    ``ScenarioSpec.training_key()``.  Fleets are heterogeneous across the
    model zoo: the paper's ResNet101 profile plus pattern-group train-mode
    profiles of the assigned architectures that *fit* NSFNET's 2 GiB edge
    nodes (the SSM and encoder-decoder members; the multi-GB LLMs belong to
    the ``tpu_pod`` suite).  All chains run the pipelined schedule
    (M = ``n_microbatches``), so TR admissions price the two-bottleneck round
    trip — the report's ``training_contention`` section and the CSV's
    mode-split columns come from this suite."""
    if shares is None:
        shares = (0.0, 0.5) if quick else (0.0, 0.25, 0.5, 0.75)
    if archs is None:
        zoo = [("mamba2-370m", 256)] if quick else [
            ("mamba2-370m", 1024), ("whisper-small", 1500)]
        archs = (("resnet101", {}),) + tuple(
            ("group", {"arch": a, "seq_len": s, "mode": "train"})
            for a, s in zoo)
    fleets = [8] if quick else [8, 16, 32]
    seeds = 1 if quick else 3
    specs = []
    for profile, prof_kwargs in archs:
        label = prof_kwargs.get("arch", profile)
        for n in fleets:
            for policy in policies:
                for solver in schemes:
                    for seed in range(seeds):
                        for share in shares:
                            specs.append(ScenarioSpec(
                                topology="nsfnet",
                                topology_kwargs={"source": SOURCE},
                                profile=profile, profile_kwargs=prof_kwargs,
                                source=SOURCE, destination=DEST,
                                batch_size=2, mode=IF, K=3, solver=solver,
                                candidate_seed=seed, n_requests=n,
                                arrival="poisson", policy=policy,
                                schedule="pipe",
                                n_microbatches=n_microbatches,
                                train_share=share,
                                tags={"suite": "nsfnet_mixed_training",
                                      "seed": seed, "arch": label,
                                      "cell": f"{label}_n{n}_{policy}",
                                      "train_share": share}))
    return specs


def random_load_scaling(quick: bool = False,
                        policies: tuple[str, ...] = ("fcfs", "latency-greedy")
                        ) -> list[ScenarioSpec]:
    """Load ladder on random G(V, p=0.2) fabrics: growing Poisson fleets of
    training chains, acceptance ratio and latency percentiles vs load."""
    vs = [10, 20] if quick else [10, 20, 30, 40]
    loads = [8, 32] if quick else [8, 16, 32, 64]
    specs = []
    for V in vs:
        dest = sorted(f"v{i}" for i in range(1, V + 1))[-1]
        for n in loads:
            for policy in policies:
                specs.append(ScenarioSpec(
                    topology="random",
                    topology_kwargs={"n_nodes": V, "p": 0.2, "seed": 7,
                                     "source": "v1"},
                    profile="resnet101", source="v1", destination=dest,
                    batch_size=2, mode=TR, K=4, solver="bcd",
                    n_requests=n, arrival="poisson", policy=policy,
                    tags={"suite": "random_load_scaling",
                          "cell": f"V{V}_n{n}_{policy}"}))
    return specs


SUITES = {
    "nsfnet_paper": nsfnet_paper,
    "exec_time_k": exec_time_k,
    "random_scaling": random_scaling,
    "tpu_pod": tpu_pod,
    "nsfnet_faults": nsfnet_faults,
    "nsfnet_pipeline": nsfnet_pipeline,
    "nsfnet_multirequest": nsfnet_multirequest,
    "nsfnet_churn": nsfnet_churn,
    "nsfnet_failures": nsfnet_failures,
    "nsfnet_gateway": nsfnet_gateway,
    "nsfnet_mixed_training": nsfnet_mixed_training,
    "random_load_scaling": random_load_scaling,
}
