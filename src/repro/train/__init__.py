from .steps import chunked_xent, loss_fn, make_eval_step, make_train_step

__all__ = ["make_train_step", "make_eval_step", "loss_fn", "chunked_xent"]
