"""Training step: chunked cross-entropy loss, grads, clipping, optimizer update.

Loss is computed in token chunks (`cfg.loss_chunk`) so the (tokens, vocab)
logits are never materialized at once — at 151k vocab x 1M tokens that is the
difference between fitting and not fitting HBM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as T
from ..models.layers import Ctx, softcap
from ..models.sharding import constrain
from ..optim import Optimizer, clip_by_global_norm

AUX_LOSS_COEF = 0.01


def chunked_xent(hidden, head_w, targets, cfg: ModelConfig):
    """hidden (B, S, D), head_w (D, V), targets (B, S) -> mean nll (fp32).

    The chunk COUNT is bounded (<= 8): each scan step re-gathers the sharded
    head matrix, so at 128k+ vocab a fixed 4096-token chunk size meant 256
    gathers of a multi-GB fp32 matrix per step (§Perf).  Chunks exist only to
    cap the live (tokens, vocab) logits block.
    """
    B, S, D = hidden.shape
    T_ = B * S
    # vocab-sharded, D-replicated head (a one-off ~100 MB/device reshard);
    # contracting against the ZeRO-sharded layout instead makes GSPMD gather
    # the multi-GB fp32 (D, V) matrix inside the chunk loop (§Perf)
    head_w = constrain(head_w, (None, "tp"))
    # chunk along SEQUENCE, keeping (B, Sc, D) 3-D chunks: flattening (B, S)
    # merges differently-sharded dims, which GSPMD can only resolve by
    # all-gathering the whole fp32 stack (28 GB on arctic — §Perf)
    n = max(1, min(8, T_ // max(1, cfg.loss_chunk)))
    while S % n:
        n -= 1
    xs = jnp.moveaxis(hidden.reshape(B, n, S // n, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, S // n), 1, 0)

    def body(acc, inp):
        xc, tc = inp  # (B, Sc, D), (B, Sc)
        logits = (xc @ head_w).astype(jnp.float32)
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        # tokens on batch/DP axes, vocab on tp: keeps dlogits in the same
        # layout the head gradient needs (the (batch, tp)-flat layout made
        # GSPMD all-gather 62 GB of fp32 logits in the backward — §Perf)
        logits = constrain(logits, ("batch", None, "tp"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction, NOT take_along_axis: gathering along the
        # vocab-sharded dim all-gathers the full (chunk, V) fp32 logits
        onehot = jax.nn.one_hot(tc, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
        return acc + jnp.sum(lse - gold), None

    # recompute logits in the backward pass: the scan otherwise stacks every
    # chunk's fp32 (chunk, vocab) logits as saved residuals (§Perf)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
    return total / T_


def loss_fn(params, cfg: ModelConfig, batch, mode: str = "train"):
    tokens, targets = batch["tokens"], batch["targets"]
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = Ctx(mode=mode, positions=pos)
    hidden, _, aux = T.forward(params, cfg, tokens, ctx,
                               memory=batch.get("memory"))
    head_w = T.head_matrix(params, cfg).astype(hidden.dtype)
    nll = chunked_xent(hidden, head_w, targets, cfg)
    return nll + AUX_LOSS_COEF * aux, {"nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, opt: Optimizer, max_grad_norm: float = 1.0):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, mode="prefill")
        return dict(metrics, loss=loss)

    return eval_step
