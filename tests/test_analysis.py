"""repro-lint (src/repro/analysis): fixture suite per rule, baseline
round-trip, CLI exit-code contract, and the whole-repo smoke.

Each rule gets a known-bad and a known-good fixture written into a tmp
mini-project; assertions name the rule so a regression in one rule cannot
hide behind another.  The whole-repo smoke pins the acceptance criterion:
``python -m repro.analysis --strict src/repro`` exits 0 on the shipped tree
under the shipped baseline.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Finding, load_baseline, run_analysis,
                            save_baseline)
from repro.analysis.cli import main as cli_main

REPO = Path(__file__).resolve().parent.parent


def write_project(tmp_path: Path, files: dict[str, str]) -> Path:
    root = tmp_path / "proj"
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return root


def findings_for(tmp_path, files, select=None) -> list[Finding]:
    root = write_project(tmp_path, files)
    return run_analysis([root], root, select=select)


def rules_hit(findings) -> set[str]:
    return {f.rule for f in findings}


# --------------------------------------------------------------- cache-key
GOOD_EVAL_KEYS = """
    class Ev:
        def segment_comp_s(self, node, lo, hi):
            key = (node, lo, hi, *self._ck)
            hit = self.cache.comp.get(key)
            if hit is None:
                self.cache.comp[key] = 1.0
            return hit

    def segment_comp_dir_s(ev, node, lo, hi, direction):
        key = (node, lo, hi, direction, *ev._ck)
        cache = ev.cache
        hit = cache.comp.get(key)
        cache.comp[key] = 2.0
        return hit
"""


def test_cache_key_good_families_clean(tmp_path):
    fs = findings_for(tmp_path, {"core/plan.py": GOOD_EVAL_KEYS},
                      select=["cache-key"])
    assert fs == []


def test_cache_key_missing_ck_tail(tmp_path):
    fs = findings_for(tmp_path, {"core/plan.py": """
        def f(cache, node, lo, hi, b):
            key = (node, lo, hi, b)
            cache.comp[key] = 1.0
    """}, select=["cache-key"])
    assert len(fs) == 1 and fs[0].rule == "cache-key"
    assert "_ck" in fs[0].message


def test_cache_key_unrecognized_constructor(tmp_path):
    fs = findings_for(tmp_path, {"core/plan.py": """
        def f(cache, node):
            cache.fits[make_key(node)] = True
    """}, select=["cache-key"])
    assert [f.rule for f in fs] == ["cache-key"]
    assert "not a recognized key-constructor" in fs[0].message


def test_cache_key_arity_collision_across_files(tmp_path):
    # two distinct families, same literal-prefix arity -> aliasing hazard
    fs = findings_for(tmp_path, {
        "core/plan.py": GOOD_EVAL_KEYS,
        "core/other.py": """
            def g(ev, node, cut, extra):
                key = (node, cut, extra, *ev._ck)
                ev.cache.comp[key] = 3.0
        """,
    }, select=["cache-key"])
    assert any("collides in arity" in f.message for f in fs), fs


def test_cache_key_plancache_tuple_key(tmp_path):
    fs = findings_for(tmp_path, {"serve/x.py": """
        def f(plan_cache, net, req, out):
            key = (req.source, req.batch_size)
            hit = plan_cache.get(key)
            plan_cache.put(key, out)
    """}, select=["cache-key"])
    assert len(fs) == 2
    assert all("content hash" in f.message for f in fs)


def test_cache_key_plancache_hash_key_clean(tmp_path):
    fs = findings_for(tmp_path, {"serve/x.py": """
        def f(plan_cache, r, out):
            key = r.solve_key()
            if plan_cache.get(key) is None:
                plan_cache.put(key, out)
    """}, select=["cache-key"])
    assert fs == []


# ------------------------------------------------------------- determinism
def test_determinism_flags_wall_clock_and_global_rng(tmp_path):
    fs = findings_for(tmp_path, {"serve/sim.py": """
        import time, random
        import numpy as np

        def run():
            t0 = time.time()
            x = random.random()
            y = np.random.rand(3)
            rng = np.random.default_rng()
            r2 = random.Random()
            return t0, x, y, rng, r2
    """}, select=["determinism"])
    msgs = "\n".join(f.message for f in fs)
    assert len(fs) == 5, fs
    assert "time.time()" in msgs
    assert "random.random()" in msgs
    assert "np.random.rand()" in msgs
    assert "unseeded np.random.default_rng()" in msgs
    assert "unseeded random.Random()" in msgs


def test_determinism_seeded_and_monotonic_clean(tmp_path):
    fs = findings_for(tmp_path, {"core/x.py": """
        import time, random
        import numpy as np

        def run(seed):
            t0 = time.perf_counter()          # monotonic stats: fine
            rng = random.Random(seed)         # seeded: fine
            g = np.random.default_rng(seed)   # seeded: fine
            p = np.random.Philox(key=seed)    # explicit bit generator: fine
            return t0, rng, g, p
    """}, select=["determinism"])
    assert fs == []


def test_determinism_allowlists_launch_and_other_trees(tmp_path):
    files = {
        "launch/run.py": "import time\n\ndef f():\n    return time.time()\n",
        "models/x.py": "import time\n\ndef f():\n    return time.time()\n",
    }
    fs = findings_for(tmp_path, files, select=["determinism"])
    assert fs == []  # launch/ allowlisted; models/ outside the checked dirs


def test_determinism_noqa_suppresses(tmp_path):
    fs = findings_for(tmp_path, {"sweep/x.py": """
        import time

        def f():
            return time.time()  # noqa: intentional provenance stamp
    """}, select=["determinism"])
    assert fs == []


# ---------------------------------------------------------- solver-registry
REGISTRY_PRELUDE = textwrap.dedent("""
    SEQ, PIPE = "seq", "pipe"
    SCHEDULES = (SEQ, PIPE)

    def register_solver(name, **kw):
        def deco(fn):
            return fn
        return deco
""")


def solver_module(body: str) -> str:
    # dedent each part separately: the prelude and the test body are written
    # at different literal indentation levels
    return REGISTRY_PRELUDE + textwrap.dedent(body)


def test_registry_declared_pipe_unhandled(tmp_path):
    fs = findings_for(tmp_path, {"core/s.py": solver_module("""
        @register_solver("toy", schedules=(SEQ, PIPE))
        def toy_solve(net, profile, request, K, candidates):
            return 42
    """)}, select=["solver-registry"])
    assert len(fs) == 1
    assert "declares schedule 'pipe'" in fs[0].message


def test_registry_undeclared_pipe_handled(tmp_path):
    fs = findings_for(tmp_path, {"core/s.py": solver_module("""
        @register_solver("toy", schedules=(SEQ,))
        def toy_solve(net, profile, request, K, candidates):
            if request.schedule == PIPE:
                return solve_pipelined(request)
            return 42
    """)}, select=["solver-registry"])
    assert len(fs) == 1
    assert "without declaring schedule 'pipe'" in fs[0].message


def test_registry_guard_raise_is_not_handling(tmp_path):
    fs = findings_for(tmp_path, {"core/s.py": solver_module("""
        @register_solver("toy", schedules=(SEQ,))
        def toy_solve(net, profile, request, K, candidates):
            if request.schedule == PIPE:
                raise ValueError("seq only")
            return 42
    """)}, select=["solver-registry"])
    assert fs == []


def test_registry_transitive_handling_through_import(tmp_path):
    fs = findings_for(tmp_path, {
        "core/helper.py": """
            PIPE = "pipe"

            def relax(request):
                if request.schedule == PIPE and request.M > 1:
                    return "pipe-tour"
                return "seq-tour"
        """,
        "core/s.py": solver_module("""
            from .helper import relax

            @register_solver("toy", schedules=(SEQ, PIPE))
            def toy_solve(net, profile, request, K, candidates):
                return relax(request)
        """),
    }, select=["solver-registry"])
    assert fs == []


def test_registry_call_form_and_meta_skip(tmp_path):
    fs = findings_for(tmp_path, {"core/s.py": solver_module("""
        def jax_solve(net, profile, request, K, candidates):
            return 42

        register_solver("toy_jax", schedules=(SEQ, PIPE))(jax_solve)

        @register_solver("meta", schedules=(SEQ, PIPE), meta=True)
        def meta_solve(net, profile, request, K, candidates):
            return 0
    """)}, select=["solver-registry"])
    # call-form registration is checked (pipe declared, unhandled);
    # the meta solver is skipped
    assert len(fs) == 1 and "toy_jax" not in fs[0].message
    assert "jax_solve" in fs[0].message


# ---------------------------------------------------------------- spec-hash
SPEC_PRELUDE = """
    from dataclasses import dataclass, asdict, field
    import json

    HASH_IRRELEVANT = (
        "name",
        "tags",
    )

    @dataclass
    class ScenarioSpec:
        topology: str = "nsfnet"
        name: str = ""
        tags: dict = field(default_factory=dict)
"""


def test_spec_hash_no_key_method_is_skipped(tmp_path):
    # a ScenarioSpec without a key() method in the class body is out of scope
    fs = findings_for(tmp_path, {"sweep/spec.py": SPEC_PRELUDE},
                      select=["spec-hash"])
    assert fs == []


def test_spec_hash_real_shape_clean(tmp_path):
    fs = findings_for(tmp_path, {"sweep/spec.py": SPEC_PRELUDE.replace(
        "        tags: dict = field(default_factory=dict)",
        """        tags: dict = field(default_factory=dict)

        def key(self):
            d = asdict(self)
            for f in HASH_IRRELEVANT:
                d.pop(f, None)
            return json.dumps(d, sort_keys=True)
""")}, select=["spec-hash"])
    assert fs == []


def test_spec_hash_undeclared_pop(tmp_path):
    fs = findings_for(tmp_path, {"sweep/spec.py": SPEC_PRELUDE.replace(
        "        tags: dict = field(default_factory=dict)",
        """        tags: dict = field(default_factory=dict)
        debug_level: int = 0

        def key(self):
            d = asdict(self)
            for f in HASH_IRRELEVANT:
                d.pop(f, None)
            d.pop("debug_level", None)
            return json.dumps(d, sort_keys=True)
""")}, select=["spec-hash"])
    assert len(fs) == 1
    assert "'debug_level'" in fs[0].message
    assert "not declared in HASH_IRRELEVANT" in fs[0].message


def test_spec_hash_stale_allowlist_entry(tmp_path):
    fs = findings_for(tmp_path, {"sweep/spec.py": SPEC_PRELUDE.replace(
        '"tags",', '"tags",\n        "renamed_away",').replace(
        "        tags: dict = field(default_factory=dict)",
        """        tags: dict = field(default_factory=dict)

        def key(self):
            d = asdict(self)
            for f in HASH_IRRELEVANT:
                d.pop(f, None)
            return json.dumps(d, sort_keys=True)
""")}, select=["spec-hash"])
    assert len(fs) == 1
    assert "stale HASH_IRRELEVANT entry 'renamed_away'" in fs[0].message


def test_spec_hash_allowlisted_but_still_hashed(tmp_path):
    fs = findings_for(tmp_path, {"sweep/spec.py": SPEC_PRELUDE.replace(
        "        tags: dict = field(default_factory=dict)",
        """        tags: dict = field(default_factory=dict)

        def key(self):
            d = asdict(self)
            d.pop("name", None)
            return json.dumps(d, sort_keys=True)
""")}, select=["spec-hash"])
    assert len(fs) == 1
    assert "'tags' is declared hash-irrelevant" in fs[0].message


# ------------------------------------------------------------ no-shim-import
SHIM_DEF = """
    def deprecated_solver_alias(name, alias):
        def shim(*a, **k):
            pass
        return shim

    bcd_solve = deprecated_solver_alias("bcd", "bcd_solve")
"""


def test_shim_import_flagged(tmp_path):
    fs = findings_for(tmp_path, {
        "core/__init__.py": SHIM_DEF,
        "serve/planner.py": "from ..core import bcd_solve\n",
    }, select=["no-shim-import"])
    assert len(fs) == 1
    assert fs[0].path == "serve/planner.py"
    assert "deprecated shim 'bcd_solve'" in fs[0].message


def test_shim_defining_module_exempt(tmp_path):
    fs = findings_for(tmp_path, {"core/__init__.py": SHIM_DEF},
                      select=["no-shim-import"])
    assert fs == []


# ------------------------------------------------------------- unused-import
def test_unused_import_flagged_and_noqa(tmp_path):
    fs = findings_for(tmp_path, {"core/x.py": """
        import os
        import sys  # noqa: re-export
        from math import sqrt

        def f():
            return sqrt(2)
    """}, select=["unused-import"])
    assert len(fs) == 1
    assert "'os'" in fs[0].message


def test_unused_import_init_reexports_exempt(tmp_path):
    fs = findings_for(tmp_path, {"core/__init__.py": "from .x import thing\n",
                                 "core/x.py": "thing = 1\n"},
                      select=["unused-import"])
    assert fs == []


def test_unused_import_all_counts_as_use(tmp_path):
    fs = findings_for(tmp_path, {"core/__init__.py": """
        from .x import thing
        import os

        __all__ = ["thing"]
    """, "core/x.py": "thing = 1\n"}, select=["unused-import"])
    assert len(fs) == 1 and "'os'" in fs[0].message


# ------------------------------------------------------- baseline round-trip
def test_baseline_roundtrip_suppresses_and_catches_new(tmp_path):
    files = {"sweep/a.py": "import time\n\ndef f():\n    return time.time()\n"}
    root = write_project(tmp_path, files)
    findings = run_analysis([root], root, select=["determinism"])
    assert len(findings) == 1

    bl_path = root / "lint_baseline.txt"
    save_baseline(bl_path, findings)
    baseline = load_baseline(bl_path)
    kept, suppressed, stale = baseline.apply(findings)
    assert kept == [] and len(suppressed) == 1 and stale == []

    # a NEW finding (different file) is not grandfathered
    (root / "sweep" / "b.py").write_text(
        "import time\n\ndef g():\n    return time.time()\n")
    findings2 = run_analysis([root], root, select=["determinism"])
    kept2, suppressed2, stale2 = baseline.apply(findings2)
    assert len(kept2) == 1 and kept2[0].path == "sweep/b.py"
    assert len(suppressed2) == 1 and stale2 == []

    # suppressed finding survives unrelated line drift in the same file
    (root / "sweep" / "a.py").write_text(
        "import time\n\nPAD = 1\n\n\ndef f():\n    return time.time()\n")
    findings3 = run_analysis([root / "sweep" / "a.py"], root,
                             select=["determinism"])
    kept3, suppressed3, _ = baseline.apply(findings3)
    assert kept3 == [] and len(suppressed3) == 1

    # fix lands -> the entry is stale
    (root / "sweep" / "a.py").write_text("def f(t):\n    return t\n")
    findings4 = run_analysis([root / "sweep" / "a.py"], root,
                             select=["determinism"])
    kept4, _, stale4 = baseline.apply(findings4)
    assert kept4 == [] and len(stale4) == 1


def test_save_baseline_preserves_justifications(tmp_path):
    f = Finding("determinism", "sweep/a.py", 3, "wall-clock call time.time()"
                " in deterministic path")
    bl_path = tmp_path / "bl.txt"
    save_baseline(bl_path, [f])
    text = bl_path.read_text().replace("# TODO: justify this suppression",
                                       "# because reasons")
    bl_path.write_text(text)
    old = load_baseline(bl_path)
    save_baseline(bl_path, [f], old=old)
    assert "# because reasons" in bl_path.read_text()
    assert "TODO" not in bl_path.read_text()


def test_malformed_baseline_raises(tmp_path):
    p = tmp_path / "bl.txt"
    p.write_text("not a valid entry\n")
    with pytest.raises(ValueError, match="malformed baseline entry"):
        load_baseline(p)


# ---------------------------------------------------------------- CLI / exit
def test_cli_exit_codes(tmp_path, capsys):
    root = write_project(tmp_path, {
        "core/bad.py": "import os\n\n\ndef f():\n    return 1\n"})
    # findings -> 1
    assert cli_main(["--root", str(root), "--select", "unused-import",
                     str(root)]) == 1
    out = capsys.readouterr().out
    assert "[unused-import]" in out
    # clean -> 0
    (root / "core" / "bad.py").write_text("def f():\n    return 1\n")
    assert cli_main(["--root", str(root), "--select", "unused-import",
                     str(root)]) == 0
    # unknown rule -> 2
    assert cli_main(["--select", "no-such-rule", str(root)]) == 2
    # missing path -> 2
    assert cli_main([str(root / "nope")]) == 2


def test_cli_update_baseline_then_strict_clean(tmp_path, capsys):
    root = write_project(tmp_path, {
        "sweep/a.py": "import time\n\n\ndef f():\n    return time.time()\n"})
    assert cli_main(["--root", str(root), "--select", "determinism",
                     str(root)]) == 1
    capsys.readouterr()
    assert cli_main(["--root", str(root), "--select", "determinism",
                     "--update-baseline", str(root)]) == 0
    assert cli_main(["--root", str(root), "--select", "determinism",
                     "--strict", str(root)]) == 0
    # stale entry fails under --strict once the violation is fixed
    (root / "sweep" / "a.py").write_text("def f(t):\n    return t\n")
    assert cli_main(["--root", str(root), "--select", "determinism",
                     "--strict", str(root)]) == 1
    assert cli_main(["--root", str(root), "--select", "determinism",
                     str(root)]) == 0  # non-strict: warn only


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("cache-key", "determinism", "solver-registry", "spec-hash",
                 "no-shim-import", "unused-import", "docs-sync"):
        assert rule in out


def test_parse_error_is_a_finding(tmp_path):
    root = write_project(tmp_path, {"core/broken.py": "def f(:\n"})
    fs = run_analysis([root], root, select=["unused-import"])
    assert len(fs) == 1 and fs[0].rule == "parse-error"


# ----------------------------------------------------------- whole-repo gate
def test_whole_repo_strict_clean_under_shipped_baseline():
    """The acceptance criterion: the shipped tree is clean in --strict mode
    (run as a subprocess so the CLI path, baseline auto-load and exit-code
    contract are all exercised end-to-end)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", "src/repro"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_whole_repo_rules_engage_on_shipped_tree():
    """The repo-specific rules must actually engage on the live tree (guards
    against the catalog silently no-opping after a refactor): the EvalCache
    key sites, PlanCache sites and solver registrations are all found."""
    from repro.analysis.base import collect_modules
    from repro.analysis.rules_cache import _eval_sites, _plancache_sites
    from repro.analysis.rules_registry import _registrations

    ctx = collect_modules([REPO / "src" / "repro"], REPO)
    n_eval = sum(len(list(_eval_sites(m.tree))) for m in ctx.modules)
    n_pc = sum(len(list(_plancache_sites(m.tree))) for m in ctx.modules)
    regs = list(_registrations(ctx))
    assert n_eval >= 6, "EvalCache key sites disappeared from the tree?"
    assert n_pc >= 2, "PlanCache get/put sites disappeared from the tree?"
    names = {fn.name for _, fn, _, _ in regs}
    assert {"bcd_solve", "exact_solve", "ilp_solve",
            "portfolio_solve"} <= names
    # declared schedules resolved for the non-meta solvers
    resolved = [d for _, fn, _, d in regs if d is not None]
    assert len(resolved) >= 5


def test_docs_sync_rule_matches_script_behavior():
    from repro.analysis.rules_docs import docs_sync_errors

    errors, n_reachable = docs_sync_errors(REPO)
    assert errors == []
    assert n_reachable >= 9  # every docs/*.md reachable from README
