"""Per-architecture smoke tests: reduced config, one train step + prefill +
decode steps on CPU; asserts output shapes and finiteness (assignment req.)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.serving import make_serve_step, prefill
from repro.train import make_train_step

pytestmark = pytest.mark.slow  # XLA-compiled train/serve steps per arch (~2min)

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(toks)}
    if cfg.memory_len:
        batch["memory"] = jnp.asarray(
            rng.standard_normal((B, cfg.memory_len, cfg.d_model), np.float32))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    opt = make_optimizer(cfg.optimizer, total=100)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed and stayed finite
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(deltas)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_serve_smoke(arch):
    cfg = ARCHS[arch].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, cache = jax.jit(
        lambda p, t, m: prefill(p, cfg, t, cache_len=S + 8, memory=m)
    )(params, batch["tokens"], batch.get("memory"))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    sstep = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(3):
        pos = jnp.full((B, 1), S + i, jnp.int32)
        tok, cache = sstep(params, cache, tok, pos)
        tok = tok.reshape(B, 1)
        assert ((0 <= np.asarray(tok)) & (np.asarray(tok) < cfg.vocab_size)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_loss_decreases(arch):
    """A few steps on the structured synthetic stream should reduce loss."""
    cfg = ARCHS[arch].reduced()
    from repro.data import BatchSpec, SyntheticLM

    spec = BatchSpec(B, S, cfg.vocab_size, memory_len=cfg.memory_len,
                     d_model=cfg.d_model)
    stream = SyntheticLM(spec, seed=1)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    opt = make_optimizer(cfg.optimizer, lr=3e-3, warmup=1, total=50)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(i % 2).items()}
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
