"""Checkpoint roundtrip, gradient compression, elastic re-planning."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import IF, TR, ServiceChainRequest, nsfnet, resnet101_profile
from repro.ft import ElasticPlanController
from repro.optim import (
    compress_with_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
    topk_densify,
    topk_sparsify,
)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"stack": {"groups": [{"w": np.arange(12.0).reshape(3, 4)},
                                        {"b": np.ones((5,), np.float32)}]},
                   "embed": np.full((2, 2), 7, np.int32)},
        "opt": {"m": [np.zeros(3), np.ones(2)], "step": np.int64(5)},
    }
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(3, tree)
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    step, restored = mgr.restore()
    assert step == 7
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": np.ones(3) * s})
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    _, r = mgr.restore(3)
    assert r["x"][0] == 3


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)) * 0.01, jnp.float32)
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale, g.shape, jnp.float32)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # blockwise int8 is ~0.4% noise on gaussians
    # error feedback makes the *accumulated* compressed stream unbiased:
    err = jnp.zeros_like(g)
    acc_true, acc_sent = jnp.zeros_like(g), jnp.zeros_like(g)
    for i in range(20):
        gi = jnp.asarray(rng.standard_normal(g.shape) * 0.01, jnp.float32)
        q, s, err = compress_with_feedback(gi, err)
        acc_true += gi
        acc_sent += dequantize_int8(q, s, g.shape, jnp.float32)
    drift = float(jnp.linalg.norm(acc_true - acc_sent - err))
    assert drift < 1e-3  # residual lives entirely in the feedback buffer


def test_topk_sparsify_roundtrip():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((64, 32)),
                    jnp.float32)
    vals, idx = topk_sparsify(x, frac=0.1)
    dense = topk_densify(vals, idx, x.shape, jnp.float32)
    kept = int((dense != 0).sum())
    assert kept == int(64 * 32 * 0.1)
    # kept entries are exact and are the largest-magnitude ones
    mask = np.asarray(dense) != 0
    np.testing.assert_allclose(np.asarray(dense)[mask], np.asarray(x)[mask])
    assert np.abs(np.asarray(x)[mask]).min() >= np.abs(
        np.asarray(x)[~mask]).max() - 1e-6


def test_elastic_replan_on_failure():
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    req = ServiceChainRequest("resnet101", "v4", "v13", 8, TR)
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    ctl = ElasticPlanController(net, prof, req, 3, cands)
    first = ctl.plan.placement[1]
    assert first in ("v7", "v11")
    new_plan = ctl.fail_node(first, step=10)
    assert first not in new_plan.placement
    kinds = [e.kind for e in ctl.events]
    assert "failure" in kinds and "replan" in kinds


def test_straggler_refit_and_replan():
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    req = ServiceChainRequest("resnet101", "v4", "v13", 8, TR)
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    ctl = ElasticPlanController(net, prof, req, 3, cands)
    node = ctl.plan.placement[1]
    flops = 1e12
    pred = net.nodes[node].compute.comp_time_s(8, flops)
    # report the node as 10x slower, twice (OLS needs 2 points)
    ctl.observe_step(1, node, 8, flops, 10 * pred)
    ctl.observe_step(2, node, 16, flops,
                     10 * net.nodes[node].compute.comp_time_s(16, flops))
    kinds = [e.kind for e in ctl.events]
    assert "straggler" in kinds
    # the fitted model now predicts ~10x the old latency
    newpred = ctl.net.nodes[node].compute.comp_time_s(8, flops)
    assert newpred == pytest.approx(10 * pred, rel=0.2)
