"""Property-based tests (hypothesis) for the planner's invariants.

`hypothesis` is an optional dev dependency (see requirements-dev.txt); the
whole module is skipped when it is not installed so `pytest -x -q` never dies
at collection.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import (
    IF,
    TR,
    LayerProfile,
    ModelProfile,
    PlanEvaluator,
    ServiceChainRequest,
    bcd_solve,
    even_split,
    exact_solve,
    nsfnet,
    validate_segments,
)
from repro.core.baselines import _dp_split
from repro.core.resnet101_profile import resnet101_profile

_settings = settings(max_examples=40, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


@given(L=st.integers(2, 60), K=st.integers(1, 12))
@_settings
def test_even_split_is_valid_partition(L, K):
    if K > L:
        return
    segs = even_split(L, K)
    validate_segments(segs, L)
    sizes = [hi - lo + 1 for lo, hi in segs]
    assert max(sizes) - min(sizes) <= 1  # "evenly dividing" (Alg. 1 line 2)
    assert sum(sizes) == L


@given(
    L=st.integers(3, 12),
    K=st.integers(2, 5),
    costs=st.lists(st.floats(0.01, 100.0), min_size=200, max_size=200),
)
@_settings
def test_dp_split_optimal_vs_bruteforce(L, K, costs):
    """The generic K-segmentation DP (shared by Alg. 2 / COMP-MS / COMM-MS) is
    optimal for arbitrary non-negative additive segment costs."""
    import itertools

    if K > L:
        return

    def segcost(k, lo, hi):
        # deterministic pseudo-random positive cost from the drawn pool
        idx = (k * 131 + lo * 17 + hi * 7) % len(costs)
        return costs[idx]

    segs = _dp_split(L, K, segcost)
    assert segs is not None
    validate_segments(segs, L)
    got = sum(segcost(k, lo, hi) for k, (lo, hi) in enumerate(segs))
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), K - 1):
        lo, tot = 1, 0.0
        for k, c in enumerate(list(cuts) + [L]):
            tot += segcost(k, lo, c)
            lo = c + 1
        best = min(best, tot)
    assert got <= best + 1e-9


@given(b=st.sampled_from([1, 2, 8, 32, 128]), K=st.integers(2, 6),
       mode=st.sampled_from([IF, TR]), seed=st.integers(0, 5))
@_settings
def test_solutions_satisfy_all_constraints(b, K, mode, seed):
    import random

    net = nsfnet(source="v4")
    prof = resnet101_profile()
    rng = random.Random(seed)
    mids = [f"v{i}" for i in range(1, 15) if f"v{i}" not in ("v4", "v13")]
    cands = [["v4"]] + [rng.sample(mids, 2) for _ in range(K - 2)] + [["v13"]]
    req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
    for solver in (exact_solve, bcd_solve):
        res = solver(net, prof, req, K, cands)
        if not res.feasible:
            continue
        ev = PlanEvaluator(net, prof, req)
        ev.check(res.plan)  # raises on any violated constraint
        # every inter-stage path is loop-free (paper Sec. III-D)
        for p in res.plan.paths + ([res.plan.tail_path] if res.plan.tail_path else []):
            assert len(p) == len(set(p))
        # breakdown is consistent
        lb = ev.evaluate(res.plan)
        assert lb.total_s == res.latency_s


@given(scale=st.floats(0.5, 4.0), b=st.sampled_from([1, 16, 256]))
@_settings
def test_latency_monotone_in_bandwidth(scale, b):
    """Scaling all link bandwidths up can never increase optimal latency."""
    from repro.core.topology import GBPS

    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    req = ServiceChainRequest("resnet101", "v4", "v13", b, IF)
    base = exact_solve(nsfnet(source="v4"), prof, req, 3, cands)
    fast = exact_solve(nsfnet(source="v4", bandwidth_bps=GBPS * scale), prof, req, 3,
                       cands)
    if scale >= 1.0:
        assert fast.latency_s <= base.latency_s + 1e-12
    else:
        assert fast.latency_s >= base.latency_s - 1e-12


@given(profile_scale=st.floats(1.0, 8.0))
@_settings
def test_latency_monotone_in_batch(profile_scale):
    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    net = nsfnet(source="v4")
    prev = 0.0
    for b in (1, 4, 16, 64):
        req = ServiceChainRequest("resnet101", "v4", "v13", b, TR)
        res = exact_solve(net, prof, req, 3, cands)
        assert res.feasible
        assert res.latency_s >= prev - 1e-12
        prev = res.latency_s
