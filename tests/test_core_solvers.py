"""Solver correctness: ILP == exact DP == brute force; BCD quality; DFTS optimality."""
import itertools
import random

import pytest

from repro.core import (
    IF,
    TR,
    ComputeModel,
    LayerProfile,
    LinkSpec,
    ModelProfile,
    NodeSpec,
    PhysicalNetwork,
    Plan,
    PlanEvaluator,
    ServiceChainRequest,
    bcd_solve,
    comm_ms_solve,
    comp_ms_solve,
    dfts,
    exact_solve,
    ilp_solve,
    nsfnet,
    resnet101_profile,
)

GB = 1024**3


def _random_instance(seed: int, n_nodes: int = 6, L: int = 6, K: int = 3):
    rng = random.Random(seed)
    net = PhysicalNetwork()
    names = [f"n{i}" for i in range(n_nodes)]
    for i, name in enumerate(names):
        cm = ComputeModel(name=f"dev{i}",
                          pieces=((float("inf"), rng.uniform(1e-12, 2e-10), 1e-12),),
                          alpha_tau=rng.choice([0.0, 2e-13]), beta_tau=0.0)
        cap = rng.uniform(0.4, 4.0) * GB
        net.add_node(NodeSpec(name, cm, cap, cap))
    # ring + random chords
    edges = {(i, (i + 1) % n_nodes) for i in range(n_nodes)}
    for i in range(n_nodes):
        for j in range(i + 1, n_nodes):
            if rng.random() < 0.4:
                edges.add((i, j))
    for i, j in edges:
        d = rng.uniform(1e-3, 15e-3)
        bw = rng.choice([0.5e9, 1e9, 2e9])
        net.add_bidirectional(names[i], names[j], LinkSpec(bw, bw, d, d))
    layers = []
    for l in range(L):
        fw = rng.uniform(0.1, 8.0) * 1e9
        act = rng.uniform(0.01, 3.0) * 1e6
        mem = rng.uniform(1, 300) * 1e6
        layers.append(LayerProfile(f"l{l}", fw, 2 * fw, act, act, mem, mem))
    prof = ModelProfile("rand", layers)
    s, d = names[0], names[-1]
    mids = names[1:-1]
    cands = [[s]] + [rng.sample(mids, k=min(2, len(mids))) for _ in range(K - 2)] + [[d]]
    mode = rng.choice([IF, TR])
    b = rng.choice([1, 4, 32, 128])
    req = ServiceChainRequest("rand", s, d, b, mode)
    return net, prof, req, K, cands


def _brute_force(net, prof, req, K, cands):
    """Enumerate every (segmentation, placement); optimal shortest path per cut."""
    ev = PlanEvaluator(net, prof, req)
    L = prof.L
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), K - 1):
        segs, lo = [], 1
        for c in list(cuts) + [L]:
            segs.append((lo, c))
            lo = c + 1
        for placement in itertools.product(*cands):
            total = 0.0
            ok = True
            for (lo_, hi_), node in zip(segs, placement):
                if not ev.segment_fits(node, lo_, hi_):
                    ok = False
                    break
                total += ev.segment_comp_s(node, lo_, hi_)
            if not ok:
                continue
            try:
                b = req.batch_size
                for k in range(K - 1):
                    cut = segs[k][1]
                    fw = b * prof.cut_bytes(cut, "FW")
                    bw = b * prof.cut_bytes(cut, "BW") if req.mode == TR else None
                    c, _ = net.shortest_path(placement[k], placement[k + 1], fw, bw)
                    total += c
                tail_bw = 0.0 if req.mode == TR else None
                c, _ = net.shortest_path(placement[-1], req.destination, 0.0, tail_bw)
                total += c
            except ValueError:
                continue
            best = min(best, total)
    return best


@pytest.mark.parametrize("seed", range(8))
def test_exact_equals_bruteforce(seed):
    net, prof, req, K, cands = _random_instance(seed)
    res = exact_solve(net, prof, req, K, cands)
    bf = _brute_force(net, prof, req, K, cands)
    if bf == float("inf"):
        assert not res.feasible
    else:
        assert res.feasible
        assert res.latency_s == pytest.approx(bf, rel=1e-9)


@pytest.mark.parametrize("seed", range(6))
def test_ilp_equals_exact(seed):
    net, prof, req, K, cands = _random_instance(seed)
    res_dp = exact_solve(net, prof, req, K, cands)
    res_ilp = ilp_solve(net, prof, req, K, cands, time_limit_s=120)
    assert res_dp.feasible == res_ilp.feasible
    if res_dp.feasible:
        assert res_ilp.latency_s == pytest.approx(res_dp.latency_s, rel=1e-6)


@pytest.mark.parametrize("seed", range(10))
def test_bcd_feasible_and_close(seed):
    net, prof, req, K, cands = _random_instance(seed, n_nodes=8, L=10, K=4)
    opt = exact_solve(net, prof, req, K, cands)
    heur = bcd_solve(net, prof, req, K, cands)
    if not opt.feasible:
        return
    assert heur.feasible
    ev = PlanEvaluator(net, prof, req)
    ev.check(heur.plan)  # constraints hold
    assert heur.latency_s >= opt.latency_s - 1e-12  # exact is a true lower bound
    assert heur.latency_s <= 1.5 * opt.latency_s  # near-optimal in practice
    # BCD objective history is monotonically non-increasing (each half-step is
    # an exact block minimization)
    for a, b in zip(heur.history, heur.history[1:]):
        assert b <= a + 1e-12


def test_bcd_matches_ilp_on_paper_instance():
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    for mode, b, K in [(IF, 2, 3), (TR, 128, 3), (IF, 64, 4)]:
        cands = [["v4"]] + [["v7", "v11"]] * (K - 2) + [["v13"]]
        req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
        opt = exact_solve(net, prof, req, K, cands)
        heur = bcd_solve(net, prof, req, K, cands)
        assert heur.latency_s == pytest.approx(opt.latency_s, rel=0.02)


def test_comparison_schemes_never_beat_optimal():
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    for mode, b in [(IF, 2), (TR, 128)]:
        for K in (2, 3, 5):
            cands = ([["v4"]] + [["v7", "v11"], ["v9", "v2"], ["v5", "v12"]][: K - 2]
                     + [["v13"]])
            req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
            opt = exact_solve(net, prof, req, K, cands)
            for solver in (comp_ms_solve, comm_ms_solve):
                r = solver(net, prof, req, K, cands)
                if r.feasible:
                    assert r.latency_s >= opt.latency_s - 1e-12


def test_dfts_optimal_given_segments():
    net, prof, req, K, cands = _random_instance(3, n_nodes=7, L=8, K=3)
    from repro.core import even_split

    segs = even_split(prof.L, K)
    plan = dfts(net, prof, req, segs, cands)
    ev = PlanEvaluator(net, prof, req)
    # brute-force placements with per-cut shortest paths
    best = float("inf")
    for placement in itertools.product(*cands):
        total, ok = 0.0, True
        for (lo, hi), node in zip(segs, placement):
            if not ev.segment_fits(node, lo, hi):
                ok = False
                break
            total += ev.segment_comp_s(node, lo, hi)
        if not ok:
            continue
        try:
            for k in range(K - 1):
                cut = segs[k][1]
                fw = req.batch_size * prof.cut_bytes(cut, "FW")
                bw = req.batch_size * prof.cut_bytes(cut, "BW") if req.mode == TR else None
                c, _ = net.shortest_path(placement[k], placement[k + 1], fw, bw)
                total += c
            tail_bw = 0.0 if req.mode == TR else None
            c, _ = net.shortest_path(placement[-1], req.destination, 0.0, tail_bw)
            total += c
        except ValueError:
            continue
        best = min(best, total)
    if best == float("inf"):
        assert plan is None
    else:
        assert plan is not None
        assert ev.latency_s(plan) == pytest.approx(best, rel=1e-9)


def test_training_is_roughly_double_inference():
    """Paper Sec. VI-B: MSI latency ~ half of MSL (BW FLOPs = 2x FW; same sizes)."""
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    for b in (8, 64):
        inf_r = exact_solve(net, prof,
                            ServiceChainRequest("r", "v4", "v13", b, IF), 3, cands)
        tr_r = exact_solve(net, prof,
                           ServiceChainRequest("r", "v4", "v13", b, TR), 3, cands)
        assert tr_r.latency_s > 1.5 * inf_r.latency_s
        assert tr_r.latency_s < 3.5 * inf_r.latency_s
