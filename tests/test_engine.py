"""SolverEngine: ProblemInstance identity, registry capabilities, deprecation
shims, the portfolio meta-solver, engine/legacy parity on the paper suites,
and third-party solver registration end-to-end through repro.sweep."""
import warnings

import pytest

from repro.core import (
    IF,
    PIPE,
    SEQ,
    TR,
    EvalCache,
    ModelProfile,
    ProblemInstance,
    ServiceChainRequest,
    SolveOutcome,
    SolveResult,
    candidate_sets,
    ensure_solver_supported,
    get_solver,
    nsfnet,
    register_solver,
    resnet101_profile,
    solve,
    solver_names,
    solver_supports,
    unregister_solver,
)
from repro.core.engine import _WARNED_ALIASES, deprecated_solver_alias
from repro.serve.requests import generate_fleet
from repro.sweep import ScenarioSpec, run_scenario
from repro.sweep.runner import clear_context
from repro.sweep.suites import nsfnet_paper, nsfnet_pipeline

NET = nsfnet(source="v4")
PROF = resnet101_profile()
# a 6-layer slice of Table I: keeps the MILP solves in this file fast
SMALL_PROF = ModelProfile("resnet6", resnet101_profile().layers[:6])
CANDS = (("v4",), ("v7", "v11"), ("v13",))


def _problem(b=2, mode=IF, schedule=SEQ, M=1, K=3, cands=CANDS,
             profile=PROF):
    req = ServiceChainRequest(profile.model_id, "v4", "v13", b, mode,
                              schedule=schedule, n_microbatches=M)
    return ProblemInstance(NET, profile, req, K, cands)


# ------------------------------------------------------- ProblemInstance
def test_problem_instance_content_hash_is_structural():
    a = _problem()
    b = ProblemInstance(nsfnet(source="v4"), resnet101_profile(),
                        a.request, 3, [["v4"], ["v7", "v11"], ["v13"]])
    assert a == b and hash(a) == hash(b)
    assert a.content_hash() == b.content_hash()
    assert a.content_hash() != _problem(b=4).content_hash()
    assert a.content_hash() != _problem(cands=(("v4",), ("v7",), ("v13",))
                                        ).content_hash()


def test_problem_instance_hash_sees_network_and_profile_content():
    net2 = nsfnet(source="v4")
    spec = net2.links[("v4", "v5")]
    net2.add_link("v4", "v5", type(spec)(spec.bw_fw * 2, spec.bw_bw,
                                         spec.delay_fw, spec.delay_bw))
    p2 = ProblemInstance(net2, PROF, _problem().request, 3, CANDS)
    assert p2.content_hash() != _problem().content_hash()


def test_pipe_with_depth_one_normalizes_to_seq_identity():
    # pipe with effective M = 1 is bit-for-bit the sequential objective, so
    # the two descriptions must be the same problem identity.
    assert (_problem(schedule=PIPE, M=1).content_hash()
            == _problem(schedule=SEQ).content_hash())
    assert (_problem(b=8, schedule=PIPE, M=4).content_hash()
            != _problem(b=8).content_hash())


def test_problem_instance_validates_candidate_count():
    with pytest.raises(ValueError):
        _problem(K=4)


def test_serve_solve_key_and_sweep_instance_key_agree():
    spec = ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                        profile="resnet101", source="v4", destination="v13",
                        batch_size=2, mode=IF, K=3, solver="bcd",
                        candidates=[list(c) for c in CANDS])
    fleet = generate_fleet(spec.build_network(), 1, "v4", "v13", 2, IF, 3,
                           candidates=[list(c) for c in CANDS],
                           batch_spread=(1,), model_id="resnet101")
    net, profile = spec.build_network(), spec.build_profile()
    assert fleet[0].solve_key(net, profile) == spec.instance_key()
    # the identity is the ProblemInstance content hash in both layers
    assert spec.instance_key() == spec.problem().content_hash()


def test_fleet_spec_has_no_single_problem():
    spec = ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                        profile="resnet101", source="v4", destination="v13",
                        batch_size=2, mode=IF, K=3, solver="bcd", n_requests=4)
    with pytest.raises(ValueError):
        spec.problem()


# ------------------------------------------------------------- capabilities
def test_unknown_solver_error_lists_registered_names():
    with pytest.raises(ValueError) as ei:
        get_solver("magic")
    assert "magic" in str(ei.value) and "bcd" in str(ei.value)


def test_ilp_pipe_rejection_is_uniform_and_actionable():
    msgs = []
    with pytest.raises(ValueError) as e1:
        ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                     profile="resnet101", source="v4", destination="v13",
                     batch_size=8, mode=IF, K=3, solver="ilp",
                     schedule="pipe", n_microbatches=4)
    msgs.append(str(e1.value))
    with pytest.raises(ValueError) as e2:
        solve(_problem(b=8, schedule=PIPE, M=4), "ilp")
    msgs.append(str(e2.value))
    from repro.core.ilp import ilp_solve as raw_ilp
    with pytest.raises(ValueError) as e3:
        raw_ilp(NET, PROF, _problem(b=8, schedule=PIPE, M=4).request, 3,
                [list(c) for c in CANDS])
    msgs.append(str(e3.value))
    for m in msgs:
        assert "'ilp'" in m and "seq" in m  # names the solver and its limits
        assert "bcd" in m  # and points at solvers that do support pipe
    assert len(set(msgs)) == 1  # one check, one message, every layer


def test_ilp_pipe_depth_one_is_allowed():
    ok, _ = solver_supports("ilp", schedule=PIPE, batch_size=1,
                            n_microbatches=8)
    assert ok  # clamps to M=1 == sequential
    assert ensure_solver_supported("ilp", _problem(schedule=SEQ)).name == "ilp"


def test_solver_supports_with_problem_instance():
    ok, reason = solver_supports("ilp", _problem(b=8, schedule=PIPE, M=4))
    assert not ok and "ilp" in reason
    assert solver_supports("exact", _problem(b=8, schedule=PIPE, M=4))[0]


# ------------------------------------------------------------ legacy shims
def test_deprecation_shims_warn_once_and_match_engine_bit_for_bit():
    problem = _problem(b=2, mode=TR)
    shim = deprecated_solver_alias("bcd", "bcd_solve_test_alias")
    _WARNED_ALIASES.discard("bcd_solve_test_alias")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r1 = shim(*problem.solver_args())
        r2 = shim(*problem.solver_args())
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1  # exactly once per process, not per call
    out = solve(problem, "bcd")
    for r in (r1, r2):
        assert r.plan == out.plan
        assert r.latency_s == out.objective


def test_all_five_legacy_shims_dispatch_to_registry():
    import repro.core as core

    problem = _problem(profile=SMALL_PROF)  # small L keeps the MILP fast
    for alias, name in [("bcd_solve", "bcd"), ("exact_solve", "exact"),
                        ("ilp_solve", "ilp"), ("comp_ms_solve", "comp-ms"),
                        ("comm_ms_solve", "comm-ms")]:
        shim = getattr(core, alias)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            res = shim(*problem.solver_args())
        assert res.plan == solve(problem, name).plan


# --------------------------------------------------------------- portfolio
def test_portfolio_never_worse_than_members_on_nsfnet_grid():
    members = ("bcd", "comp-ms", "comm-ms")
    cache = EvalCache()
    for mode, b in ((IF, 2), (IF, 128), (TR, 2), (TR, 128)):
        for seed in range(3):
            cands = tuple(tuple(c) for c in
                          candidate_sets(3, seed, sorted(NET.nodes),
                                         "v4", "v13"))
            problem = _problem(b=b, mode=mode, cands=cands)
            pf = solve(problem, "portfolio", cache=cache,
                       members=members)
            assert pf.feasible
            per_member = [solve(problem, m, cache=cache) for m in members]
            for m, res in zip(members, per_member):
                if res.feasible:
                    assert pf.objective <= res.objective + 1e-12, m
            assert pf.objective == min(r.objective for r in per_member
                                       if r.feasible)
            assert pf.stats["winner"] in members
            assert set(pf.stats["members"]) == set(members)


def test_portfolio_inherits_optimality_from_optimal_member():
    out = solve(_problem(), "portfolio", members=("exact", "bcd"))
    assert out.status == "optimal"
    assert solve(_problem(), "portfolio").status == "feasible"


def test_portfolio_skips_unsupported_members():
    out = solve(_problem(b=8, schedule=PIPE, M=4), "portfolio",
                members=("ilp", "bcd"))
    assert out.feasible
    assert out.stats["members"]["ilp"]["status"] == "unsupported"
    assert out.stats["winner"] == "bcd"


def test_portfolio_rejects_meta_members_and_empty_sets():
    with pytest.raises(ValueError):
        solve(_problem(), "portfolio", members=("portfolio",))
    with pytest.raises(ValueError):
        solve(_problem(), "portfolio", members=())


def test_portfolio_runs_through_sweep():
    spec = ScenarioSpec(topology="nsfnet", topology_kwargs={"source": "v4"},
                        profile="resnet101", source="v4", destination="v13",
                        batch_size=2, mode=IF, K=3, solver="portfolio",
                        candidates=[list(c) for c in CANDS])
    res = run_scenario(spec, use_context_cache=False)
    assert res.feasible and res.status == "feasible"
    assert res.solver_stats["winner"] in res.solver_stats["members"]
    bcd = run_scenario(ScenarioSpec.from_dict(
        {**spec.to_dict(), "solver": "bcd"}), use_context_cache=False)
    assert res.latency_s <= bcd.latency_s + 1e-12


# ------------------------------------------- third-party solver registration
def test_third_party_solver_end_to_end_through_sweep():
    @register_solver("toy-first-fit", schedules=(SEQ,),
                     description="test-only: bcd plan passthrough")
    def toy_solve(net, profile, request, K, candidates, cache=None):
        from repro.core.bcd import bcd_solve as raw_bcd

        res = raw_bcd(net, profile, request, K, candidates, cache=cache)
        return SolveResult(res.plan, res.latency, res.wall_time_s,
                           solver="toy-first-fit")

    try:
        assert "toy-first-fit" in solver_names()
        out = solve(_problem(), "toy-first-fit")
        assert out.feasible and out.status == "feasible"
        # sweepable with zero further wiring: spec validation, dispatch,
        # and result recording all come from the registry
        spec = ScenarioSpec(topology="nsfnet",
                            topology_kwargs={"source": "v4"},
                            profile="resnet101", source="v4",
                            destination="v13", batch_size=2, mode=IF, K=3,
                            solver="toy-first-fit",
                            candidates=[list(c) for c in CANDS])
        res = run_scenario(spec, use_context_cache=False)
        assert res.feasible and res.status == "feasible"
        # capability checks apply to third-party solvers too (seq only)
        with pytest.raises(ValueError):
            ScenarioSpec.from_dict({**spec.to_dict(), "schedule": "pipe",
                                    "batch_size": 8, "n_microbatches": 4})
    finally:
        unregister_solver("toy-first-fit")
    with pytest.raises(ValueError):
        get_solver("toy-first-fit")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_solver("bcd")(lambda *a, **k: None)


# -------------------------------------------------- engine vs legacy parity
def _dedupe_single_chain(specs):
    seen, out = set(), []
    for s in specs:
        if s.n_requests == 1 and s.spec_hash() not in seen:
            seen.add(s.spec_hash())
            out.append(s)
    return out


@pytest.mark.parametrize("suite,specs", [
    ("nsfnet_paper", _dedupe_single_chain(
        nsfnet_paper(quick=True, seeds=1))),
    ("nsfnet_pipeline", _dedupe_single_chain(nsfnet_pipeline(quick=True))),
])
def test_engine_and_legacy_paths_identical_on_suites(suite, specs):
    """Acceptance: for every (instance, solver) pair of the paper suites the
    engine entry point and the legacy ``*_solve`` signature produce identical
    plans and objectives."""
    assert specs
    clear_context()
    cache = EvalCache()
    # all specs of these suites share one (topology, profile) cell: reuse the
    # built objects so the frontier caches are shared like a real sweep run
    net, profile = specs[0].build_network(), specs[0].build_profile()
    for spec in specs:
        problem = spec.problem(net, profile)
        out = solve(problem, spec.solver, cache=cache, **spec.solver_kwargs)
        raw = get_solver(spec.solver).fn(  # the legacy call signature
            *problem.solver_args(), cache=cache, **spec.solver_kwargs)
        assert out.feasible == raw.feasible, spec.scenario_id()
        if out.feasible:
            assert out.plan == raw.plan, spec.scenario_id()
            assert out.objective == raw.latency_s, spec.scenario_id()


def test_portfolio_dominates_members_on_suite_instances():
    """Acceptance: on every quick-tier instance of nsfnet_paper and
    nsfnet_pipeline, the portfolio's objective is <= every member's."""
    instances, seen = [], set()
    for spec in (nsfnet_paper(quick=True) + nsfnet_pipeline(quick=True)):
        key = spec.group_key()
        if spec.n_requests == 1 and key not in seen:
            seen.add(key)
            instances.append(spec)
    cache = EvalCache()
    net, profile = instances[0].build_network(), instances[0].build_profile()
    members = ("bcd", "comp-ms", "comm-ms")
    for spec in instances:
        problem = spec.problem(net, profile)
        pf = solve(problem, "portfolio", cache=cache, members=members)
        feas = {}
        for m in members:
            res = solve(problem, m, cache=cache)
            if res.feasible:
                feas[m] = res.objective
                assert pf.objective <= res.objective + 1e-12, (
                    spec.scenario_id(), m)
        assert pf.feasible == bool(feas)
        if feas:
            assert pf.objective == min(feas.values())
            assert pf.stats["winner"] == min(feas, key=feas.get)


def test_outcome_status_vocabulary():
    out = solve(_problem(), "exact")
    assert out.status == "optimal" and out.objective == out.latency_s
    out = solve(_problem(), "bcd")
    assert out.status == "feasible"
    # starved instance: the batch's smashed-data memory exceeds every node
    starved = _problem(b=10**9, mode=TR)
    assert solve(starved, "bcd").status == "infeasible"
    assert isinstance(solve(starved, "bcd"), SolveOutcome)
