"""Deterministic failure-injection regression tier (docs/failures.md).

Pinned seeded scenarios on nsfnet/resnet101 — a single link down, the
source (articulation) node down, a same-instant failure burst, and a
fail-then-recover outage — with bit-for-bit expected survivor sets,
kill sets, and restoration latencies.  A behaviour drift in victim
detection, migration, the retry/park queues, or the cost model moves one
of these pins and fails loudly here.

Also anchors the zero-failure contract: with ``failures=None`` the
simulator returns a plain :class:`SimOutcome` bit-for-bit identical to a
run that never heard of failures, and ``failures=[]`` only *adds* the
failure keys to the summary without perturbing any shared one.
"""
from __future__ import annotations

import pytest

from repro.core import IF, nsfnet, resnet101_profile
from repro.serve import (FailureEvent, FailureOutcome, ServeSim, SimOutcome,
                         generate_failures, generate_fleet,
                         replay_verify_sim)

NET = nsfnet()
PROF = resnet101_profile()


def _fleet(n=12, seed=1):
    return generate_fleet(NET, n, "v4", "v13", 2, IF, 3, seed=seed,
                          arrival="poisson", hold_model="exp",
                          hold_time_s=8.0)


def _run(failures):
    out = ServeSim(NET, PROF, retry=True).run(_fleet(), failures=failures)
    assert replay_verify_sim(NET, PROF, out.served, failures=out.failures)
    return out


def _pins(out):
    acc = [s for s in out.served if s.accepted]
    return {
        "accepted": sorted(s.request.request_id for s in acc),
        "survivors": sorted(s.request.request_id for s in acc
                            if s.failed_s is None),
        "killed": sorted(s.request.request_id for s in acc
                         if s.failed_s is not None),
        "restored": sorted(s.request.request_id for s in acc
                           if s.migrations),
        "restore_latency_s": {
            s.request.request_id: round(sum(m["disruption_s"]
                                            for m in s.migrations), 6)
            for s in acc if s.migrations},
    }


# ---------------------------------------------------------- pinned scenarios
def test_single_link_down():
    """One busy link fails: both hosted chains migrate at the failure
    instant; one replans to a disjoint path in place (zero restage), the
    other relocates a stage and pays the parameter reload."""
    out = _run([FailureEvent(t_s=4.0, kind="link_down", link=("v11", "v14"))])
    assert _pins(out) == {
        "accepted": list(range(12)),
        "survivors": list(range(12)),
        "killed": [],
        "restored": [0, 2],
        "restore_latency_s": {0: 0.0, 2: 21.466636},
    }
    assert (out.n_failed, out.n_restored, out.n_killed) == (2, 2, 0)
    assert out.restored_fraction == 1.0
    assert round(out.moved_bytes) == 2683329512


def test_articulation_node_down():
    """The source node fails: every chain terminates or originates there,
    so no replan exists — all live chains are killed and every later
    arrival is rejected against the degraded substrate."""
    out = _run([FailureEvent(t_s=4.0, kind="node_down", node="v4")])
    assert _pins(out) == {
        "accepted": [0, 1, 2],
        "survivors": [],
        "killed": [0, 1, 2],
        "restored": [],
        "restore_latency_s": {},
    }
    assert (out.n_failed, out.n_restored, out.n_killed) == (3, 0, 3)
    assert out.restored_fraction == 0.0


def test_failure_burst_same_instant():
    """Two links and a node fail in the same instant: the victims are
    detected once against the union outage and every chain relocates,
    paying the full restage cost."""
    out = _run([
        FailureEvent(t_s=4.0, kind="link_down", link=("v11", "v14")),
        FailureEvent(t_s=4.0, kind="link_down", link=("v13", "v14")),
        FailureEvent(t_s=4.0, kind="node_down", node="v9"),
    ])
    assert _pins(out) == {
        "accepted": list(range(12)),
        "survivors": list(range(12)),
        "killed": [],
        "restored": [0, 1, 2],
        "restore_latency_s": {0: 21.273964, 1: 21.338188, 2: 21.466636},
    }
    assert (out.n_failed, out.n_restored, out.n_killed) == (3, 3, 0)
    assert round(out.moved_bytes) == 8009848536


def test_fail_then_recover():
    """The source goes down for a 3 s outage and comes back: parked victims
    are restored at the recovery instant with exactly the outage as their
    disruption (same plan, nothing moved); one victim's residual hold
    expires during the outage and is killed, not restored."""
    out = _run([FailureEvent(t_s=4.0, kind="node_down", node="v4"),
                FailureEvent(t_s=7.0, kind="recover", node="v4")])
    assert _pins(out) == {
        "accepted": list(range(12)),
        "survivors": [0] + list(range(2, 12)),
        "killed": [1],
        "restored": [0, 2],
        "restore_latency_s": {0: 3.0, 2: 3.0},
    }
    assert (out.n_failed, out.n_restored, out.n_killed) == (3, 2, 1)
    assert out.moved_bytes == 0.0  # restored on their original plans
    assert out.restore_latencies() == [3.0, 3.0]


# --------------------------------------------------------- zero-failure parity
def test_no_failures_is_bitwise_identical():
    """failures=None must be byte-for-byte the failure-free simulator —
    same outcome type, same summary, same per-record serialization."""
    plain = ServeSim(NET, PROF, retry=True).run(_fleet())
    with_none = ServeSim(NET, PROF, retry=True).run(_fleet(), failures=None)
    assert type(plain) is SimOutcome and type(with_none) is SimOutcome
    assert not isinstance(with_none, FailureOutcome)
    a, b = plain.sim_summary(), with_none.sim_summary()
    for d in (a, b):
        d.pop("wall_time_s", None)
    assert a == b
    assert [s.to_dict() for s in plain.served] == \
           [s.to_dict() for s in with_none.served]


def test_empty_failure_schedule_only_adds_keys():
    plain = ServeSim(NET, PROF, retry=True).run(_fleet())
    empty = ServeSim(NET, PROF, retry=True).run(_fleet(), failures=[])
    assert isinstance(empty, FailureOutcome)
    a, b = plain.sim_summary(), empty.sim_summary()
    for d in (a, b):
        d.pop("wall_time_s", None)
    extra = set(b) - set(a)
    assert extra == {"failures", "failure_events"}
    assert {k: b[k] for k in a} == a
    assert b["failure_events"] == []
    assert empty.n_failed == 0 and empty.n_killed == 0


# ------------------------------------------------------- schedule generation
def test_generate_failures_is_deterministic_and_protects():
    evs1 = generate_failures(NET, rate_per_s=0.3, horizon_s=20.0, seed=7,
                             protect=("v4", "v13"))
    evs2 = generate_failures(NET, rate_per_s=0.3, horizon_s=20.0, seed=7,
                             protect=("v4", "v13"))
    assert [e.to_dict() for e in evs1] == [e.to_dict() for e in evs2]
    assert evs1, "rate 0.3 over 20 s should draw events"
    for ev in evs1:
        assert ev.kind in ("link_down", "node_down", "recover")
        if ev.node is not None:
            assert ev.node not in ("v4", "v13")
    assert generate_failures(NET, rate_per_s=0.0, horizon_s=20.0) == []
    # a different seed draws a different schedule
    evs3 = generate_failures(NET, rate_per_s=0.3, horizon_s=20.0, seed=8,
                             protect=("v4", "v13"))
    assert [e.to_dict() for e in evs3] != [e.to_dict() for e in evs1]


def test_failure_event_validation():
    with pytest.raises(ValueError):
        FailureEvent(t_s=0.0, kind="meteor", node="v1")
    with pytest.raises(ValueError):
        FailureEvent(t_s=0.0, kind="link_down")  # no resource named
    ev = FailureEvent(t_s=1.5, kind="node_down", node="v2")
    assert FailureEvent.from_dict(ev.to_dict()) == ev
