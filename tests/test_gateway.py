"""ServeGateway: the long-running admission control plane (docs/gateway.md).

Anchoring invariants: a gateway fed an entire fleet in one tick with an
unbounded queue and no SLO reproduces the static admission round bit-for-bit;
gateway traces replay-verify through the simulator's event verifier; the
control-plane gates (backpressure, SLO) reject without ever touching the
fabric; and the warm PlanCache dedupes identical shapes across ticks.
"""
import dataclasses

import pytest

from repro.core import IF, nsfnet, resnet101_profile
from repro.serve import (GatewayConfig, PlanCache, ServeGateway, ServePlanner,
                         ServeSim, ServedRequest, generate_fleet,
                         replay_verify_sim)
from repro.sweep import (SUITES, ScenarioSpec, churn_pairs, run_scenario,
                         verify_result)

NET = nsfnet()
PROF = resnet101_profile()


def _fleet(n=12, mode=IF, b=2, seed=0, **kw):
    return generate_fleet(NET, n, "v4", "v13", b, mode, 3, seed=seed, **kw)


def _static_fields(s: ServedRequest):
    """The static-round fields of a served record (the gateway adds
    admit/depart timestamps on top, like the simulator)."""
    return (s.request, s.accepted, s.replanned, s.latency_s, s.plan, s.reason,
            s.status)


# ------------------------------------------------------------- config knobs
def test_gateway_config_validation():
    GatewayConfig()  # all defaults valid
    GatewayConfig(batch_window_s=0.5, max_queue=4, slo_latency_s=1.0,
                  retry=True)
    with pytest.raises(ValueError):
        GatewayConfig(batch_window_s=-0.1)
    with pytest.raises(ValueError):
        GatewayConfig(max_queue=0)
    with pytest.raises(ValueError):
        GatewayConfig(slo_latency_s=0.0)
    with pytest.raises(ValueError):
        ServeGateway(NET, PROF, policy="magic")


# ------------------------------------------------------------ anchor parity
@pytest.mark.parametrize("policy", ["fcfs", "latency-greedy", "batch-desc"])
def test_single_tick_gateway_matches_static_round(policy):
    """The tentpole anchor: entire fleet in one tick, unbounded queue, no
    SLO, cold cache -> bit-for-bit the static ServePlanner.admit round."""
    fleet = _fleet(16)
    static = ServePlanner(NET, PROF).admit(fleet, policy=policy)
    gw = ServeGateway(NET, PROF, policy=policy)
    assert gw.submit(fleet) == len(fleet)
    gw.tick()
    out = gw.drain()
    assert [_static_fields(s) for s in out.served] == \
           [_static_fields(s) for s in static.served]
    assert out.status == static.status
    assert out.gateway_stats["n_ticks"] == 1
    assert out.n_slo_rejected == 0 and out.n_queue_rejected == 0
    assert replay_verify_sim(NET, PROF, out.served)


def test_run_stream_with_infinite_holds_matches_static():
    """Streamed one arrival per tick (window 0), infinite holds: same
    decisions as the static round — the planner sees identical residuals."""
    fleet = _fleet(12, arrival="poisson", seed=3)
    static = ServePlanner(NET, PROF).admit(fleet, policy="fcfs")
    out = ServeGateway(NET, PROF).run_stream(fleet)
    assert [_static_fields(s) for s in out.served] == \
           [_static_fields(s) for s in static.served]
    for s in out.served:
        if s.accepted:
            assert s.admit_s == s.request.arrival_s


# ----------------------------------------------------------- control plane
def test_bounded_queue_backpressure_rejects_at_submit():
    fleet = _fleet(6)
    gw = ServeGateway(NET, PROF, config=GatewayConfig(max_queue=2))
    assert gw.submit(fleet) == 2  # the rest bounce off the full queue
    out_rows = [s for s in gw.core.served if s.reason == "queue-full"]
    assert len(out_rows) == 4
    gw.tick()
    out = gw.drain()
    assert out.n_queue_rejected == 4
    assert out.gateway_stats["n_queue_rejected"] == 4
    assert len(out.served) == len(fleet)  # every submission is accounted
    # backpressure rejections never touched the fabric or the planner
    assert all(s.plan is None and s.latency_s is None for s in out_rows)
    assert replay_verify_sim(NET, PROF, out.served)


def test_slo_gate_rejects_before_commit():
    fleet = _fleet(8)
    gw = ServeGateway(NET, PROF,
                      config=GatewayConfig(slo_latency_s=1e-9))  # impossible
    gw.submit(fleet)
    gw.tick()
    out = gw.drain()
    assert out.n_accepted == 0
    assert out.n_slo_rejected == len(fleet)
    assert all(s.reason == "slo" for s in out.served)
    # nothing was committed: the fabric is untouched
    assert gw.core.concurrent == 0
    assert replay_verify_sim(NET, PROF, out.served)
    # a loose SLO admits exactly what the unconstrained gateway admits
    loose = ServeGateway(NET, PROF, config=GatewayConfig(slo_latency_s=1e9))
    loose.submit(fleet)
    loose.tick()
    assert loose.drain().n_accepted == \
        ServePlanner(NET, PROF).admit(fleet).n_accepted


def test_slo_respects_contended_latency():
    """The SLO gate tests the *contended* latency (against live residuals),
    so a threshold between the best and worst admitted latency splits the
    fleet rather than rejecting everything."""
    fleet = _fleet(16)
    base = ServePlanner(NET, PROF).admit(fleet)
    lats = sorted(s.latency_s for s in base.served if s.accepted)
    assert len(lats) >= 2 and lats[0] < lats[-1]
    cut = (lats[0] + lats[-1]) / 2
    gw = ServeGateway(NET, PROF, config=GatewayConfig(slo_latency_s=cut))
    gw.submit(fleet)
    gw.tick()
    out = gw.drain()
    assert 0 < out.n_accepted
    assert out.n_slo_rejected > 0
    assert all(s.latency_s <= cut for s in out.served if s.accepted)


# --------------------------------------------------------------- plan cache
def test_plan_cache_dedupes_across_ticks():
    fleet = _fleet(4)
    gw = ServeGateway(NET, PROF)
    gw.submit(fleet)
    row1 = gw.tick()
    assert row1["plan_cache_hits"] == 0  # cold cache: every shape is new
    # same shapes, new identities, arriving later: all warm-cache hits
    clones = [dataclasses.replace(r, request_id=100 + r.request_id,
                                  arrival_s=1.0) for r in fleet]
    gw.submit(clones)
    row2 = gw.tick()
    assert row2["plan_cache_misses"] == 0
    assert row2["plan_cache_hits"] == len(clones)
    out = gw.drain()
    pc = out.gateway_stats["plan_cache"]
    assert pc["hits"] == len(clones)
    assert pc["hit_rate"] == pytest.approx(0.5)
    # warm hits are the exact cached outcomes: the snapshot solve for a
    # clone is the same object the cold round stored for its shape
    for r, c in zip(fleet, clones):
        assert gw.core.snapshot_for(c) is gw.core.snapshot_for(r)
    assert replay_verify_sim(NET, PROF, out.served)


def test_plan_cache_lru_and_counters():
    cache = PlanCache(capacity=2)
    assert cache.get("a") is None
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1  # refreshes a's recency
    cache.put("c", 3)  # evicts b, the least recently used
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.evictions == 1
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["size"] == 2
    assert s["hit_rate"] == pytest.approx(0.5)
    cache.clear()
    assert len(cache) == 0
    assert cache.stats()["hits"] == 1  # counters survive a clear
    with pytest.raises(ValueError):
        PlanCache(capacity=0)


# ------------------------------------------------------------ batch windows
def test_batch_window_groups_arrivals_into_ticks():
    fleet = _fleet(12, arrival="poisson", seed=3)
    times = sorted({r.arrival_s for r in fleet})
    per_arrival = ServeGateway(NET, PROF).run_stream(fleet)
    assert per_arrival.gateway_stats["n_ticks"] == len(times)
    one_shot = ServeGateway(
        NET, PROF, config=GatewayConfig(batch_window_s=1e9)).run_stream(fleet)
    assert one_shot.gateway_stats["n_ticks"] == 1
    windowed = ServeGateway(
        NET, PROF, config=GatewayConfig(batch_window_s=0.5)).run_stream(fleet)
    assert 1 <= windowed.gateway_stats["n_ticks"] <= len(times)
    for out in (per_arrival, one_shot, windowed):
        assert len(out.served) == len(fleet)
        assert replay_verify_sim(NET, PROF, out.served)


def test_gateway_stats_rows_are_consistent():
    fleet = _fleet(12, arrival="poisson", seed=3)
    gw = ServeGateway(NET, PROF, config=GatewayConfig(batch_window_s=0.5))
    out = gw.run_stream(fleet)
    gs = out.gateway_stats
    rows = gw.stats.ticks
    assert gs["n_ticks"] == len(rows)
    assert gs["n_submitted"] == len(fleet)
    assert sum(r["n_arrivals"] for r in rows) == len(fleet)
    assert sum(r["n_admitted"] for r in rows) == out.n_accepted
    assert all(r["wall_s"] > 0 for r in rows)
    pct = gs["tick_wall_pct"]
    assert pct["p50"] <= pct["p95"] <= pct["p99"]
    assert gs["admissions_per_s"] > 0
    assert gs["tick_wall_total_s"] == pytest.approx(
        sum(r["wall_s"] for r in rows))


# ------------------------------------------------------------ churn + retry
def test_gateway_churn_with_retry_matches_sim_semantics():
    """Exp holds + retry through the per-arrival gateway (window 0): the
    sim's drain-departures-then-retry rule at tick granularity.  Departures
    *between* arrivals are released at the next tick rather than their own
    instant, so traces are not bit-equal to ServeSim under churn — but the
    trace replay-verifies, acceptance beats the static round when
    overloaded, and on this pinned stream the admitted count agrees."""
    fleet = _fleet(32, arrival="poisson", hold_model="exp", hold_time_s=4.0)
    static = ServePlanner(NET, PROF).admit(fleet)
    out = ServeGateway(NET, PROF,
                       config=GatewayConfig(retry=True)).run_stream(fleet)
    assert static.n_accepted < len(fleet)  # overloaded
    assert out.n_accepted > static.n_accepted
    assert out.n_departed > 0
    assert out.n_retried > 0
    assert replay_verify_sim(NET, PROF, out.served)
    sim = ServeSim(NET, PROF, retry=True).run(fleet)
    assert out.n_accepted == sim.n_accepted  # pinned: same stream, same count


def test_gateway_lifecycle_guards():
    gw = ServeGateway(NET, PROF)
    gw.submit(_fleet(2))
    gw.drain()
    with pytest.raises(RuntimeError):
        gw.submit(_fleet(1))
    with pytest.raises(RuntimeError):
        gw.tick()
    with pytest.raises(RuntimeError):
        gw.drain()


# ------------------------------------------------------- sweep integration
def test_gateway_scenario_spec_knobs_and_validation():
    spec = ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": "v4"},
        profile="resnet101", source="v4", destination="v13",
        batch_size=2, mode=IF, K=3, solver="bcd",
        n_requests=8, arrival="poisson", policy="fcfs",
        gateway=True, batch_window_s=0.5, hold_model="exp", duration_s=4.0,
        retry=True)
    clone = ScenarioSpec.from_dict(spec.to_dict())
    assert clone == spec and clone.spec_hash() == spec.spec_hash()
    # gateway knobs are solve-relevant (hash) but pair on churn_key
    for patch in ({"gateway": False, "batch_window_s": 0.0,
                   "hold_model": "none", "duration_s": None, "retry": False},
                  {"batch_window_s": 1.0}, {"max_queue": 4},
                  {"slo_latency_s": 1.0}):
        other = ScenarioSpec.from_dict({**spec.to_dict(), **patch})
        assert other.spec_hash() != spec.spec_hash()
        assert other.churn_key() == spec.churn_key()
    base = dict(topology="nsfnet", profile="resnet101", source="v4",
                destination="v13", batch_size=2, mode=IF, K=3, n_requests=8)
    with pytest.raises(ValueError):  # sim and gateway are exclusive
        ScenarioSpec(**base, sim=True, gateway=True)
    with pytest.raises(ValueError):  # gateway knob without the gateway
        ScenarioSpec(**base, batch_window_s=0.5)
    with pytest.raises(ValueError):
        ScenarioSpec(**base, max_queue=4)
    with pytest.raises(ValueError):
        ScenarioSpec(**base, slo_latency_s=1.0)
    with pytest.raises(ValueError):  # gateway needs a fleet
        ScenarioSpec(**{**base, "n_requests": 1}, gateway=True)
    with pytest.raises(ValueError):  # bad knob values
        ScenarioSpec(**base, gateway=True, batch_window_s=-1.0)
    with pytest.raises(ValueError):
        ScenarioSpec(**base, gateway=True, max_queue=0)
    # retry/hold_model are legal with gateway (not only sim)
    ScenarioSpec(**base, gateway=True, retry=True, hold_model="exp",
                 duration_s=4.0)


def test_gateway_scenario_runs_and_verifies():
    spec = ScenarioSpec(
        topology="nsfnet", topology_kwargs={"source": "v4"},
        profile="resnet101", source="v4", destination="v13",
        batch_size=2, mode=IF, K=3, solver="bcd",
        n_requests=12, arrival="poisson", policy="fcfs",
        gateway=True, hold_model="exp", duration_s=4.0, retry=True,
        tags={"suite": "test"})
    result = run_scenario(spec, use_context_cache=False)
    assert result.feasible
    assert result.gateway is not None and result.gateway["n_ticks"] >= 1
    assert result.eval_cache_hit_rate is not None
    assert result.plan_cache_hit_rate is not None
    assert result.blocking_probability is not None
    assert len(result.served) == 12
    assert verify_result(result)
    # corrupting the trace must fail verification
    bad = run_scenario(spec, use_context_cache=False)
    for d in bad.served:
        if d["accepted"] and d.get("depart_s") is not None:
            d["depart_s"] = d["admit_s"] - 1.0
            break
    assert not verify_result(bad)


def test_nsfnet_gateway_suite_pairs_and_uplifts():
    specs = SUITES["nsfnet_gateway"](quick=True)
    assert any(s.gateway for s in specs) and any(not s.gateway for s in specs)
    # run one cell (static + its gateway variants) to keep the test quick
    cell = [s for s in specs if s.tags["cell"] == "n16_fcfs"]
    results = [run_scenario(s) for s in cell]
    assert all(r.error is None for r in results)
    pairs = churn_pairs(results)
    assert len(pairs) == sum(1 for s in cell if s.gateway)
    assert all(p["driver"] == "gateway" for p in pairs.values())
    static = next(r for r in results if not r.spec.gateway)
    if static.acceptance_ratio < 1.0:  # overloaded cell: departures help
        assert any(p["uplift"] > 0 for p in pairs.values())
    for r in results:
        assert verify_result(r)
