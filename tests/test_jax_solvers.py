"""Parity/property lockdown for the batched JAX solver core.

``dfts_jax`` / ``bcd_jax`` are accelerated twins of the scalar NumPy solvers:
the contract is *bit parity* — identical plans and latency breakdowns on the
full quick tiers (policy fallback: latency within 1e-6 relative with provably
tied-cost plans; see docs/solvers.md).  Beyond parity, this module locks down
the batch engine semantics: padded ragged batches equal the singleton loop,
content-hash-equal instances produce bit-identical batched results, memo keys
never collide across (schedule, M) variants, and the registry degrades
gracefully when the JAX solvers are absent.
"""
from __future__ import annotations

import pytest

import repro.core.engine as engine_mod
from repro.core import (
    IF,
    PIPE,
    TR,
    EvalCache,
    ProblemInstance,
    ServiceChainRequest,
    SolveOutcome,
    bcd_solve,
    nsfnet,
    portfolio_solve,
    resnet101_profile,
    solve,
    solve_batch,
    solver_names,
)
from repro.sweep.spec import candidate_sets
from repro.sweep.suites import DEST, NSFNET_NODES, SOURCE

NET = nsfnet(source=SOURCE)
PROF = resnet101_profile()

REL_TOL = 1e-6  # documented fallback tolerance (docs/solvers.md)


def _problem(mode=IF, K=3, b=2, seed=0, schedule="seq", M=1,
             per_stage=2) -> ProblemInstance:
    cands = candidate_sets(K, seed, NSFNET_NODES, SOURCE, DEST,
                           per_stage=per_stage)
    req = ServiceChainRequest(
        model_id=PROF.model_id, source=SOURCE, destination=DEST,
        batch_size=b, mode=mode, schedule=schedule, n_microbatches=M)
    return ProblemInstance(NET, PROF, req, K, tuple(tuple(c) for c in cands))


def _assert_parity(ref: SolveOutcome, jax: SolveOutcome) -> None:
    assert ref.feasible == jax.feasible
    if not ref.feasible:
        return
    rel = abs(jax.latency_s - ref.latency_s) / max(abs(ref.latency_s), 1e-30)
    assert rel <= REL_TOL, (ref.latency_s, jax.latency_s)
    if jax.plan != ref.plan:
        # different plans are acceptable only when provably tied in cost
        assert jax.latency_s == ref.latency_s
    else:
        # same plan must mean the same breakdown, bit for bit
        assert jax.latency == ref.latency


# --------------------------------------------------- quick-tier parity grids
def _paper_cells():
    ks = [2, 3, 5]
    bs = [2, 128]
    cells = []
    for mode in (IF, TR):
        for K in ks:
            for b in bs:
                for seed in range(3):
                    cells.append((mode, K, b, seed))
    return cells


_FAST_CELLS = [c for c in _paper_cells() if c[1] == 3]
_SLOW_CELLS = [c for c in _paper_cells() if c[1] != 3]


def _check_seq_cell(mode, K, b, seed):
    p = _problem(mode=mode, K=K, b=b, seed=seed)
    _assert_parity(solve(p, "dfts_np", cache=EvalCache()),
                   solve(p, "dfts_jax", cache=EvalCache()))
    _assert_parity(solve(p, "bcd", cache=EvalCache()),
                   solve(p, "bcd_jax", cache=EvalCache()))


@pytest.mark.parametrize("mode,K,b,seed", _FAST_CELLS)
def test_parity_nsfnet_paper_quick(mode, K, b, seed):
    _check_seq_cell(mode, K, b, seed)


@pytest.mark.slow
@pytest.mark.parametrize("mode,K,b,seed", _SLOW_CELLS)
def test_parity_nsfnet_paper_quick_full(mode, K, b, seed):
    _check_seq_cell(mode, K, b, seed)


def _pipeline_cells():
    cells = []
    for K in (3,):
        for mode, b in ((IF, 32), (TR, 128)):
            for M in (1, 4, 16):
                cells.append((mode, K, b, M))
    return cells


@pytest.mark.parametrize("mode,K,b,M", _pipeline_cells())
def test_parity_nsfnet_pipeline_quick(mode, K, b, M):
    p = _problem(mode=mode, K=K, b=b, seed=0, schedule=PIPE, M=M)
    _assert_parity(solve(p, "dfts_np", cache=EvalCache()),
                   solve(p, "dfts_jax", cache=EvalCache()))
    _assert_parity(solve(p, "bcd", cache=EvalCache()),
                   solve(p, "bcd_jax", cache=EvalCache()))


@pytest.mark.slow
@pytest.mark.parametrize("mode,b", [(IF, 32), (TR, 128)])
def test_parity_nsfnet_pipeline_k5(mode, b):
    p = _problem(mode=mode, K=5, b=b, seed=0, schedule=PIPE, M=4)
    _assert_parity(solve(p, "dfts_np", cache=EvalCache()),
                   solve(p, "dfts_jax", cache=EvalCache()))


# --------------------------------------------- padded batch == singleton loop
def _ragged_batch() -> list[ProblemInstance]:
    """Mixed K / candidate-set-size / mode / schedule — maximally ragged, so
    the padding (both the S candidate axis and the pow2 batch axis) is
    exercised in one call."""
    return [
        _problem(mode=IF, K=2, b=2, seed=0),
        _problem(mode=TR, K=3, b=128, seed=1),
        _problem(mode=IF, K=5, b=8, seed=2, per_stage=4),
        _problem(mode=TR, K=3, b=32, seed=3, per_stage=6),
        _problem(mode=IF, K=3, b=32, seed=4, schedule=PIPE, M=4),
        _problem(mode=IF, K=2, b=2, seed=5),
        _problem(mode=TR, K=5, b=128, seed=6, per_stage=4),
    ]


@pytest.mark.parametrize("solver", ["dfts_jax", "bcd_jax"])
def test_ragged_batch_equals_singleton_loop(solver):
    problems = _ragged_batch()
    batched = solve_batch(problems, solver, dedup=False)
    singles = [solve(p, solver) for p in problems]
    assert len(batched) == len(problems)
    for got, want in zip(batched, singles):
        assert got.feasible == want.feasible
        assert got.plan == want.plan
        assert got.latency == want.latency  # bit-identical breakdowns
        assert got.status == want.status


def test_batch_dedup_shares_outcomes():
    a, b = _problem(seed=0), _problem(seed=0)  # equal content, new objects
    assert a.content_hash() == b.content_hash()
    out = solve_batch([a, b, _problem(seed=1)], "dfts_jax")
    assert out[0] is out[1]  # dedup shares the outcome object
    assert out[0].plan == solve(a, "dfts_jax").plan


def test_batch_empty_and_singleton():
    assert solve_batch([], "dfts_jax") == []
    p = _problem(seed=0)
    outs = solve_batch([p], "dfts_jax")
    assert len(outs) == 1 and outs[0].feasible
    assert outs[0].plan == solve(p, "dfts_jax").plan


# ------------------------------------------------- content-hash / memo keys
def test_hash_stable_results_across_padding():
    """Content-hash-equal instances must produce bit-identical results no
    matter where they land in a padded batch (regression: padding position
    must not leak into decode)."""
    base = _problem(mode=TR, K=3, b=128, seed=1)
    twin = _problem(mode=TR, K=3, b=128, seed=1)
    fillers = [_problem(mode=IF, K=2, b=2, seed=s) for s in range(4)]
    o1 = solve_batch([base] + fillers, "dfts_jax", dedup=False)[0]
    o2 = solve_batch(fillers + [twin], "dfts_jax", dedup=False)[-1]
    assert base.content_hash() == twin.content_hash()
    assert o1.plan == o2.plan
    assert o1.latency == o2.latency


def test_memo_keys_distinguish_schedule_and_microbatches():
    """seq / pipe-M4 / pipe-M16 variants of one cell are distinct instances:
    hashes differ and interleaved solving never cross-contaminates (a key
    collision across (schedule, M) would surface here as a wrong latency)."""
    import repro.core.jax_solvers as jx

    variants = [
        _problem(mode=IF, K=3, b=32, seed=0),
        _problem(mode=IF, K=3, b=32, seed=0, schedule=PIPE, M=4),
        _problem(mode=IF, K=3, b=32, seed=0, schedule=PIPE, M=16),
    ]
    hashes = [p.content_hash() for p in variants]
    assert len(set(hashes)) == len(hashes)

    # cold reference: each variant solved with every module memo cleared
    cold = []
    for p in variants:
        for memo in (jx._ENCODE_MEMO, jx._GRID_MEMO, jx._SHIP_MEMO,
                     jx._PATH_MEMO, jx._PATHCOST_MEMO, jx._NODEVEC_MEMO,
                     jx._PROFILE_MEMO, jx._PLAN_MEMO):
            memo.clear()
        cold.append(solve(p, "dfts_jax"))
    # warm: all three interleaved twice over shared memos
    for _ in range(2):
        for p, ref in zip(variants, cold):
            got = solve(p, "dfts_jax")
            assert got.plan == ref.plan
            assert got.latency == ref.latency


# ----------------------------------------------------- engine / registry
def test_registered_with_capabilities():
    names = solver_names()
    for required in ("dfts_np", "dfts_jax", "bcd_jax"):
        assert required in names
    for name in ("dfts_jax", "bcd_jax"):
        caps = engine_mod.get_solver(name).capabilities()
        assert caps["batched"] is True
        assert set(caps["schedules"]) == {"seq", "pipe"}
    assert engine_mod.get_solver("dfts_np").capabilities()["batched"] is False


def test_solve_batch_capability_error_uniform():
    """solve_batch raises the same actionable message as scalar solve, before
    any solving starts."""
    good = _problem(mode=IF, K=3, b=32, seed=0)
    pipe = _problem(mode=IF, K=3, b=32, seed=0, schedule=PIPE, M=4)
    with pytest.raises(ValueError) as scalar_err:
        solve(pipe, "ilp")
    with pytest.raises(ValueError) as batch_err:
        solve_batch([good, pipe], "ilp")
    assert str(batch_err.value) == str(scalar_err.value)
    assert "ilp" in str(batch_err.value)
    with pytest.raises(ValueError):
        solve_batch([good], "no-such-solver")


def test_scalar_solvers_batch_via_fallback_loop():
    """Every registered solver is batch-dispatchable: no batch_fn means a
    scalar solve loop with identical outcomes."""
    problems = [_problem(seed=0), _problem(seed=1)]
    outs = solve_batch(problems, "bcd", dedup=False)
    for p, got in zip(problems, outs):
        want = solve(p, "bcd")
        assert got.plan == want.plan
        assert got.latency == want.latency


def test_portfolio_survives_missing_jax_solvers():
    """With the JAX solvers deregistered (e.g. jax absent at import), the
    portfolio and the batch entry point still work on scalar members."""
    saved = {}
    for name in ("dfts_jax", "bcd_jax", "dfts_np"):
        saved[name] = engine_mod._REGISTRY.pop(name)
    try:
        assert "dfts_jax" not in solver_names()
        p = _problem(seed=0)
        out = portfolio_solve(*p.solver_args())
        assert out.feasible and out.stats["winner"] in solver_names()
        outs = solve_batch([p], "bcd")
        assert outs[0].feasible
        with pytest.raises(ValueError):
            solve_batch([p], "dfts_jax")
    finally:
        engine_mod._REGISTRY.update(saved)
    assert "dfts_jax" in solver_names()


def test_deprecated_shims_bit_for_bit():
    """The warn-once legacy shims keep returning bit-identical plans now that
    the registry carries batch functions too."""
    import warnings

    p = _problem(mode=TR, K=3, b=128, seed=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = bcd_solve(*p.solver_args())
    out = solve(p, "bcd")
    assert res.plan == out.plan
    assert res.latency == out.latency


# -------------------------------------------------- min_batch dispatch gate
def test_min_batch_threshold_routes_tiny_batches_to_scalar_loop():
    """Below ``min_batch`` unique instances, solve_batch skips the padded
    vectorized kernel (whose fixed overhead loses to the scalar loop at the
    measured crossover, BENCH_solver.json) — with identical outcomes either
    side of the threshold, so dispatch is purely a performance decision."""
    import dataclasses

    from repro.core import engine as eng

    problems = [_problem(seed=0), _problem(seed=1)]
    calls = {"batch": 0}
    info = eng.get_solver("dfts_jax")
    orig = info.batch_fn

    def counting_batch_fn(unique, *, cache=None, **kw):
        calls["batch"] += 1
        return orig(unique, cache=cache, **kw)

    eng._REGISTRY["dfts_jax"] = dataclasses.replace(
        info, batch_fn=counting_batch_fn)
    try:
        # 2 unique < default threshold (4): the scalar loop handles it
        assert eng.SOLVE_BATCH_MIN_BATCH == 4
        via_loop = solve_batch(problems, "dfts_jax", dedup=False)
        assert calls["batch"] == 0
        # forcing min_batch=1 routes the same set through the batch kernel
        via_kernel = solve_batch(problems, "dfts_jax", dedup=False,
                                 min_batch=1)
        assert calls["batch"] == 1
        # and a high threshold forces the loop even for big-enough batches
        solve_batch(problems * 3, "dfts_jax", dedup=False, min_batch=100)
        assert calls["batch"] == 1
    finally:
        eng._REGISTRY["dfts_jax"] = info
    for a, b in zip(via_loop, via_kernel):
        assert a.feasible == b.feasible
        assert a.plan == b.plan
        assert a.latency == b.latency  # bit-identical either side
        assert a.status == b.status
