"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # interpret-mode kernel sweeps (~30s)

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 6e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,hd,causal,window,softcap",
    [
        (2, 128, 128, 4, 2, 64, True, None, None),
        (1, 200, 200, 8, 1, 64, True, None, 50.0),  # MQA + softcap + ragged
        (2, 256, 256, 4, 4, 128, True, 64, None),  # sliding window
        (1, 64, 256, 2, 2, 64, False, None, None),  # cross attention
        (1, 96, 96, 6, 3, 32, True, 32, 30.0),  # everything + tiny head
    ],
)
def test_flash_attention(B, Sq, Sk, Hq, Hkv, hd, causal, window, softcap, dtype):
    q = _rand((B, Sq, Hq, hd), dtype)
    k = _rand((B, Sk, Hkv, hd), dtype)
    v = _rand((B, Sk, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=64, block_k=64)
    expect = ref.reference_attention(q, k, v, causal=causal, window=window,
                                     softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F,act", [
    (4, 64, 96, 80, "none"),
    (2, 128, 256, 128, "silu"),
    (8, 40, 72, 200, "gelu"),  # ragged, padding exercised
])
def test_expert_matmul(E, C, D, F, act, dtype):
    x = _rand((E, C, D), dtype)
    w = _rand((E, D, F), dtype) * 0.1
    out = ops.expert_matmul(x, w, activation=act, block_c=32, block_f=64,
                            block_d=64)
    expect = ref.reference_expert_matmul(x, w, activation=act)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=8 * TOL[dtype], rtol=8 * TOL[dtype])


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 64, 128, 16, 64),
    (1, 100, 48, 32, 32),  # ragged
    (3, 256, 512, 64, 256),
])
def test_rglru_scan(B, S, W, bs, bw):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (B, S, W)), jnp.float32)
    b = _rand((B, S, W), jnp.float32) * 0.1
    out = ops.rglru_scan(a, b, block_s=bs, block_w=bw)
    expect = ref.reference_rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 128, 64),
])
def test_ssd_intra_chunk(B, S, H, P, N, Q):
    nc = S // Q
    x = _rand((B, nc, H, Q, P), jnp.float32)
    Bm = _rand((B, nc, Q, N), jnp.float32) * 0.3
    Cm = _rand((B, nc, Q, N), jnp.float32) * 0.3
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, nc, H, Q)), jnp.float32)
    A = jnp.asarray(RNG.uniform(0.5, 4.0, (H,)), jnp.float32)
    y, hc, dec = ops.ssd_intra_chunk(x, Bm, Cm, dt, A)
    ye, hce, dece = ref.reference_ssd_intra_chunk(x, Bm, Cm, dt, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hce), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(dece), atol=1e-5,
                               rtol=1e-5)


def test_ssd_forward_matches_model_layer():
    """The composed kernel path must equal the model's _ssd_chunked oracle."""
    from repro.models.layers import _ssd_chunked

    B, S, H, P, N, Q = 2, 128, 4, 32, 16, 32
    x = _rand((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(RNG.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32) * 0.3
    Cm = _rand((B, S, N), jnp.float32) * 0.3
    y_k, h_k = ops.ssd_forward(x, dt, A, Bm, Cm, chunk=Q)
    y_m, h_m = _ssd_chunked(x, dt, -A, Bm, Cm, None, chunk=Q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("seed", range(5))
def test_flash_attention_property(seed):
    """Hypothesis-style randomized shapes (GQA divisibility respected)."""
    rng = np.random.default_rng(seed)
    hd = int(rng.choice([32, 64, 128]))
    Hkv = int(rng.choice([1, 2, 4]))
    G = int(rng.choice([1, 2, 4]))
    Sq = int(rng.integers(16, 200))
    q = _rand((1, Sq, Hkv * G, hd), jnp.float32)
    k = _rand((1, Sq, Hkv, hd), jnp.float32)
    v = _rand((1, Sq, Hkv, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    expect = ref.reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4,
                               rtol=2e-4)
