"""Property lockdown for the Pallas tropical (min-plus) matmul.

The kernel runs in interpret mode here (CPU CI path — the same code Mosaic
lowers on TPU); the oracle is the dense jnp broadcast in
``repro.kernels.ref``.  Deterministic grids cover the properties on every
run; the Hypothesis suite at the bottom fuzzes them further when
``hypothesis`` is installed (optional — without it the deterministic grid is
the coverage, not a skip of the whole module).
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.experimental import enable_x64  # noqa: E402

from repro.kernels.minplus import minplus_matmul  # noqa: E402
from repro.kernels.ref import reference_minplus  # noqa: E402

INF = np.inf


def _mm(a, b):
    """Kernel under f64 (the solvers always call it inside ``enable_x64``)."""
    with enable_x64():
        return minplus_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True)


def _ref(a, b):
    with enable_x64():
        return reference_minplus(jnp.asarray(a), jnp.asarray(b))


def _rand(rng, shape, p_inf=0.2):
    """Cost-like matrix: non-negative floats with +inf holes (infeasible
    hops), the only matrix population the solvers ever produce."""
    x = rng.uniform(0.0, 10.0, size=shape)
    x[rng.uniform(size=shape) < p_inf] = INF
    return x


def _check(a, b):
    val, idx = _mm(a, b)
    rval, ridx = _ref(a, b)
    np.testing.assert_array_equal(np.asarray(val), np.asarray(rval))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


# ------------------------------------------------------- deterministic grid
# deliberately off-tile shapes: the kernel pads to (8, 128) tiles internally
_SHAPES = [
    (1, 1, 1),
    (2, 3, 4),
    (8, 8, 8),
    (5, 128, 7),
    (9, 130, 3),     # crosses both the _BM=8 and _BK=128 tile boundaries
    (16, 16, 16),
]


@pytest.mark.parametrize("m,k,n", _SHAPES)
def test_matches_reference(m, k, n):
    rng = np.random.default_rng((m * 73856093 + k * 19349663 + n) % 2**32)
    _check(_rand(rng, (m, k)), _rand(rng, (k, n)))


@pytest.mark.parametrize("batch", [(1,), (3,), (2, 2)])
def test_batched_matches_reference(batch):
    rng = np.random.default_rng(7)
    _check(_rand(rng, batch + (4, 6)), _rand(rng, batch + (6, 5)))


def test_first_argmin_on_ties():
    # two equal minimizing k: the first index must win (np.argmin convention)
    a = np.array([[1.0, 1.0, 5.0]])
    b = np.array([[2.0], [2.0], [0.0]])
    val, idx = _mm(a, b)
    assert float(val[0, 0]) == 3.0
    assert int(idx[0, 0]) == 0


def test_inf_padding_absorbs():
    """Growing either operand with +inf rows/cols must not change the valid
    region — the exact property the solvers' shape padding relies on."""
    rng = np.random.default_rng(11)
    a, b = _rand(rng, (5, 6)), _rand(rng, (6, 4))
    val, idx = _mm(a, b)
    ap = np.pad(a, ((0, 3), (0, 10)), constant_values=INF)
    bp = np.pad(b, ((0, 10), (0, 5)), constant_values=INF)
    vp, ip = _mm(ap, bp)
    np.testing.assert_array_equal(np.asarray(vp)[:5, :4], np.asarray(val))
    np.testing.assert_array_equal(np.asarray(ip)[:5, :4], np.asarray(idx))


def test_all_inf_column_yields_index_zero():
    a = np.full((2, 3), INF)
    b = _rand(np.random.default_rng(3), (3, 2), p_inf=0.0)
    val, idx = _mm(a, b)
    assert np.all(np.isinf(np.asarray(val)))
    assert np.all(np.asarray(idx) == 0)  # jnp.argmin convention on all-inf


def test_associativity_of_values():
    """(A ∘ B) ∘ C == A ∘ (B ∘ C) on values — the tropical semiring law the
    multi-hop frontier composition depends on.  (Indices are relative to
    different factorizations, so only values are comparable.)"""
    rng = np.random.default_rng(23)
    a, b, c = _rand(rng, (4, 5)), _rand(rng, (5, 6)), _rand(rng, (6, 3))
    ab, _ = _mm(a, b)
    bc, _ = _mm(b, c)
    left, _ = _mm(np.asarray(ab), c)
    right, _ = _mm(a, np.asarray(bc))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=1e-12, atol=0)


def test_shape_errors():
    with pytest.raises(ValueError, match="contraction"):
        _mm(np.zeros((2, 3)), np.zeros((4, 2)))
    with pytest.raises(ValueError, match="batch"):
        _mm(np.zeros((2, 2, 3)), np.zeros((3, 3, 2)))


# ------------------------------------------------------ hypothesis fuzzing
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:  # optional dependency; deterministic grid still ran
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:

    @st.composite
    def _mats(draw):
        m = draw(st.integers(1, 12))
        k = draw(st.integers(1, 20))
        n = draw(st.integers(1, 12))
        seed = draw(st.integers(0, 2**16))
        p_inf = draw(st.sampled_from([0.0, 0.2, 0.9]))
        rng = np.random.default_rng(seed)
        return _rand(rng, (m, k), p_inf), _rand(rng, (k, n), p_inf)

    @settings(max_examples=25, deadline=None)
    @given(_mats())
    def test_hypothesis_matches_reference(ab):
        _check(*ab)

    @settings(max_examples=15, deadline=None)
    @given(_mats())
    def test_hypothesis_inf_padding_absorbs(ab):
        a, b = ab
        val, idx = _mm(a, b)
        ap = np.pad(a, ((0, 2), (0, 3)), constant_values=INF)
        bp = np.pad(b, ((0, 3), (0, 1)), constant_values=INF)
        vp, ip = _mm(ap, bp)
        m, n = a.shape[0], b.shape[1]
        np.testing.assert_array_equal(np.asarray(vp)[:m, :n],
                                      np.asarray(val))
        np.testing.assert_array_equal(np.asarray(ip)[:m, :n],
                                      np.asarray(idx))
else:

    @pytest.mark.skip(reason="hypothesis not installed; deterministic grid "
                             "above is the coverage")
    def test_hypothesis_suite_unavailable():
        pass
