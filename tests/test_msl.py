"""MSL pipeline: planner on arch profiles + shard_map runtime equivalence
(the runtime check needs >1 device, so it runs via subprocess with
xla_force_host_platform_device_count)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import ARCHS
from repro.msl import group_profile, plan_pipeline

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.mark.parametrize("arch", ["qwen3-14b", "gemma2-27b", "mamba2-370m",
                                  "qwen3-moe-30b-a3b"])
def test_plan_pipeline(arch):
    cfg = ARCHS[arch]
    plan = plan_pipeline(cfg, seq_len=4096, microbatch=8,
                         candidate_K=(2, 4, 8))
    assert 2 <= plan.K <= 8
    assert sum(plan.groups_per_stage) == plan.n_groups
    assert plan.predicted_latency_s > 0
    # segments are a contiguous partition
    lo_expect = 1
    for lo, hi in plan.segments:
        assert lo == lo_expect and hi >= lo
        lo_expect = hi + 1
    assert plan.segments[-1][1] == plan.n_groups


def test_group_profile_conserves_totals():
    cfg = ARCHS["gemma2-27b"]
    from repro.core import FW
    from repro.models.profiles import model_profile

    gp = group_profile(cfg, 4096, "train")
    full = model_profile(cfg, 4096, "train")
    block_rows = full.layers[1:-1]
    assert sum(l.flops_fw for l in gp.layers) == pytest.approx(
        sum(l.flops_fw for l in block_rows))
    assert sum(l.mem_bytes for l in gp.layers) == pytest.approx(
        sum(l.mem_bytes for l in block_rows))


@pytest.mark.slow  # subprocess shard_map pipeline run (~1 min per arch)
@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-370m"])
def test_pipeline_runtime_equivalence(arch):
    """Pipelined forward == sequential forward; pipelined train step runs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.msl.pipeline_check", arch],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "PIPELINE CHECK OK" in proc.stdout
