"""Pipelined execution model (docs/pipeline.md): evaluator properties
(M=1 bit-for-bit sequential, pipe <= seq, monotone in M), solver exactness
(exact-pipe == brute force on tiny instances), BCD-pipe parity, serve-layer
steady-state occupancy accounting, and the nsfnet_pipeline sweep invariants."""
import itertools
import random
from dataclasses import replace

import pytest

from repro.core import (
    IF,
    TR,
    ComputeModel,
    LayerProfile,
    LinkSpec,
    ModelProfile,
    NodeSpec,
    PhysicalNetwork,
    PlanEvaluator,
    ServiceChainRequest,
    bcd_solve,
    exact_solve,
    ilp_solve,
    nsfnet,
    resnet101_profile,
)

GB = 1024**3


def _random_instance(seed: int, n_nodes: int = 6, L: int = 6, K: int = 3,
                     chord_p: float = 0.4):
    rng = random.Random(seed)
    net = PhysicalNetwork()
    names = [f"n{i}" for i in range(n_nodes)]
    for i, name in enumerate(names):
        cm = ComputeModel(name=f"dev{i}",
                          pieces=((float("inf"), rng.uniform(1e-12, 2e-10), 1e-12),),
                          alpha_tau=rng.choice([0.0, 2e-13]), beta_tau=0.0)
        cap = rng.uniform(0.4, 4.0) * GB
        net.add_node(NodeSpec(name, cm, cap, cap))
    edges = {(i, (i + 1) % n_nodes) for i in range(n_nodes)}
    for i in range(n_nodes):
        for j in range(i + 1, n_nodes):
            if rng.random() < chord_p:
                edges.add((i, j))
    for i, j in edges:
        d = rng.uniform(1e-3, 15e-3)
        bw = rng.choice([0.5e9, 1e9, 2e9])
        net.add_bidirectional(names[i], names[j], LinkSpec(bw, bw, d, d))
    layers = []
    for l in range(L):
        fw = rng.uniform(0.1, 8.0) * 1e9
        act = rng.uniform(0.01, 3.0) * 1e6
        mem = rng.uniform(1, 300) * 1e6
        layers.append(LayerProfile(f"l{l}", fw, 2 * fw, act, act, mem, mem))
    prof = ModelProfile("rand", layers)
    s, d = names[0], names[-1]
    mids = names[1:-1]
    cands = [[s]] + [rng.sample(mids, k=min(2, len(mids))) for _ in range(K - 2)] + [[d]]
    mode = rng.choice([IF, TR])
    b = rng.choice([8, 32, 128])
    req = ServiceChainRequest("rand", s, d, b, mode)
    return net, prof, req, K, cands


def _pipe(req: ServiceChainRequest, M: int) -> ServiceChainRequest:
    return replace(req, schedule="pipe", n_microbatches=M)


# --------------------------------------------------- evaluator: M=1 bit-for-bit
def test_pipe_m1_evaluator_bitforbit_nsfnet():
    """Acceptance criterion: the pipelined evaluator with n_microbatches=1 is
    *bit-for-bit* equal to the sequential evaluator on paper-grid plans."""
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    for mode, b, K in [(IF, 2, 3), (IF, 64, 4), (TR, 128, 3), (TR, 8, 5)]:
        cands = ([["v4"]] + [["v7", "v11"], ["v9", "v2"], ["v5", "v12"]][: K - 2]
                 + [["v13"]])
        req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
        for solver in (exact_solve, bcd_solve):
            res = solver(net, prof, req, K, cands)
            assert res.feasible
            seq_lb = PlanEvaluator(net, prof, req).evaluate(res.plan)
            ev1 = PlanEvaluator(net, prof, _pipe(req, 1))
            pipe_lb = ev1.evaluate(res.plan)
            assert pipe_lb.computation_s == seq_lb.computation_s
            assert pipe_lb.transmission_s == seq_lb.transmission_s
            assert pipe_lb.propagation_s == seq_lb.propagation_s
            assert pipe_lb.bubble_s == 0.0
            assert pipe_lb.total_s == seq_lb.total_s


@pytest.mark.parametrize("seed", range(6))
def test_pipe_m1_evaluator_bitforbit_random(seed):
    net, prof, req, K, cands = _random_instance(seed)
    res = exact_solve(net, prof, req, K, cands)
    if not res.feasible:
        return
    seq = PlanEvaluator(net, prof, req).latency_s(res.plan)
    pipe1 = PlanEvaluator(net, prof, _pipe(req, 1)).latency_s(res.plan)
    assert pipe1 == seq


@pytest.mark.parametrize("solver", [exact_solve, bcd_solve])
def test_pipe_m1_solver_bitforbit(solver):
    """Solvers treat M=1 as the sequential special case exactly."""
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    for mode, b in [(IF, 32), (TR, 128)]:
        req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
        seq = solver(net, prof, req, 3, cands)
        p1 = solver(net, prof, _pipe(req, 1), 3, cands)
        assert p1.latency_s == seq.latency_s
        assert p1.plan.segments == seq.plan.segments
        assert p1.plan.placement == seq.plan.placement


# -------------------------------------------- pipe <= seq and monotone in M
@pytest.mark.parametrize("seed", range(10))
def test_pipe_leq_seq_and_monotone_in_M(seed):
    """For any fixed plan, pipelined latency is <= sequential for every M >= 1
    and non-increasing in M (the bottleneck can't exceed the stage-time sum)."""
    net, prof, req, K, cands = _random_instance(seed, n_nodes=7, L=8, K=3)
    res = bcd_solve(net, prof, req, K, cands)
    if not res.feasible:
        return
    seq = PlanEvaluator(net, prof, req).latency_s(res.plan)
    prev = seq
    for M in (1, 2, 3, 4, 8, 16, 64):
        lat = PlanEvaluator(net, prof, _pipe(req, M)).latency_s(res.plan)
        assert lat <= seq * (1 + 1e-12)
        assert lat <= prev * (1 + 1e-12)
        prev = lat


def test_bubble_matches_bottleneck_formula():
    net, prof, req, K, cands = _random_instance(1, n_nodes=7, L=8, K=3)
    res = exact_solve(net, prof, req, K, cands)
    assert res.feasible
    for M in (2, 8):
        ev = PlanEvaluator(net, prof, _pipe(req, M))
        lb = ev.evaluate(res.plan)
        tau = ev.bottleneck_s(res.plan)
        assert lb.bubble_s == pytest.approx((M - 1) * tau / M, rel=1e-12)
        assert lb.total_s == pytest.approx(
            lb.computation_s + lb.transmission_s + lb.propagation_s + lb.bubble_s)


# --------------------------------------------------- exact-pipe == brute force
def _all_simple_paths(net, src, dst):
    out_edges = {}
    for (u, v) in net.links:
        out_edges.setdefault(u, []).append(v)
    out, path = [], [src]

    def rec(node):
        if node == dst:
            out.append(list(path))
            return
        for v in out_edges.get(node, ()):
            if v not in path:
                path.append(v)
                rec(v)
                path.pop()

    rec(src)
    return out


def _brute_force_pipe(net, prof, req, K, cands):
    """Exhaustive min over (segmentation, placement, subpath combinations) of
    the pipelined evaluator; the tail is propagation-only and contributes no
    pipeline stage, so its best (min-propagation) simple path is separable."""
    from repro.core import Plan

    ev = PlanEvaluator(net, prof, req)
    L = prof.L
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), K - 1):
        segs, lo = [], 1
        for c in list(cuts) + [L]:
            segs.append((lo, c))
            lo = c + 1
        for placement in itertools.product(*cands):
            if not all(ev.segment_fits(n, lo, hi)
                       for (lo, hi), n in zip(segs, placement)):
                continue
            path_sets = [_all_simple_paths(net, placement[k], placement[k + 1])
                         for k in range(K - 1)]
            if any(not ps for ps in path_sets):
                continue
            tails = _all_simple_paths(net, placement[-1], req.destination)
            if not tails:
                continue

            def tail_prop(path):
                # the evaluator charges the psi_K = 0 tail FW-only (Eq. 16)
                return net.path_cost_breakdown(path, 0.0, None)[1]

            tail = min(tails, key=tail_prop)
            for combo in itertools.product(*path_sets):
                plan = Plan(segments=list(segs), placement=list(placement),
                            paths=[list(p) for p in combo],
                            tail_path=tail if len(tail) > 1 else [])
                best = min(best, ev.latency_s(plan))
    return best


@pytest.mark.parametrize("seed", range(5))
def test_exact_pipe_equals_bruteforce(seed):
    net, prof, req, K, cands = _random_instance(seed, n_nodes=5, L=5, K=3,
                                                chord_p=0.3)
    for M in (2, 4):
        preq = _pipe(req, M)
        res = exact_solve(net, prof, preq, K, cands)
        bf = _brute_force_pipe(net, prof, preq, K, cands)
        if bf == float("inf"):
            assert not res.feasible
        else:
            assert res.feasible
            assert res.latency_s == pytest.approx(bf, rel=1e-9)


# ----------------------------------------------------------- BCD-pipe parity
@pytest.mark.parametrize("seed", range(10))
def test_bcd_pipe_vs_exact_pipe_parity(seed):
    """exact-pipe is a true lower bound; BCD-pipe is seq-anchored (<= the
    seq-optimal plan evaluated under pipe) and near-optimal in practice."""
    net, prof, req, K, cands = _random_instance(seed, n_nodes=7, L=8, K=3)
    seq_opt = exact_solve(net, prof, req, K, cands)
    if not seq_opt.feasible:
        return
    for M in (4, 16):
        preq = _pipe(req, M)
        opt = exact_solve(net, prof, preq, K, cands)
        heur = bcd_solve(net, prof, preq, K, cands)
        assert opt.feasible and heur.feasible
        ev = PlanEvaluator(net, prof, preq)
        ev.check(heur.plan)
        assert heur.latency_s >= opt.latency_s - 1e-12
        assert heur.latency_s <= 2.0 * opt.latency_s  # BCD-pipe has more local
        # optima than seq BCD (bottleneck couples placement+splitting); the
        # anchored bound below is the hard guarantee
        anchored = ev.latency_s(seq_opt.plan)
        assert heur.latency_s <= anchored + 1e-12
        assert opt.latency_s <= anchored + 1e-12
        # monotone history (each half-step minimizes the pipe objective)
        for a, b in zip(heur.history, heur.history[1:]):
            assert b <= a + 1e-12


def test_bcd_pipe_leq_bcd_seq_on_nsfnet():
    """The suite invariant: same instance + solver, pipe latency <= seq."""
    net = nsfnet(source="v4")
    prof = resnet101_profile()
    cands = [["v4"], ["v7", "v11"], ["v13"]]
    for mode, b in [(IF, 32), (TR, 128)]:
        req = ServiceChainRequest("resnet101", "v4", "v13", b, mode)
        seq = bcd_solve(net, prof, req, 3, cands)
        prev = seq.latency_s
        for M in (2, 4, 8, 16, 32):
            res = bcd_solve(net, prof, _pipe(req, M), 3, cands)
            assert res.latency_s <= seq.latency_s * (1 + 1e-12)
            assert res.latency_s <= prev * (1 + 1e-9)  # deeper pipeline helps
            prev = res.latency_s


def test_ilp_rejects_pipelined_requests():
    net, prof, req, K, cands = _random_instance(0)
    with pytest.raises(ValueError, match="seq"):
        ilp_solve(net, prof, _pipe(req, 4), K, cands)


def test_microbatch_clamp():
    """M is clamped to the batch size: a 2-sample batch pipelines at most
    2-deep, and M=clamped-to-1 is exactly sequential."""
    req = ServiceChainRequest("m", "a", "b", 2, IF, schedule="pipe",
                              n_microbatches=64)
    assert req.microbatches() == 2
    assert ServiceChainRequest("m", "a", "b", 1, IF, schedule="pipe",
                               n_microbatches=64).microbatches() == 1
    assert ServiceChainRequest("m", "a", "b", 128, IF).microbatches() == 1


# ------------------------------------------------- serve: occupancy accounting
def test_pipe_plan_demand_uses_steady_state_occupancy():
    """A pipelined chain reserves min(rate, 1/tau): at a requested rate above
    its streaming throughput it reserves strictly less than the seq chain."""
    from repro.serve import effective_rate_rps, generate_fleet, plan_demand

    net = nsfnet(source="v4")
    prof = resnet101_profile()
    fleet = generate_fleet(net, 1, "v4", "v13", 4, IF, 3, seed=0,
                           model_id="resnet101", rate_rps=1.0)
    r_seq = fleet[0]
    res = bcd_solve(net, prof, r_seq.chain_request(), 3,
                    r_seq.candidate_lists())
    assert res.feasible
    tau = PlanEvaluator(net, prof, _pipe(r_seq.chain_request(), 8)
                        ).bottleneck_s(res.plan)
    hot_rate = 2.0 / tau  # twice the pipeline's streaming throughput
    r_seq = replace(r_seq, rate_rps=hot_rate)
    r_pipe = replace(r_seq, schedule="pipe", n_microbatches=8)
    assert effective_rate_rps(prof, r_pipe, res.plan, net) == pytest.approx(
        1.0 / tau)
    assert effective_rate_rps(prof, r_seq, res.plan, net) == hot_rate
    d_seq = plan_demand(prof, r_seq, res.plan, net)
    d_pipe = plan_demand(prof, r_pipe, res.plan, net)
    for link, f in d_seq.link_fw_bps.items():
        assert d_pipe.link_fw_bps[link] == pytest.approx(f / 2.0)
    # node footprints are schedule-invariant (conservative full-batch peak)
    assert d_pipe.node_mem_bytes == d_seq.node_mem_bytes
    assert d_pipe.node_disk_bytes == d_seq.node_disk_bytes


def test_pipe_fleet_admission_and_replay():
    """Pipelined fleets admit at least as many chains as sequential ones at a
    hot execution rate, and their admission records replay cleanly."""
    from repro.serve import ServedRequest, ServePlanner, generate_fleet, replay_verify

    net = nsfnet(source="v4")
    prof = resnet101_profile()
    kw = dict(seed=0, model_id="resnet101", rate_rps=8.0)
    seq_fleet = generate_fleet(net, 8, "v4", "v13", 4, IF, 3, **kw)
    pipe_fleet = generate_fleet(net, 8, "v4", "v13", 4, IF, 3,
                                schedule="pipe", n_microbatches=8, **kw)
    out_seq = ServePlanner(net, prof, solver="bcd").admit(seq_fleet)
    out_pipe = ServePlanner(net, prof, solver="bcd").admit(pipe_fleet)
    assert out_pipe.n_accepted >= out_seq.n_accepted
    assert out_pipe.n_accepted >= 1
    # round-trip the records and replay against a fresh residual state
    records = [ServedRequest.from_dict(s.to_dict()) for s in out_pipe.served]
    assert all(r.request.schedule == "pipe" for r in records)
    assert replay_verify(net, prof, records)


# ---------------------------------------------------- sweep: nsfnet_pipeline
def test_nsfnet_pipeline_suite_speedups():
    """Acceptance criterion: the nsfnet_pipeline report pairs every pipe
    scenario with its seq counterpart, speedup >= 1 everywhere, and the M=1
    rows are *exactly* 1.0 (bit-for-bit sequential)."""
    from repro.sweep import SweepRunner, comparison_report, verify_result
    from repro.sweep.suites import nsfnet_pipeline

    specs = nsfnet_pipeline(quick=True)
    results = SweepRunner(workers=0).run(specs)
    assert all(r.feasible for r in results)
    report = comparison_report(results)
    sc = report["schedule_comparison"]
    n_pipe = sum(r.spec.schedule == "pipe" for r in results)
    assert sc is not None and sc["n_pairs"] == n_pipe > 0
    for p in sc["pairs"].values():
        assert p["speedup"] >= 1.0 - 1e-12
        if p["n_microbatches"] == 1:
            assert p["speedup"] == 1.0
            assert p["bubble_s"] == 0.0
        else:
            assert p["bubble_s"] > 0.0
    # artifact round-trip: every pipe result re-evaluates to its recorded latency
    for r in results:
        assert verify_result(r)


def test_scenario_spec_schedule_roundtrip():
    from repro.sweep import ScenarioSpec

    spec = ScenarioSpec(batch_size=32, schedule="pipe", n_microbatches=8,
                        solver="bcd")
    again = ScenarioSpec.from_dict(spec.to_dict())
    assert again.spec_hash() == spec.spec_hash()
    assert "pipeM8" in spec.scenario_id()
    seq = ScenarioSpec(batch_size=32, solver="bcd")
    assert seq.schedule_key() == spec.schedule_key()
    assert seq.group_key() != spec.group_key()
    assert seq.spec_hash() != spec.spec_hash()
    with pytest.raises(ValueError, match="ilp"):
        ScenarioSpec(batch_size=32, schedule="pipe", n_microbatches=8,
                     solver="ilp")
    # an ilp spec whose M clamps to 1 is sequential and therefore fine
    ScenarioSpec(batch_size=1, schedule="pipe", n_microbatches=8, solver="ilp")
    with pytest.raises(ValueError, match="schedule"):
        ScenarioSpec(schedule="interleaved")
