"""Cost-model profiles: Table I fidelity + analytic arch profiles sanity."""
import pytest

from repro.configs import ARCHS
from repro.core import FW, BW, resnet101_profile
from repro.models.profiles import active_params, model_profile, total_params

EXPECTED_PARAMS_B = {  # nameplate sanity bands
    "qwen3-moe-30b-a3b": (28, 33),
    "arctic-480b": (450, 500),
    "llama-3.2-vision-90b": (80, 95),
    "qwen2-1.5b": (1.3, 1.8),
    "starcoder2-7b": (6.5, 8.0),
    "gemma2-27b": (25, 29),
    "qwen3-14b": (13, 16),
    "recurrentgemma-9b": (8.3, 10.5),
    "whisper-small": (0.15, 0.4),
    "mamba2-370m": (0.3, 0.45),
}


def test_resnet101_table1():
    prof = resnet101_profile()
    assert prof.L == 37
    # spot values straight from Table I
    assert prof.layers[0].flops_fw == pytest.approx(236.02e6)
    assert prof.layers[2].mem_bytes == pytest.approx(3.02e6)
    assert prof.layers[32].mem_bytes == pytest.approx(234.92e6)
    assert prof.layers[35].act_bytes == 8192
    assert prof.layers[36].act_bytes == 4000
    # paper characteristics: (C1) middle layers dominate compute
    mid = prof.seg_flops(3, 35, FW)
    assert mid / prof.total_flops(FW) > 0.95
    # (C2) smashed data size non-increasing after layer 2
    acts = [l.act_bytes for l in prof.layers]
    assert all(a >= b for a, b in zip(acts[2:], acts[3:]))
    # BW = 2x FW (paper rounds to 3 significant digits, e.g. 12.9 vs 2x6.43)
    for l in prof.layers:
        assert l.flops_bw == pytest.approx(2 * l.flops_fw, rel=5e-3)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_profile_sane(arch):
    cfg = ARCHS[arch]
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = total_params(cfg) / 1e9
    assert lo <= n <= hi, f"{arch}: {n}B params out of band ({lo},{hi})"
    assert active_params(cfg) <= total_params(cfg)
    prof = model_profile(cfg, seq_len=4096, mode="train")
    assert prof.L == 1 + cfg.enc_layers + cfg.n_layers + 1
    for l in prof.layers:
        assert l.flops_fw >= 0 and l.mem_bytes >= 0
        assert l.flops_bw == pytest.approx(2 * l.flops_fw)
    # decode flops per token << train flops per sequence (excluding encoder
    # rows: the chain profile charges the enc once per request, not per token)
    dec = model_profile(cfg, seq_len=4096, mode="decode", cache_len=32768)
    dec_flops = sum(l.flops_fw for l in dec.layers[1 + cfg.enc_layers:])
    assert dec_flops < prof.total_flops(FW) / 100


@pytest.mark.parametrize("arch", ["qwen3-14b", "qwen3-moe-30b-a3b", "mamba2-370m"])
def test_planner_runs_on_arch_profiles(arch):
    """The paper's planner consumes every arch profile (DESIGN.md Sec. 3)."""
    from repro.core import IF, TR, ServiceChainRequest, bcd_solve, exact_solve, tpu_pod_topology

    cfg = ARCHS[arch]
    prof = model_profile(cfg, seq_len=4096, mode="train")
    net = tpu_pod_topology(n_groups=8, chips_per_group=32)
    nodes = sorted(net.nodes)
    K = 4
    cands = [[nodes[0]]] + [nodes[1:4], nodes[4:7]] + [[nodes[-1]]]
    req = ServiceChainRequest(cfg.name, nodes[0], nodes[-1], 8, TR)
    opt = exact_solve(net, prof, req, K, cands)
    heur = bcd_solve(net, prof, req, K, cands)
    assert opt.feasible and heur.feasible
    assert heur.latency_s <= 1.5 * opt.latency_s + 1e-9
