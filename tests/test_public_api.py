"""Public-API snapshot: `repro.core.__all__` is a contract — accidental export
churn (a refactor dropping or silently adding names) must fail loudly here and
be updated deliberately, together with docs/solvers.md."""
import repro.core as core

# The deliberate export surface.  Update this snapshot (and docs) when the API
# intentionally changes; an unexplained diff is a regression.
CORE_ALL_SNAPSHOT = (
    # cost-model vocabulary
    "BW", "FW", "IF", "TR", "SEQ", "PIPE", "SCHEDULES",
    "effective_microbatches",
    "CPU_XEON_6226R", "GPU_RTX_A6000", "ComputeModel",
    "EvalCache", "LayerProfile", "ModelProfile", "LatencyBreakdown",
    "Plan", "PlanEvaluator", "ServiceChainRequest",
    # engine: problem / solver / outcome
    "OPTIMAL", "FEASIBLE", "INFEASIBLE", "STATUSES",
    "ProblemInstance", "SolveOutcome", "SolveResult", "SolverInfo",
    "register_solver", "unregister_solver", "solve", "solve_batch",
    "solver_names",
    "solver_supports", "ensure_solver_supported", "get_solver",
    "solver_capabilities", "portfolio_solve", "PORTFOLIO_DEFAULT_MEMBERS",
    # network + legacy solver surface
    "LinkSpec", "NodeSpec", "PhysicalNetwork", "SOLVERS",
    "bcd_solve", "exact_solve", "ilp_solve", "comp_ms_solve", "comm_ms_solve",
    "dfts", "k_sequence_segmentation",
    "candidate_sets", "nsfnet", "random_network", "tpu_pod_topology",
    "resnet101_profile",
    "even_split", "segments_from_sizes", "cuts_from_segments",
    "validate_segments",
    "transmission_time_s", "tpu_group_compute_model",
    # round-trip training pipelines (docs/training.md)
    "evaluate_round_trip", "round_trip_stage_times", "round_trip_taus",
    "round_trip_bottleneck_s", "segment_comp_dir_s",
)


def test_core_all_matches_snapshot():
    assert sorted(core.__all__) == sorted(CORE_ALL_SNAPSHOT), (
        "repro.core.__all__ drifted from the snapshot; if the change is "
        "intentional update tests/test_public_api.py and docs/solvers.md")
    assert len(set(core.__all__)) == len(core.__all__), "duplicate exports"


def test_core_all_names_exist_and_are_importable():
    for name in core.__all__:
        assert hasattr(core, name), f"__all__ exports missing name {name!r}"


def test_builtin_solvers_registered():
    names = core.solver_names()
    for required in ("ilp", "exact", "bcd", "comp-ms", "comm-ms", "portfolio"):
        assert required in names
    # the legacy dict view is derived from the registry, never hand-written
    assert set(core.SOLVERS) == set(names)
